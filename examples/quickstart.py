"""Quickstart: QUEST over a synthetic corpus in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Engine, Filter, Query, conj
from repro.data.corpus import make_wiki_corpus
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever


def main():
    corpus = make_wiki_corpus(seed=0)
    print(f"corpus: {len(corpus.docs)} documents, "
          f"{len(corpus.attr_specs)} logical tables")

    retriever = TwoLevelRetriever(corpus)          # builds the two-level index
    # batch_size batches extractions across documents (same rows and token
    # cost as batch_size=1; wall-clock win with the real serving extractor)
    engine = Engine(retriever, OracleExtractor(corpus), batch_size=8)

    query = Query(
        tables=["players"],
        select=[("players", "player_name")],
        where=conj(Filter("age", ">", 35, table="players"),
                   Filter("all_stars", ">", 12, table="players")),
    )
    print("query:", query)

    result = engine.execute(query)
    print(f"\n{len(result.rows)} rows:")
    for r in result.rows:
        print("  ", r["players.player_name"])
    print("\nLLM cost:", result.ledger.snapshot())
    print("\nexample per-document plans (instance-optimized):")
    for (table, doc), plan in list(result.plans_sampled.items())[:3]:
        print(f"  {doc}: {plan}")


if __name__ == "__main__":
    main()
