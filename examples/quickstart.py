"""Quickstart: QUEST over a synthetic corpus through the Session API.

    PYTHONPATH=src python examples/quickstart.py

A Session owns the cross-query state (attribute-value cache, per-table
sampling statistics, cost ledger): `prepare` validates and explains a
query before anything is paid, `submit` returns a handle whose `rows()`
streams results as documents clear projection, and a second query on the
same table reuses the first's sampling investment.
"""
from repro.core import Filter, Query, Session, conj
from repro.data.corpus import make_wiki_corpus
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever


def main():
    corpus = make_wiki_corpus(seed=0)
    print(f"corpus: {len(corpus.docs)} documents, "
          f"{len(corpus.attr_specs)} logical tables")

    retriever = TwoLevelRetriever(corpus)          # builds the two-level index
    # batch_size batches extractions across documents — and across queries
    # (same rows and token cost as batch_size=1; wall-clock win with the
    # real serving extractor)
    session = Session(retriever, OracleExtractor(corpus), batch_size=8)

    query = Query(
        tables=["players"],
        select=[("players", "player_name")],
        where=conj(Filter("age", ">", 35, table="players"),
                   Filter("all_stars", ">", 12, table="players")),
    )
    prepared = session.prepare(query)     # unknown table/op/attr fails HERE
    print("plan before paying anything:")
    print(prepared.explain_text())

    handle = prepared.submit()
    print("\nrows (streamed as documents clear projection):")
    for row in handle.rows():
        print("  ", row["players.player_name"])
    result = handle.result()
    print("\nLLM cost (this query only):", result.ledger.snapshot())
    print("\nexample per-document plans (instance-optimized):")
    for (table, doc), plan in list(result.plans_sampled.items())[:3]:
        print(f"  {doc}: {plan}")

    # a second query on the same table: sampling already paid -> reused
    q2 = Query(tables=["players"], select=[("players", "player_name")],
               where=Filter("age", ">", 38, table="players"))
    print("\nsecond query:", q2)
    print(session.prepare(q2).explain_text())
    r2 = session.execute(q2)
    print(f"rows: {len(r2.rows)} | sampling tokens this query: "
          f"{r2.ledger.per_phase.get('sampling', 0)} (reused: "
          f"{r2.meta['sampling_reused']['players']})")


if __name__ == "__main__":
    main()
