"""Live corpus: ingest / update / delete while querying (DESIGN.md §17).

    PYTHONPATH=src python examples/live_corpus.py

A `LiveCorpus` puts corpus mutations behind a versioned mutation log, a
`LiveRetriever` absorbs each mutation incrementally (content-hash memo:
only the bytes an edit touched are re-embedded), and a `LiveSession`
serializes mutations against in-flight queries — a mutation arriving
while a query holds emitted rows is deferred; one arriving over a
rowless query restarts it on the new snapshot. After every mutation the
session's rows stay byte-identical to a session rebuilt from scratch.
"""
from repro.core import Filter, Query, Session, conj
from repro.data.corpus import Document, make_wiki_corpus
from repro.extract import OracleExtractor
from repro.live import LiveCorpus, LiveRetriever, LiveSession, render_edit


def copy_subset(full, ids):
    # Corpus.subset shares Document objects with its parent; copy them so
    # live in-place mutations leave the source corpus pristine.
    sub = full.subset(ids)
    sub.docs = {d: Document(doc.doc_id, doc.domain, doc.text, dict(doc.truth),
                            dict(doc.spans), doc.tokens, version=doc.version,
                            sha=doc.sha)
                for d, doc in sub.docs.items()}
    return sub


def rows_of(sess, query):
    return sorted(sess.execute(query).rows, key=repr)


def rebuilt_rows(live, retr, query):
    """The oracle: corpus + index rebuilt from scratch at this mutation
    point, queried through a fresh (cold) session."""
    snap = live.snapshot()
    fresh = Session(retr.rebuild_reference(snap), OracleExtractor(snap),
                    batch_size=8)
    return sorted(fresh.execute(query).rows, key=repr)


def report(tag, live, retr, sess):
    emb = retr.embedder
    print(f"  [{tag}] seq={live.seq} docs={len(live.docs)} | "
          f"re-embedded {emb.reembedded_bytes}B, reused {emb.reused_bytes}B")
    print(f"  [{tag}] cascade: {sess.cascade.stats.snapshot()}")


def main():
    full = make_wiki_corpus(seed=0)
    players = [d for d in full.docs if full.docs[d].domain == "players"]
    teams = [d for d in full.docs if full.docs[d].domain == "teams"]
    live = LiveCorpus(copy_subset(full, players[:20] + teams[:8]))
    retr = LiveRetriever(live)                   # frozen-idf incremental index
    # batch_size=2 streams rows in small projection chunks, so the
    # snapshot-isolation demo below can catch a query mid-flight
    sess = LiveSession(live, retr, OracleExtractor(live), batch_size=2)
    print(f"live corpus: {len(live.docs)} documents, seq={live.seq}")

    query = Query(
        tables=["players"],
        select=[("players", "player_name")],
        where=conj(Filter("age", ">", 30, table="players"),
                   Filter("all_stars", ">=", 3, table="players")),
    )
    base = rows_of(sess, query)
    print(f"\ninitial query: {len(base)} rows")

    # -- update: a localized edit re-embeds only the touched sentence ------
    pid = players[0]
    rec = sess.update(pid, render_edit(live, pid, "age", 41))
    print(f"\nupdate {pid} (age -> 41): seq={rec.seq} "
          f"version={rec.version} sha={rec.sha[:12]}…")
    report("update", live, retr, sess)

    # -- delete: every cache / sample / index entry for the doc drops ------
    rec = sess.delete(players[1])
    print(f"\ndelete {players[1]}: seq={rec.seq}")
    report("delete", live, retr, sess)

    # -- ingest: a brand-new document becomes queryable immediately -------
    donor = next(d for d in players if d not in live.docs)
    rec = sess.ingest("players/new0", full.docs[donor].text, "players")
    print(f"\ningest players/new0: seq={rec.seq} sha={rec.sha[:12]}…")
    report("ingest", live, retr, sess)

    after = rows_of(sess, query)
    oracle = rebuilt_rows(live, retr, query)
    assert after == oracle, "live rows diverged from rebuilt-from-scratch"
    print(f"\nquery after 3 mutations: {len(after)} rows "
          f"(byte-identical to a rebuilt corpus + fresh session)")

    # -- snapshot isolation: mutations defer behind a query with rows -----
    handle = sess.prepare(query).submit()
    while not handle._rows and handle in sess._active:
        sess._step()                    # drive until the first rows stream
    rec = sess.update(pid, render_edit(live, pid, "all_stars", 9))
    print(f"\nmutation over live rows deferred: record={rec} "
          f"(applies once the query drains)")
    assert rec is None, "expected the mutation to defer behind live rows"
    handle.result()                     # drain the in-flight query
    final = rows_of(sess, query)        # next query applies the pending update
    assert live.seq == 4 and final == rebuilt_rows(live, retr, query)
    print(f"pending update applied on the next query: seq={live.seq}, "
          f"{len(final)} rows, still oracle-identical")
    print(f"live_stats: {sess.live_stats}")

    # -- replay: the log rebuilds the manifest bit-for-bit ----------------
    fresh = LiveCorpus(copy_subset(full, players[:20] + teams[:8]))
    live.log.replay(fresh)
    assert fresh.log.manifest_digest() == live.log.manifest_digest()
    print(f"replay digest ok: {live.log.manifest_digest()[:16]}… "
          f"({len(live.log)} mutations)")


if __name__ == "__main__":
    main()
