"""Difficulty-aware model cascade (DESIGN.md §18): the same analytics
query target-only and cascaded, side by side.

    PYTHONPATH=src python examples/cascade_analytics.py

Two served Sessions over the synthetic SWDE corpus run one query each:

  * target-only — every extraction pays the target model;
  * cascaded    — a small zoo model (same engine plumbing, ~1/20 the
    parameters) serves the per-(doc, attr) extractions the
    DifficultyEstimator scores as easy (sampling-phase agreement +
    segment retrieval margins + context length); the verifier escalates
    anything structurally invalid back to the target model, exactly once
    per (doc, attr).

Printed at the end: per-tier token counts, the routing split, the
escalation rate, the target-model tokens the cascade avoided, and the row
diff between the two paths — which is empty, because the §8.1 parse is
deterministic per (doc, attr, segments): the cascade changes which model
produced a value, never which value.

Uses reduced (smoke) configs so it runs on CPU in under a minute.
"""
import time

import jax

from repro.configs import get_smoke_config
from repro.core import DifficultyEstimator, Filter, Query, Session, conj
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.extract import CascadeExtractor, ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_params
from repro.serving.engine import ServingEngine

MAX_NEW = 6
BATCH = 4


def _query() -> Query:
    return Query(tables=["universities"],
                 select=[("universities", "university_name")],
                 where=conj(Filter("tuition", "<", 42000,
                                   table="universities"),
                            Filter("enrollment", ">", 15000,
                                   table="universities")))


def _rows_key(result):
    return sorted(tuple(sorted(r["_docs"].items())) for r in result.rows)


def main():
    full = make_swde_corpus()
    keep = ([d for d in sorted(full.docs) if "universities" in d][:40]
            + [d for d in sorted(full.docs) if "laptops" in d][:10])
    corpus = full.subset(keep)
    print(f"corpus: {len(corpus.docs)} documents")

    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    small_cfg = cfg.replace(num_layers=1, d_model=32, n_heads=2,
                            n_kv_heads=2, head_dim=16, d_ff=48)
    params = init_params(cfg, jax.random.PRNGKey(0))
    small_params = init_params(small_cfg, jax.random.PRNGKey(1))

    # ---- path 1: target-only --------------------------------------------
    engine = ServingEngine(cfg, params, slots=BATCH, max_len=1024,
                           prefix_cache=True)
    retr = TwoLevelRetriever(corpus)
    session = Session(retr, ServedExtractor(corpus, engine, max_new=MAX_NEW),
                      batch_size=BATCH)
    t0 = time.time()
    target_result = session.execute(_query())
    target_wall = time.time() - t0
    target_stats = session.extractor.stats

    # ---- path 2: cascaded -----------------------------------------------
    engine = ServingEngine(cfg, params, slots=BATCH, max_len=1024,
                           prefix_cache=True)
    small = ServingEngine(small_cfg, small_params, slots=BATCH, max_len=1024,
                          prefix_cache=True)
    retr = TwoLevelRetriever(corpus)
    extractor = CascadeExtractor(corpus, engine, small, cascade="on",
                                 difficulty=DifficultyEstimator(retr),
                                 max_new=MAX_NEW)
    session = Session(retr, extractor, batch_size=BATCH)
    prepared = session.prepare(_query())
    t0 = time.time()
    casc_result = prepared.submit().result()
    casc_wall = time.time() - t0
    s = extractor.stats

    # explain() after the sampling phase predicts the tier mix per stage
    print("\nplan with predicted cascade tier split (post-sampling):")
    print(prepared.explain_text())

    routed = s.routed_small + s.routed_target
    print("\n--- per-tier economics ----------------------------------------")
    print(f"target-only : {target_stats.prompt_tokens:6d} prompt + "
          f"{target_stats.generated_tokens:4d} decode tokens "
          f"({target_wall:.1f}s)")
    print(f"cascaded    : target {s.prompt_tokens:6d} prompt + "
          f"{s.generated_tokens:4d} decode | small "
          f"{s.small_prompt_tokens:6d} prompt + "
          f"{s.small_generated_tokens:4d} decode ({casc_wall:.1f}s)")
    reduction = 1 - s.generated_tokens / max(target_stats.generated_tokens, 1)
    # round deltas (prefix/spec/cascade) land on the session ledger — the
    # per-query child ledgers carry the logical token charges only
    print(f"target decode tokens avoided: {reduction:.1%} "
          f"(ledger target_tokens_saved="
          f"{session.ledger.snapshot()['target_tokens_saved']})")
    print(f"routing     : {s.routed_small}/{routed} small-tier "
          f"({s.routed_small / max(routed, 1):.0%}), "
          f"{s.memo_target_routes} memoized target routes")
    print(f"verifier    : {s.accepted_small} accepted, {s.escalations} "
          f"escalated (rate {s.escalations / max(s.routed_small, 1):.1%})")

    diff = (set(map(repr, _rows_key(target_result)))
            ^ set(map(repr, _rows_key(casc_result))))
    print(f"\nrow diff target-only vs cascaded: {sorted(diff) or '(empty)'}")
    assert not diff, "cascade changed rows — §18 parity violated"
    print(f"rows ({len(casc_result.rows)}):")
    for row in casc_result.rows[:5]:
        print("  ", row["universities.university_name"])
    if len(casc_result.rows) > 5:
        print(f"   ... and {len(casc_result.rows) - 5} more")


if __name__ == "__main__":
    main()
