"""End-to-end driver (deliverable (b)): QUEST query execution where every
extraction runs through the REAL JAX serving engine (prefill + batched
decode with KV caches) — the paper's LLM substrate, not a mock.

    PYTHONPATH=src python examples/analytics_serving.py [--arch qwen2.5-3b]

Uses the arch's reduced (smoke) config so it runs on CPU; on TPU pass
--full to serve the full config on the production mesh.
"""
import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import Engine, Filter, Query, conj
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.extract.served import ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_params
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=None,
                    help="cross-document extraction batch (default: slots)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared-prefix KV reuse (DESIGN.md §10)")
    args = ap.parse_args()

    cfg = (get_config if args.full else get_smoke_config)(args.arch)
    cfg = cfg.replace(vocab_size=max(cfg.vocab_size, lm_data.VOCAB))
    print(f"serving {cfg.name} ({cfg.family}), d_model={cfg.d_model}, "
          f"layers={cfg.num_layers}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, slots=args.slots, max_len=1024,
                           prefix_cache=not args.no_prefix_cache)

    corpus = make_swde_corpus()
    retriever = TwoLevelRetriever(corpus)
    extractor = ServedExtractor(corpus, engine)
    batch = args.batch_size if args.batch_size is not None else args.slots
    quest = Engine(retriever, extractor, sample_rate=0.03, batch_size=batch)

    query = Query(
        tables=["universities"],
        select=[("universities", "university_name")],
        where=conj(Filter("tuition", "<", 20000, table="universities"),
                   Filter("enrollment", ">", 30000, table="universities")),
    )
    print("query:", query)
    t0 = time.time()
    result = quest.execute(query)
    dt = time.time() - t0

    print(f"\n{len(result.rows)} rows in {dt:.1f}s:")
    for r in result.rows[:10]:
        print("  ", r["universities.university_name"])
    print("\nQUEST ledger:", result.ledger.snapshot())
    print("serving engine stats:", engine.stats)
    print("served extractor:", extractor.stats)
    print("batch scheduler:", quest.scheduler.stats.snapshot())


if __name__ == "__main__":
    main()
