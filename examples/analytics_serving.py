"""End-to-end driver (deliverable (b)): QUEST query execution where every
extraction runs through the REAL JAX serving engine (prefill + batched
decode with KV caches) — the paper's LLM substrate, not a mock.

    PYTHONPATH=src python examples/analytics_serving.py [--arch qwen2.5-3b]

Two analytics queries run *concurrently* through one Session multiplexed
over one serving engine: their document coroutines feed the same
continuous-batching rounds (shared `engine.run()` calls, shared prefix-KV
groups) and the second query reuses the first's sampling investment, so
its sampling token column is zero. Decode runs speculatively by default
(`spec_decode="prompt_lookup"`, DESIGN.md §14): n-gram drafts from each
request's own context are verified in batched chunks, emitting several
tokens per target invocation at byte-identical output — the acceptance
rate and decode steps saved are printed with the engine stats.

Uses the arch's reduced (smoke) config so it runs on CPU; on TPU pass
--full to serve the full config on the production mesh. `--mesh-shape
1x2` serves one TP/FSDP-sharded engine on a device mesh (DESIGN.md §15;
on CPU the devices are forced via XLA_FLAGS before jax initializes) and
`--replicas 2` runs data-parallel engines behind one shared admission
queue — rows are byte-identical either way. `--tenants N [--qps R]`
routes every extraction through the async admission tier (DESIGN.md §16):
each query runs as its own tenant under weighted fair-share scheduling
with page-headroom backpressure, and per-tenant token/latency accounting
prints at the end. `--compilation-cache DIR` persists XLA compilations
across runs.
"""
import argparse
import os
import sys
import time


def _force_cpu_devices_for_mesh(argv) -> None:
    # XLA only honours the forced host-device count if it's set before jax
    # initializes, so this must run ahead of `import jax` when the user
    # asks for a mesh on a single-device host.
    if "--mesh-shape" not in argv:
        return
    spec = argv[argv.index("--mesh-shape") + 1]
    need = 1
    for part in spec.replace(",", "x").split("x"):
        need *= int(part)
    flags = os.environ.get("XLA_FLAGS", "")
    if need > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={need}".strip())


_force_cpu_devices_for_mesh(sys.argv)

import jax  # noqa: E402

from repro.configs import ARCH_IDS, get_config, get_smoke_config  # noqa: E402
from repro.core import Filter, Query, Session, conj  # noqa: E402
from repro.data import lm_data  # noqa: E402
from repro.data.corpus import make_swde_corpus  # noqa: E402
from repro.extract.served import ServedExtractor  # noqa: E402
from repro.index.retriever import TwoLevelRetriever  # noqa: E402
from repro.launch.mesh import make_serving_mesh, parse_mesh_shape  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402
from repro.serving.frontend import ServingFrontend  # noqa: E402
from repro.serving.replicas import ReplicaGroup  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=None,
                    help="cross-document extraction batch (default: slots)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared-prefix KV reuse (DESIGN.md §10)")
    ap.add_argument("--spec-decode", default="prompt_lookup",
                    choices=["off", "prompt_lookup"],
                    help="speculative decoding drafter (DESIGN.md §14)")
    ap.add_argument("--mesh-shape", default=None,
                    help="serve on a (data, model) device mesh, e.g. 1x2 "
                         "(DESIGN.md §15; forces CPU devices if needed)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind one shared "
                         "queue (DESIGN.md §15)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="route extraction through the async admission tier "
                         "with N tenants on weighted fair-share scheduling "
                         "(DESIGN.md §16); 0 = direct engine submission")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="with --tenants: stagger query arrivals at this "
                         "rate instead of submitting all at once")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent JAX compilation cache directory — "
                         "repeat runs skip XLA recompiles")
    args = ap.parse_args()

    cfg = (get_config if args.full else get_smoke_config)(args.arch)
    cfg = cfg.replace(vocab_size=max(cfg.vocab_size, lm_data.VOCAB))
    print(f"serving {cfg.name} ({cfg.family}), d_model={cfg.d_model}, "
          f"layers={cfg.num_layers}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = None
    if args.mesh_shape is not None:
        mesh = make_serving_mesh(parse_mesh_shape(args.mesh_shape))
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    if args.replicas > 1:
        engine = ReplicaGroup(cfg, params, replicas=args.replicas,
                              slots=args.slots, max_len=1024,
                              prefix_cache=not args.no_prefix_cache,
                              spec_decode=args.spec_decode, mesh=mesh,
                              compilation_cache_dir=args.compilation_cache)
        print(f"{args.replicas} engine replicas behind one shared queue")
    else:
        engine = ServingEngine(cfg, params, slots=args.slots, max_len=1024,
                               prefix_cache=not args.no_prefix_cache,
                               spec_decode=args.spec_decode, mesh=mesh,
                               compilation_cache_dir=args.compilation_cache)

    frontend = None
    if args.tenants > 0:
        frontend = ServingFrontend(engine, max_prefill_chunks=2)
        print(f"admission tier: {args.tenants} tenants, weighted fair share")

    corpus = make_swde_corpus()
    retriever = TwoLevelRetriever(corpus)
    # longer generations give the prompt-lookup drafter its regime (the
    # n-gram matcher accelerates repeated/copied spans mid-output)
    extractor = ServedExtractor(corpus, engine, max_new=24,
                                frontend=frontend)
    batch = args.batch_size if args.batch_size is not None else args.slots
    session = Session(retriever, extractor, sample_rate=0.03,
                      batch_size=batch)

    q1 = Query(
        tables=["universities"],
        select=[("universities", "university_name")],
        where=conj(Filter("tuition", "<", 20000, table="universities"),
                   Filter("enrollment", ">", 30000, table="universities")),
    )
    q2 = Query(
        tables=["universities"],
        select=[("universities", "university_name")],
        where=Filter("enrollment", ">", 45000, table="universities"),
    )
    p1, p2 = session.prepare(q1), session.prepare(q2)
    for p in (p1, p2):
        print("\n" + p.explain_text())

    t0 = time.time()
    if args.tenants > 0:
        # each query runs as its own tenant (round-robin); --qps staggers
        # arrivals like a live workload instead of one submit burst
        handles = []
        for i, p in enumerate((p1, p2)):
            if args.qps > 0 and i:
                time.sleep(1.0 / args.qps)
            handles.append(p.submit(tenant=f"tenant-{i % args.tenants}"))
        h1, h2 = handles
    else:
        h1, h2 = p1.submit(), p2.submit()  # both in flight, shared rounds
    session.drain()
    dt = time.time() - t0
    r1, r2 = h1.result(), h2.result()

    for name, r in (("q1", r1), ("q2", r2)):
        print(f"\n{name}: {len(r.rows)} rows "
              f"(sampling tokens {r.ledger.per_phase.get('sampling', 0)}, "
              f"reused: {r.meta['sampling_reused']['universities']})")
        for row in r.rows[:10]:
            print("  ", row["universities.university_name"])
    print(f"\nboth queries in {dt:.1f}s over one engine")
    es = engine.stats
    if args.spec_decode != "off":
        acc = es["accepted_tokens"] / max(es["draft_tokens"], 1)
        print(f"speculative decode ({args.spec_decode}): "
              f"{es['draft_tokens']} drafted, {es['accepted_tokens']} "
              f"accepted ({acc:.1%}), {es['decode_steps_saved']} decode "
              f"steps saved over {es['spec_rounds']} verify rounds")
    print("session ledger:", session.ledger.snapshot())
    print("serving engine stats:", engine.stats)
    print("served extractor:", extractor.stats)
    print("batch scheduler:", session.scheduler.stats.snapshot())
    if frontend is not None:
        print("admission tier:", frontend.stats)
        for tenant, snap in sorted(frontend.tenant_snapshot().items()):
            print(f"  {tenant}: {snap}")
        for tenant, snap in session.tenant_costs().items():
            print(f"  {tenant} tokens: in={snap['input_tokens']} "
                  f"out={snap['output_tokens']} calls={snap['llm_calls']}")


if __name__ == "__main__":
    main()
