"""EXPLAIN ANALYZE + trace profiling of one QUEST query (DESIGN.md §19).

    PYTHONPATH=src python examples/explain_analyze.py

Attach one `Tracer` to a Session, run a query, then:

  * `handle.report_text()` prints the estimated-vs-actual table: per plan
    stage, the optimizer's selectivity/cost estimates (from the sampling
    investment) next to what the run actually measured — filters
    evaluated/passed, tokens and invocations per attribute — plus the
    prefix/speculation/cascade savings columns;
  * the trace exports to `explain_analyze_trace.json` in Chrome
    trace-event format — open https://ui.perfetto.dev and drag the file
    in (or chrome://tracing) to see the session -> scheduler -> engine
    span tree on a timeline.

The wall clock is used here so the Perfetto timeline is real time; pass
`Tracer(clock="ticks")` instead for byte-deterministic traces (what
tests/test_obs.py pins).
"""
import json
from pathlib import Path

from repro.core import Filter, Query, Session, conj
from repro.data.corpus import make_wiki_corpus
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.obs import Tracer

TRACE_PATH = Path(__file__).parent / "explain_analyze_trace.json"


def main():
    corpus = make_wiki_corpus(seed=0)
    tracer = Tracer(clock="wall", level="full")   # obs_level knob: off|phases|full
    session = Session(TwoLevelRetriever(corpus), OracleExtractor(corpus),
                      batch_size=8, tracer=tracer)

    query = Query(
        tables=["players"],
        select=[("players", "player_name")],
        where=conj(Filter("age", ">", 30, table="players"),
                   Filter("all_stars", ">=", 5, table="players")),
    )
    prepared = session.prepare(query)
    print("ESTIMATES (explain, before paying):")
    print(prepared.explain_text())

    handle = prepared.submit()
    rows = list(handle.rows())
    print(f"\n{len(rows)} rows; first 3: "
          f"{[r['players.player_name'] for r in rows[:3]]}")

    print("\n" + handle.report_text())

    report = handle.report()
    for t in report["tables"]:
        for st in t["stages"]:
            est, act = st["est_selectivity"], st["actual_selectivity"]
            if est is not None and act is not None:
                print(f"  residual {st['attr']}: est sel {est:.3f} vs "
                      f"actual {act:.3f} ({act - est:+.3f})")

    tracer.write_chrome(TRACE_PATH)
    n_events = len(json.loads(TRACE_PATH.read_text())["traceEvents"])
    print(f"\nwrote {TRACE_PATH.name}: {n_events} events "
          f"({len(tracer.spans)} spans) — open https://ui.perfetto.dev "
          f"and drop the file in to browse the timeline")


if __name__ == "__main__":
    main()
