"""Training driver example (deliverable (b)): train an LM on the corpus
byte stream with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200        # ~5M params (CPU)
    PYTHONPATH=src python examples/train_lm.py --size 100m ...    # ~100M (accelerator)

Demonstrates: data pipeline -> train_step (remat, grad clip) -> AdamW ->
async checkpoints -> crash-free resume (rerun the same command; it continues
from the last checkpoint).
"""
import argparse

from repro.data import lm_data
from repro.data.corpus import make_wiki_corpus
from repro.models.config import ModelConfig
from repro.training.driver import Trainer, TrainerConfig
from repro.training.optim import OptConfig

SIZES = {
    "5m": ModelConfig(name="lm-5m", num_layers=4, d_model=256, n_heads=4,
                      n_kv_heads=4, d_ff=1024, vocab_size=lm_data.VOCAB,
                      dtype="float32"),
    "100m": ModelConfig(name="lm-100m", num_layers=12, d_model=768, n_heads=12,
                        n_kv_heads=12, d_ff=3072, vocab_size=lm_data.VOCAB,
                        dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="5m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = SIZES[args.size]
    corpus = make_wiki_corpus()
    stream = lm_data.corpus_token_stream(corpus)
    data = lm_data.LMBatches(stream, batch=args.batch, seq=args.seq)
    print(f"model {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params; "
          f"stream {len(stream)} tokens")

    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=50,
                         ckpt_dir=args.ckpt_dir, log_every=10)
    trainer = Trainer(cfg, OptConfig(lr=3e-4, warmup_steps=20), data, tcfg)
    trainer.init()
    if trainer.resume():
        print(f"resumed from step {trainer.step}")
    hist = trainer.run()
    print(f"done: loss {hist[0]:.3f} -> {hist[-1]:.3f}")


if __name__ == "__main__":
    main()
