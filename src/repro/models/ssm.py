"""Mamba1 (selective scan) and Mamba2 (SSD chunked) blocks, TPU-adapted.

Hardware adaptation note (see DESIGN.md §5): the CUDA reference realizes the
selective scan as a warp-parallel prefix scan in shared memory. On TPU we
instead (a) express Mamba2's scalar-decay recurrence in the SSD *matrix* form
(chunked: intra-chunk attention-like matmuls feed the MXU, inter-chunk carry
is a tiny scan), and (b) express Mamba1's per-channel-decay recurrence as a
lane-vectorized sequential scan (channels on the 128-wide VPU lanes, time
sequential) — the Pallas `ssm_scan` kernel keeps the state VMEM-resident.
The pure-jnp forms below are the oracles and the XLA/dry-run path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import rms_norm


def causal_depthwise_conv(x, w, b):
    """x: (B, S, C); w: (K, C); b: (C). Causal depthwise conv."""
    K, C = w.shape
    out = lax.conv_general_dilated(
        x, w[:, None, :], window_strides=(1,), padding=[(K - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=C)
    return out + b


def conv_step(conv_state, x_t, w, b):
    """One decode step of the causal conv. conv_state: (B, K-1, C); x_t: (B, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return window[:, 1:], y


# ------------------------------------------------------------- mamba 1 -----


def mamba1_init(cfg: ModelConfig, key):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    r, K = cfg.resolved_dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    std = 0.02
    dt_init = jnp.exp(jax.random.uniform(ks[5], (di,), jnp.float32)
                      * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * std,
        "conv_w": jax.random.normal(ks[1], (K, di), jnp.float32) * std,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": jax.random.normal(ks[2], (di, r + 2 * N), jnp.float32) * std,
        "dt_proj": jax.random.normal(ks[3], (r, di), jnp.float32) * (r ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(dt_init)),  # softplus^-1 of dt_init
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d), jnp.float32) * std / math.sqrt(2 * cfg.num_layers),
    }


def _mamba1_ssm_inputs(cfg: ModelConfig, p, x):
    """Shared pre-scan computation. x: (B, S, d)."""
    N, r = cfg.ssm_state, cfg.resolved_dt_rank
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    return x_in, z


def _mamba1_scan_params(cfg, p, x_conv):
    N, r = cfg.ssm_state, cfg.resolved_dt_rank
    proj = x_conv @ p["x_proj"]
    dt_raw, B_mat, C_mat = proj[..., :r], proj[..., r:r + N], proj[..., r + N:]
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])  # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (di,N)
    return dt, A, B_mat, C_mat


def mamba1_scan_ref(x, dt, A, B_mat, C_mat, D, h0=None):
    """Reference selective scan. x,dt: (B,S,di); A: (di,N); B,C: (B,S,N).

    Returns (y (B,S,di), h_final (B,di,N)). fp32 state.
    """
    Bsz, S, di = x.shape
    N = A.shape[-1]
    h = jnp.zeros((Bsz, di, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        da = jnp.exp(dt_t[..., None] * A)                       # (B,di,N)
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B_mat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C_mat, 1, 0).astype(jnp.float32))
    h, ys = lax.scan(step, h, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D
    return y.astype(x.dtype), h


def mamba1_scan_states(x, dt, A, B_mat, C_mat, D, h0=None):
    """`mamba1_scan_ref` that also returns the recurrent state *after every
    position* — the mid-sequence checkpoints speculative-decoding rollback
    needs (DESIGN.md §14). Returns (y (B,S,di), h_all (B,S,di,N) fp32);
    h_all[:, j] equals the final state of a scan over the first j+1 tokens,
    bit-for-bit (same step recurrence, states merely collected)."""
    Bsz, S, di = x.shape
    N = A.shape[-1]
    h = jnp.zeros((Bsz, di, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        da = jnp.exp(dt_t[..., None] * A)
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, (y, h)

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B_mat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C_mat, 1, 0).astype(jnp.float32))
    _, (ys, hs) = lax.scan(step, h, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D
    return y.astype(x.dtype), jnp.moveaxis(hs, 0, 1)


def mamba1_apply(cfg: ModelConfig, p, x, *, ssm_kernel=None):
    x_in, z = _mamba1_ssm_inputs(cfg, p, x)
    x_conv = jax.nn.silu(causal_depthwise_conv(x_in, p["conv_w"], p["conv_b"]))
    dt, A, B_mat, C_mat = _mamba1_scan_params(cfg, p, x_conv)
    scan = ssm_kernel or mamba1_scan_ref
    y, _ = scan(x_conv, dt, A, B_mat, C_mat, p["D"])
    return (y * jax.nn.silu(z)) @ p["out_proj"]


def mamba1_decode(cfg: ModelConfig, p, x_t, *, conv_state, ssm_state):
    """x_t: (B, 1, d). conv_state: (B, K-1, di); ssm_state: (B, di, N) fp32."""
    x_in, z = _mamba1_ssm_inputs(cfg, p, x_t)
    conv_state, xc = conv_step(conv_state, x_in[:, 0], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)[:, None, :]
    dt, A, B_mat, C_mat = _mamba1_scan_params(cfg, p, xc)
    da = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * A)
    ssm_state = da * ssm_state + (dt[:, 0] * xc[:, 0])[..., None].astype(jnp.float32) * B_mat[:, 0, None, :].astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", ssm_state, C_mat[:, 0].astype(jnp.float32))
    y = (y + xc[:, 0].astype(jnp.float32) * p["D"]).astype(x_t.dtype)[:, None, :]
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, conv_state, ssm_state


def mamba1_chunk(cfg: ModelConfig, p, x, *, conv_state, ssm_state,
                 length=None):
    """Advance conv+ssm state through a C-token chunk (chunked prefill).

    x: (B, C, d); conv_state: (B, K-1, di) raw pre-conv inputs; ssm_state:
    (B, di, N) fp32. Exactly the decode recurrence batched over C — the
    carried conv window is prepended so the causal conv sees the true
    history instead of zero padding. `length` (traced): true token count of
    a right-padded chunk — dt=0 past it freezes the scan state, and the
    conv tail is sliced at the real boundary. Returns
    (out, conv_state, ssm_state).
    """
    K = p["conv_w"].shape[0]
    x_in, z = _mamba1_ssm_inputs(cfg, p, x)
    x_cat = jnp.concatenate([conv_state.astype(x_in.dtype), x_in], axis=1)
    xc = jax.nn.silu(
        causal_depthwise_conv(x_cat, p["conv_w"], p["conv_b"])[:, K - 1:])
    dt, A, B_mat, C_mat = _mamba1_scan_params(cfg, p, xc)
    if length is not None:
        dt = dt * (jnp.arange(x.shape[1])[None, :, None] < length)
    y, h = mamba1_scan_ref(xc, dt, A, B_mat, C_mat, p["D"], h0=ssm_state)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    if length is None:
        tail = x_cat[:, -(K - 1):]
    else:
        tail = lax.dynamic_slice_in_dim(x_cat, length, K - 1, axis=1)
    return out, tail, h


def mamba1_chunk_states(cfg: ModelConfig, p, x, *, conv_state, ssm_state):
    """`mamba1_chunk` variant for speculative verification: every position's
    output is needed (per-position logits) and so is every position's state
    (rollback to an arbitrary acceptance boundary). Returns
    (out (B,C,d), x_cat (B,K-1+C,di), h_all (B,C,di,N)): the conv window
    after keeping j tokens is x_cat[:, j:j+K-1], the scan state h_all[:, j-1]."""
    K = p["conv_w"].shape[0]
    x_in, z = _mamba1_ssm_inputs(cfg, p, x)
    x_cat = jnp.concatenate([conv_state.astype(x_in.dtype), x_in], axis=1)
    xc = jax.nn.silu(
        causal_depthwise_conv(x_cat, p["conv_w"], p["conv_b"])[:, K - 1:])
    dt, A, B_mat, C_mat = _mamba1_scan_params(cfg, p, xc)
    y, hs = mamba1_scan_states(xc, dt, A, B_mat, C_mat, p["D"], h0=ssm_state)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, x_cat, hs


# ------------------------------------------------------------- mamba 2 -----


def mamba2_init(cfg: ModelConfig, key):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, K = cfg.n_ssm_heads, cfg.ssm_conv
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 4)
    std = 0.02
    dt_init = jnp.exp(jax.random.uniform(ks[3], (h,), jnp.float32)
                      * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * N + h), jnp.float32) * std,
        "conv_w": jax.random.normal(ks[1], (K, conv_dim), jnp.float32) * std,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt_init)),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d), jnp.float32) * std / math.sqrt(2 * cfg.num_layers),
    }


def _mamba2_proj(cfg: ModelConfig, p, x):
    di, N, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt_raw = zxbcdt[..., di + di + 2 * N:]
    return z, xbc, dt_raw


def mamba2_ssd_ref(x, dt, A, B_mat, C_mat, D, *, chunk: int, h0=None):
    """SSD chunked scan (matrix form). x: (B,S,h,p); dt: (B,S,h); A: (h,);
    B_mat/C_mat: (B,S,N) (single group). Returns (y, final_state (B,h,p,N))."""
    Bsz, S, H, P = x.shape
    N = B_mat.shape[-1]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:  # zero-pad: dt=0 => decay 1, contribution 0 => state preserved
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B_mat, C_mat = zp(x), zp(dt), zp(B_mat), zp(C_mat)
    S_pad = S + pad
    nc = S_pad // c
    f32 = jnp.float32

    xr = x.reshape(Bsz, nc, c, H, P).astype(f32)
    dtr = (dt.reshape(Bsz, nc, c, H).astype(f32))
    Br = B_mat.reshape(Bsz, nc, c, N).astype(f32)
    Cr = C_mat.reshape(Bsz, nc, c, N).astype(f32)

    dtA = dtr * A                                   # (B,nc,c,h)
    L = jnp.cumsum(dtA, axis=2)                     # inclusive cumsum
    # intra-chunk: M[t,j] = exp(L_t - L_j) * (C_t.B_j) * dt_j  for j <= t
    diff = L[:, :, :, None, :] - L[:, :, None, :, :]          # (B,nc,c,c,h)
    tri = jnp.tril(jnp.ones((c, c), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bnce,bnje->bncj", Cr, Br)
    M = CB[..., None] * decay * dtr[:, :, None, :, :]          # (B,nc,c,c,h)
    y_intra = jnp.einsum("bncjh,bnjhp->bnchp", M, xr)

    # chunk summaries: state contribution  S_n = sum_j exp(L_end - L_j) dt_j B_j x_j
    seg = jnp.exp(L[:, :, -1:, :] - L)                         # (B,nc,c,h)
    states = jnp.einsum("bnch,bnce,bnchp->bnhpe", seg * dtr, Br, xr)  # (B,nc,h,p,N)
    chunk_decay = jnp.exp(L[:, :, -1, :])                      # (B,nc,h)

    def carry_step(hprev, inp):
        st, cd = inp                                           # (B,h,p,N), (B,h)
        hnew = cd[..., None, None] * hprev + st
        return hnew, hprev

    h_init = jnp.zeros((Bsz, H, P, N), f32) if h0 is None else h0.astype(f32)
    h_fin, h_before = lax.scan(
        carry_step, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)                    # (B,nc,h,p,N)

    # inter-chunk contribution: y_t += C_t . (exp(L_t) * h_in)
    y_inter = jnp.einsum("bnce,bnch,bnhpe->bnchp", Cr, jnp.exp(L), h_before)
    y = (y_intra + y_inter).reshape(Bsz, S_pad, H, P) + xr.reshape(Bsz, S_pad, H, P) * D[:, None]
    y = y[:, :S]
    return y.astype(x.dtype), h_fin


def mamba2_apply(cfg: ModelConfig, p, x, *, ssd_kernel=None):
    B, S, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.mamba_headdim
    z, xbc, dt_raw = _mamba2_proj(cfg, p, x)
    xbc = jax.nn.silu(causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"]))
    x_in, B_mat, C_mat = xbc[..., :di], xbc[..., di:di + N], xbc[..., di + N:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    ssd = ssd_kernel or mamba2_ssd_ref
    y, _ = ssd(x_in.reshape(B, S, H, P), dt, A, B_mat, C_mat, p["D"], chunk=cfg.ssm_chunk)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba2_chunk(cfg: ModelConfig, p, x, *, conv_state, ssm_state,
                 length=None):
    """Chunked-prefill step for Mamba2 (see `mamba1_chunk`). x: (B, C, d);
    conv_state: (B, K-1, di+2N) raw pre-conv inputs; ssm_state: (B,h,p,N)
    fp32. Returns (out, conv_state, ssm_state)."""
    B, C, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.mamba_headdim
    K = p["conv_w"].shape[0]
    z, xbc_raw, dt_raw = _mamba2_proj(cfg, p, x)
    x_cat = jnp.concatenate([conv_state.astype(xbc_raw.dtype), xbc_raw], axis=1)
    xc = jax.nn.silu(
        causal_depthwise_conv(x_cat, p["conv_w"], p["conv_b"])[:, K - 1:])
    x_in, B_mat, C_mat = xc[..., :di], xc[..., di:di + N], xc[..., di + N:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    if length is not None:
        dt = dt * (jnp.arange(C)[None, :, None] < length)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = mamba2_ssd_ref(x_in.reshape(B, C, H, P), dt, A, B_mat, C_mat,
                          p["D"], chunk=cfg.ssm_chunk, h0=ssm_state)
    y = y.reshape(B, C, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    if length is None:
        tail = x_cat[:, -(K - 1):]
    else:
        tail = lax.dynamic_slice_in_dim(x_cat, length, K - 1, axis=1)
    return y @ p["out_proj"], tail, h


def mamba2_scan_states(x, dt, A, B_mat, C_mat, D, h0=None):
    """Sequential Mamba2 recurrence returning per-position states — the
    verification-path counterpart of `mamba1_scan_states`. Deliberately the
    `mamba2_decode` step math (not the SSD matrix form): a C-token verify
    chunk is tiny, and stepping the exact decode recurrence keeps the
    checkpointed states bit-identical to what sequential decode would have
    produced. x: (B,S,h,p); dt: (B,S,h); A: (h,); B/C: (B,S,N).
    Returns (y (B,S,h,p), h_all (B,S,h,p,N) fp32)."""
    Bsz, S, H, P = x.shape

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp               # (B,h,p),(B,h),(B,N),(B,N)
        da = jnp.exp(dt_t * A)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t, B_t)
        h = da[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, (y, h)

    h0 = jnp.zeros((Bsz, H, P, B_mat.shape[-1]), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B_mat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C_mat, 1, 0).astype(jnp.float32))
    _, (ys, hs) = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D[:, None]
    return y.astype(x.dtype), jnp.moveaxis(hs, 0, 1)


def mamba2_chunk_states(cfg: ModelConfig, p, x, *, conv_state, ssm_state):
    """`mamba2_chunk` variant for speculative verification (see
    `mamba1_chunk_states`). Returns (out (B,C,d), x_cat (B,K-1+C,di+2N),
    h_all (B,C,h,p,N))."""
    B, C, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.mamba_headdim
    K = p["conv_w"].shape[0]
    z, xbc_raw, dt_raw = _mamba2_proj(cfg, p, x)
    x_cat = jnp.concatenate([conv_state.astype(xbc_raw.dtype), xbc_raw], axis=1)
    xc = jax.nn.silu(
        causal_depthwise_conv(x_cat, p["conv_w"], p["conv_b"])[:, K - 1:])
    x_in, B_mat, C_mat = xc[..., :di], xc[..., di:di + N], xc[..., di + N:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, hs = mamba2_scan_states(x_in.reshape(B, C, H, P), dt, A, B_mat, C_mat,
                               p["D"], h0=ssm_state)
    y = y.reshape(B, C, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], x_cat, hs


def mamba2_decode(cfg: ModelConfig, p, x_t, *, conv_state, ssm_state):
    """x_t: (B,1,d); conv_state: (B,K-1,di+2N); ssm_state: (B,h,p,N) fp32."""
    B = x_t.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.mamba_headdim
    z, xbc, dt_raw = _mamba2_proj(cfg, p, x_t)
    conv_state, xc = conv_step(conv_state, xbc[:, 0], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    x_in, B_mat, C_mat = xc[..., :di], xc[..., di:di + N], xc[..., di + N:]
    dt = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"]).astype(jnp.float32)   # (B,h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * A)                                       # (B,h)
    xh = x_in.reshape(B, H, P).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, B_mat.astype(jnp.float32))
    ssm_state = da[..., None, None] * ssm_state + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, C_mat.astype(jnp.float32))
    y = y + xh * p["D"][:, None]
    y = y.reshape(B, 1, di).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], conv_state, ssm_state
