"""Unified model configuration covering all assigned architectures.

One dataclass describes every family: dense decoder-only transformers (GQA,
qk-norm, qkv-bias, squared-ReLU), MoE (shared+routed, top-k), MLA
(compressed-KV attention), pure SSM (Mamba1), hybrid Mamba2+shared-attention
(Zamba2), encoder-decoder (Whisper) and VLM (LLaVA-NeXT, stub frontend).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm

    # trunk
    num_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 256
    vocab_size: int = 1024
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention details
    attn_bias: bool = False           # qwen2.5 QKV bias
    qk_norm: bool = False             # qwen3 per-head RMSNorm on q/k
    use_rope: bool = True             # whisper uses learned absolute positions
    rope_theta: float = 10_000.0
    max_position: int = 1 << 20       # learned-abs position table size cap
    gated_mlp: bool = True            # llama-style gate/up/down (3 matrices)
    activation: str = "silu"          # silu | squared_relu | gelu

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    expert_d_ff: int = 0
    first_dense_layers: int = 0       # deepseek-v2: first layer(s) dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba)
    mamba_version: int = 0            # 0 = none, 1 = mamba1, 2 = mamba2
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_headdim: int = 64           # mamba2 head dim (p)
    dt_rank: int = 0                  # mamba1; 0 -> d_model // 16
    ssm_chunk: int = 64               # mamba2 SSD chunk length

    # hybrid (zamba2): shared attention block applied every `attn_every`
    # mamba layers, cycling over `n_shared_attn_blocks` shared blocks, each
    # application owning a LoRA adapter of rank `shared_lora_rank`.
    attn_every: int = 0
    n_shared_attn_blocks: int = 2
    shared_lora_rank: int = 0

    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 0              # whisper: 1500 post-conv frames (stub)

    # VLM (llava): image patch embeddings prepended to the text sequence.
    n_image_tokens: int = 0

    # norms / misc
    norm_eps: float = 1e-5
    use_layernorm: bool = False       # whisper uses LayerNorm, others RMSNorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"           # params/compute dtype for deployment
    logit_dtype: str = "float32"

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.mamba_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid archs)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper via its decoder)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Rough parameter count (used for roofline MODEL_FLOPS = 6*N*D and for
    # memory budgeting; exact count comes from the real param pytree).
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.use_mla:
                r = self.kv_lora_rank
                qd = self.qk_nope_dim + self.qk_rope_dim
                return (d * nq * qd + d * (r + self.qk_rope_dim)
                        + r * nq * (self.qk_nope_dim + self.v_head_dim)
                        + nq * self.v_head_dim * d)
            return d * (nq + 2 * nkv) * hd + nq * hd * d

        def mlp_params(ff: int) -> int:
            return d * ff * (3 if self.gated_mlp else 2)

        def mamba_params() -> int:
            di, n = self.d_inner, self.ssm_state
            if self.mamba_version == 2:
                h = self.n_ssm_heads
                return d * (2 * di + 2 * n + h) + di * d + di * self.ssm_conv
            r = self.resolved_dt_rank
            return (d * 2 * di + di * (r + 2 * n) + r * di + di * n
                    + di * d + di * self.ssm_conv)

        total = emb
        if self.family == "encdec":
            total += self.n_encoder_layers * (attn_params() + mlp_params(self.d_ff))
            total += self.num_layers * (2 * attn_params() + mlp_params(self.d_ff))
            total += self.encoder_seq * d  # encoder positions (stub frontend)
            return total
        if self.family == "ssm":
            return total + self.num_layers * mamba_params()
        if self.family == "hybrid":
            total += self.num_layers * mamba_params()
            shared = self.n_shared_attn_blocks * (attn_params() + mlp_params(self.d_ff))
            n_app = self.num_layers // max(1, self.attn_every)
            lora = n_app * 4 * (d * self.shared_lora_rank + self.shared_lora_rank * nq * hd)
            return total + shared + lora
        # dense / moe / vlm
        per_layer_attn = attn_params()
        if self.family == "moe" or self.n_experts:
            routed = self.n_experts * mlp_params(self.expert_d_ff or self.d_ff)
            shared = self.n_shared_experts * mlp_params(self.expert_d_ff or self.d_ff)
            router = d * self.n_experts
            moe_layers = self.num_layers - self.first_dense_layers
            total += self.first_dense_layers * (per_layer_attn + mlp_params(self.d_ff))
            if active_only:
                active_ff = (self.moe_top_k + self.n_shared_experts) * \
                    mlp_params(self.expert_d_ff or self.d_ff)
                total += moe_layers * (per_layer_attn + router + active_ff)
            else:
                total += moe_layers * (per_layer_attn + router + routed + shared)
            return total
        return total + self.num_layers * (per_layer_attn + mlp_params(self.d_ff))
