from .config import ModelConfig
from .model import (decode_step, encode_cross_kv, forward, init_decode_cache,
                    init_params, param_count, prefill, prefill_chunk,
                    verify_chunk)

__all__ = ["ModelConfig", "init_params", "forward", "prefill", "prefill_chunk",
           "decode_step", "encode_cross_kv", "init_decode_cache", "param_count",
           "verify_chunk"]
