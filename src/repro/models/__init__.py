from .config import ModelConfig
from .model import (decode_step, forward, init_decode_cache, init_params,
                    param_count, prefill)

__all__ = ["ModelConfig", "init_params", "forward", "prefill", "decode_step",
           "init_decode_cache", "param_count"]
