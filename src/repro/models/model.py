"""Model assembly: init / forward / prefill / decode for all six families.

Families: dense | moe | ssm | hybrid | encdec | vlm — all driven by one
ModelConfig. Layer stacks are *stacked pytrees* scanned with lax.scan so HLO
size and compile time are depth-independent (a 95-layer deepseek compiles
like one layer), and remat has a natural per-layer boundary.

Inputs (`batch` dicts):
  dense/moe/ssm/hybrid : {"tokens": (B, S) int32}
  encdec (whisper)     : {"tokens": (B, S), "frames": (B, encoder_seq, d)}  # stub frontend
  vlm (llava)          : {"tokens": (B, S - n_image_tokens),
                          "image_embeds": (B, n_image_tokens, vision_dim)}  # stub frontend
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from . import layers as L
from . import ssm as S

VISION_DIM = 1024  # stub vision-tower output width (llava)


def _cast(params, dtype):
    def c(a):
        return a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a
    return jax.tree.map(c, params)


def _id_constrain(x, kind):  # default no-op sharding hook
    return x


# ------------------------------------------------------------------ init ----


def _block_init(cfg: ModelConfig, key, *, moe: bool = False, cross: bool = False):
    ks = jax.random.split(key, 6)
    p = {
        "attn_norm": L.norm_init(cfg, cfg.d_model),
        "attn": L.mla_init(cfg, ks[0]) if cfg.use_mla else L.attn_init(cfg, ks[0]),
        "mlp_norm": L.norm_init(cfg, cfg.d_model),
    }
    if moe:
        p["moe"] = L.moe_init(cfg, ks[1])
    else:
        p["mlp"] = L.mlp_init(cfg, ks[1])
    if cross:
        p["cross_norm"] = L.norm_init(cfg, cfg.d_model)
        p["cross_attn"] = L.attn_init(cfg, ks[2])
    return p


def _stack_init(init_fn, key, n):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    p = {"embed": jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02,
         "final_norm": L.norm_init(cfg, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(ks[1], (cfg.d_model, cfg.vocab_size), jnp.float32) * 0.02

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _stack_init(lambda k: _block_init(cfg, k), ks[2], cfg.num_layers)
        if fam == "vlm":
            k1, k2 = jax.random.split(ks[3])
            p["mm_proj"] = {
                "w1": jax.random.normal(k1, (VISION_DIM, cfg.d_model), jnp.float32) * 0.02,
                "w2": jax.random.normal(k2, (cfg.d_model, cfg.d_model), jnp.float32) * 0.02,
            }
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            p["dense_layers"] = _stack_init(lambda k: _block_init(cfg, k), ks[2], nd)
        p["layers"] = _stack_init(lambda k: _block_init(cfg, k, moe=True), ks[3],
                                  cfg.num_layers - nd)
    elif fam == "ssm":
        def mb(k):
            return {"norm": L.norm_init(cfg, cfg.d_model), "mamba": S.mamba1_init(cfg, k)}
        p["layers"] = _stack_init(mb, ks[2], cfg.num_layers)
    elif fam == "hybrid":
        def mb(k):
            return {"norm": L.norm_init(cfg, cfg.d_model), "mamba": S.mamba2_init(cfg, k)}
        p["layers"] = _stack_init(mb, ks[2], cfg.num_layers)
        p["shared_blocks"] = _stack_init(lambda k: _block_init(cfg, k), ks[3],
                                         cfg.n_shared_attn_blocks)
        n_app = cfg.num_layers // cfg.attn_every
        p["lora"] = L.lora_init(cfg, ks[4], n_app)
    elif fam == "encdec":
        p["enc_layers"] = _stack_init(lambda k: _block_init(cfg, k), ks[2], cfg.n_encoder_layers)
        p["enc_final_norm"] = L.norm_init(cfg, cfg.d_model)
        p["dec_layers"] = _stack_init(lambda k: _block_init(cfg, k, cross=True), ks[3],
                                      cfg.num_layers)
        p["dec_pos"] = jax.random.normal(ks[4], (cfg.max_position, cfg.d_model), jnp.float32) * 0.02
    else:
        raise ValueError(fam)
    return p


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ----------------------------------------------------------- trunk fwd -----


def _dense_block(cfg, lp, x, positions, constrain, *, lora=None, causal=True):
    h = L.norm_apply(cfg, lp["attn_norm"], x)
    if cfg.use_mla:
        a, kv = L.mla_apply(cfg, lp["attn"], h, positions=positions)
    else:
        a, kv = L.attn_apply(cfg, lp["attn"], h, positions=positions, causal=causal, lora=lora)
    x = constrain(x + a, "hidden")
    h = L.norm_apply(cfg, lp["mlp_norm"], x)
    if "moe" in lp:
        m, aux = L.moe_apply(cfg, lp["moe"], h, return_aux=True, constrain=constrain)
    else:
        m, aux = L.mlp_apply(cfg, lp["mlp"], h), jnp.float32(0.0)
    return constrain(x + m, "hidden"), kv, aux


def _scan_blocks(cfg, stacked, x, positions, constrain, *, moe, remat, causal=True,
                 unroll=False):
    def body(carry, lp):
        h, aux = carry
        h, kv, a = _dense_block(cfg, lp, h, positions, constrain, causal=causal)
        return (h, aux + a), kv

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    (x, aux), kvs = lax.scan(fn, (x, jnp.float32(0.0)), stacked, unroll=unroll)
    return x, kvs, aux


def _ssm_block(cfg, lp, x, constrain):
    h = L.norm_apply(cfg, lp["norm"], x)
    if cfg.mamba_version == 2:
        y = S.mamba2_apply(cfg, lp["mamba"], h)
    else:
        y = S.mamba1_apply(cfg, lp["mamba"], h)
    return constrain(x + y, "hidden")


def _hybrid_trunk(cfg, p, x, positions, constrain, *, remat, unroll=False):
    """Zamba2: scan over super-blocks of (shared attn block + attn_every mamba)."""
    n_app = cfg.num_layers // cfg.attn_every
    stacked = jax.tree.map(
        lambda a: a.reshape((n_app, cfg.attn_every) + a.shape[1:]), p["layers"])

    def super_block(carry, inp):
        h, _ = carry
        i, mamba_stack, lora_i = inp
        shared = jax.tree.map(lambda a: a[i % cfg.n_shared_attn_blocks], p["shared_blocks"])
        h, _, _ = _dense_block(cfg, shared, h, positions, constrain, lora=lora_i)

        def mamba_body(hh, lp):
            return _ssm_block(cfg, lp, hh, constrain), None
        mb = jax.checkpoint(mamba_body, policy=jax.checkpoint_policies.nothing_saveable) if remat else mamba_body
        h, _ = lax.scan(mb, h, mamba_stack, unroll=unroll)
        return (h, jnp.float32(0.0)), None

    fn = jax.checkpoint(super_block, policy=jax.checkpoint_policies.nothing_saveable) if remat else super_block
    (x, _), _ = lax.scan(fn, (x, jnp.float32(0.0)),
                         (jnp.arange(n_app), stacked, p["lora"]), unroll=unroll)
    return x


def _encoder(cfg, p, frames, constrain, *, remat, unroll=False):
    x = frames + L.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)
    positions = jnp.arange(frames.shape[1])[None, :]

    def body(h, lp):
        hh = L.norm_apply(cfg, lp["attn_norm"], h)
        a, _ = L.attn_apply(cfg, lp["attn"], hh, positions=positions, causal=False)
        h = constrain(h + a, "hidden")
        hh = L.norm_apply(cfg, lp["mlp_norm"], h)
        return constrain(h + L.mlp_apply(cfg, lp["mlp"], hh), "hidden"), None

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    x, _ = lax.scan(fn, x, p["enc_layers"], unroll=unroll)
    return L.norm_apply(cfg, p["enc_final_norm"], x)


def _decoder_block(cfg, lp, x, positions, enc_out, constrain):
    h = L.norm_apply(cfg, lp["attn_norm"], x)
    a, kv = L.attn_apply(cfg, lp["attn"], h, positions=positions, causal=True)
    x = constrain(x + a, "hidden")
    h = L.norm_apply(cfg, lp["cross_norm"], x)
    ck = jnp.einsum("bsd,dhe->bshe", enc_out, lp["cross_attn"]["wk"])
    cv = jnp.einsum("bsd,dhe->bshe", enc_out, lp["cross_attn"]["wv"])
    a, _ = L.attn_apply(cfg, lp["cross_attn"], h, positions=positions, causal=False,
                        kv_override=(ck, cv))
    x = constrain(x + a, "hidden")
    h = L.norm_apply(cfg, lp["mlp_norm"], x)
    return constrain(x + L.mlp_apply(cfg, lp["mlp"], h), "hidden"), kv, (ck, cv)


# ------------------------------------------------------------- forward -----


def forward(cfg: ModelConfig, params, batch, *, remat=False, constrain=None,
            return_kv=False, unroll=False):
    """Full-sequence forward. Returns (logits, aux_loss) — logits (B, S, V)
    over *text* positions (vlm: image positions excluded)."""
    constrain = constrain or _id_constrain
    p = _cast(params, cfg.dtype)
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = jnp.take(p["embed"], tokens, axis=0)
    aux = jnp.float32(0.0)
    kvs = None
    n_img = 0

    if cfg.family == "vlm":
        img = batch["image_embeds"].astype(cfg.dtype)
        img = jax.nn.gelu(img @ p["mm_proj"]["w1"]) @ p["mm_proj"]["w2"]
        x = jnp.concatenate([img, x], axis=1)
        n_img = img.shape[1]
    S_total = x.shape[1]
    positions = jnp.arange(S_total)[None, :]
    x = constrain(x, "hidden")

    if cfg.family in ("dense", "vlm"):
        x, kvs, aux = _scan_blocks(cfg, p["layers"], x, positions, constrain,
                                   moe=False, remat=remat, unroll=unroll)
    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            x, _, _ = _scan_blocks(cfg, p["dense_layers"], x, positions, constrain,
                                   moe=False, remat=remat, unroll=unroll)
        x, kvs, aux = _scan_blocks(cfg, p["layers"], x, positions, constrain,
                                   moe=True, remat=remat, unroll=unroll)
    elif cfg.family == "ssm":
        def body(h, lp):
            return _ssm_block(cfg, lp, h, constrain), None
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
        x, _ = lax.scan(fn, x, p["layers"], unroll=unroll)
    elif cfg.family == "hybrid":
        x = _hybrid_trunk(cfg, p, x, positions, constrain, remat=remat, unroll=unroll)
    elif cfg.family == "encdec":
        enc_out = _encoder(cfg, p, batch["frames"].astype(cfg.dtype), constrain,
                           remat=remat, unroll=unroll)
        pos_emb = lax.dynamic_slice_in_dim(p["dec_pos"], 0, tokens.shape[1], axis=0)
        x = x + pos_emb[None]

        def body(h, lp):
            h, kv, ckv = _decoder_block(cfg, lp, h, positions, enc_out, constrain)
            return h, (kv, ckv)
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
        x, _ = lax.scan(fn, x, p["dec_layers"], unroll=unroll)

    x = L.norm_apply(cfg, p["final_norm"], x)
    if n_img:
        x = x[:, n_img:]
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = constrain(x @ head, "logits")
    return logits, aux


# ------------------------------------------------------------ caches -------


def init_decode_cache(cfg: ModelConfig, B: int, max_len: int, dtype=None):
    """Decode-state pytree sized for a cache of `max_len` tokens."""
    dt = dtype or jnp.dtype(cfg.dtype)
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    cache = {"pos": jnp.zeros((), jnp.int32)}
    fam = cfg.family
    if fam in ("dense", "vlm"):
        Lc = cfg.num_layers
        if cfg.use_mla:
            cache["ckv"] = jnp.zeros((Lc, B, max_len, cfg.kv_lora_rank), dt)
            cache["krope"] = jnp.zeros((Lc, B, max_len, cfg.qk_rope_dim), dt)
        else:
            cache["k"] = jnp.zeros((Lc, B, max_len, nkv, hd), dt)
            cache["v"] = jnp.zeros((Lc, B, max_len, nkv, hd), dt)
    elif fam == "moe":
        Lc = cfg.num_layers
        if cfg.use_mla:
            cache["ckv"] = jnp.zeros((Lc, B, max_len, cfg.kv_lora_rank), dt)
            cache["krope"] = jnp.zeros((Lc, B, max_len, cfg.qk_rope_dim), dt)
        else:
            cache["k"] = jnp.zeros((Lc, B, max_len, nkv, hd), dt)
            cache["v"] = jnp.zeros((Lc, B, max_len, nkv, hd), dt)
    elif fam == "ssm":
        di = cfg.d_inner
        cache["conv"] = jnp.zeros((cfg.num_layers, B, cfg.ssm_conv - 1, di), dt)
        cache["ssm"] = jnp.zeros((cfg.num_layers, B, di, cfg.ssm_state), jnp.float32)
    elif fam == "hybrid":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        n_app = cfg.num_layers // cfg.attn_every
        cache["conv"] = jnp.zeros((cfg.num_layers, B, cfg.ssm_conv - 1, conv_dim), dt)
        cache["ssm"] = jnp.zeros((cfg.num_layers, B, cfg.n_ssm_heads,
                                  cfg.mamba_headdim, cfg.ssm_state), jnp.float32)
        cache["k"] = jnp.zeros((n_app, B, max_len, nkv, hd), dt)
        cache["v"] = jnp.zeros((n_app, B, max_len, nkv, hd), dt)
    elif fam == "encdec":
        Lc = cfg.num_layers
        cache["k"] = jnp.zeros((Lc, B, max_len, nkv, hd), dt)
        cache["v"] = jnp.zeros((Lc, B, max_len, nkv, hd), dt)
        cache["ck"] = jnp.zeros((Lc, B, cfg.encoder_seq, nkv, hd), dt)
        cache["cv"] = jnp.zeros((Lc, B, cfg.encoder_seq, nkv, hd), dt)
    return cache


# ------------------------------------------------------------- decode ------


def decode_step(cfg: ModelConfig, params, token, cache, *, constrain=None,
                attn_impl=None, unroll=False):
    """One decode step. token: (B, 1) int32. Returns (logits (B,1,V), cache)."""
    constrain = constrain or _id_constrain
    p = _cast(params, cfg.dtype)
    pos = cache["pos"]
    x = jnp.take(p["embed"], token, axis=0)
    x = constrain(x, "hidden")
    fam = cfg.family
    new_cache = dict(cache)

    def attn_block(lp, h, kc, vc, lora=None, cross_kv=None):
        hh = L.norm_apply(cfg, lp["attn_norm"], h)
        a, (kc, vc) = L.attn_decode_apply(cfg, lp["attn"], hh, pos=pos, k_cache=kc,
                                          v_cache=vc, lora=lora, attn_impl=attn_impl)
        h = h + a
        if cross_kv is not None:
            hh = L.norm_apply(cfg, lp["cross_norm"], h)
            a, _ = L.attn_decode_apply(cfg, lp["cross_attn"], hh, pos=pos,
                                       k_cache=cross_kv[0], v_cache=cross_kv[1],
                                       cross=True, attn_impl=attn_impl)
            h = h + a
        hh = L.norm_apply(cfg, lp["mlp_norm"], h)
        if "moe" in lp:
            h = h + L.moe_apply(cfg, lp["moe"], hh, constrain=constrain)
        else:
            h = h + L.mlp_apply(cfg, lp["mlp"], hh)
        return h, kc, vc

    scan = lambda f, init, xs: lax.scan(f, init, xs, unroll=unroll)
    if fam in ("dense", "vlm", "moe"):
        if cfg.use_mla:
            def body(h, xs):
                lp, ckv, kr = xs
                hh = L.norm_apply(cfg, lp["attn_norm"], h)
                a, (ckv, kr) = L.mla_decode_apply(cfg, lp["attn"], hh, pos=pos,
                                                  ckv_cache=ckv, krope_cache=kr)
                h = h + a
                hh = L.norm_apply(cfg, lp["mlp_norm"], h)
                if "moe" in lp:
                    h = h + L.moe_apply(cfg, lp["moe"], hh)
                else:
                    h = h + L.mlp_apply(cfg, lp["mlp"], hh)
                return h, (ckv, kr)
            nd = cfg.first_dense_layers
            if fam == "moe" and nd:
                x, (ckv_d, kr_d) = scan(
                    body, x, (p["dense_layers"], cache["ckv"][:nd], cache["krope"][:nd]))
                x, (ckv_m, kr_m) = scan(
                    body, x, (p["layers"], cache["ckv"][nd:], cache["krope"][nd:]))
                new_cache["ckv"] = jnp.concatenate([ckv_d, ckv_m], axis=0)
                new_cache["krope"] = jnp.concatenate([kr_d, kr_m], axis=0)
            else:
                x, (ckv, kr) = scan(body, x, (p["layers"], cache["ckv"], cache["krope"]))
                new_cache["ckv"], new_cache["krope"] = ckv, kr
        else:
            def body(h, xs):
                lp, kc, vc = xs
                h, kc, vc = attn_block(lp, h, kc, vc)
                return h, (kc, vc)
            nd = cfg.first_dense_layers if fam == "moe" else 0
            if nd:
                x, (k_d, v_d) = scan(body, x, (p["dense_layers"], cache["k"][:nd], cache["v"][:nd]))
                x, (k_m, v_m) = scan(body, x, (p["layers"], cache["k"][nd:], cache["v"][nd:]))
                new_cache["k"] = jnp.concatenate([k_d, k_m], axis=0)
                new_cache["v"] = jnp.concatenate([v_d, v_m], axis=0)
            else:
                x, (k, v) = scan(body, x, (p["layers"], cache["k"], cache["v"]))
                new_cache["k"], new_cache["v"] = k, v
    elif fam == "ssm":
        def body(h, xs):
            lp, conv, st = xs
            hh = L.norm_apply(cfg, lp["norm"], h)
            y, conv, st = S.mamba1_decode(cfg, lp["mamba"], hh, conv_state=conv, ssm_state=st)
            return h + y, (conv, st)
        x, (conv, st) = scan(body, x, (p["layers"], cache["conv"], cache["ssm"]))
        new_cache["conv"], new_cache["ssm"] = conv, st
    elif fam == "hybrid":
        n_app = cfg.num_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_app, cfg.attn_every) + a.shape[1:]), p["layers"])
        conv_r = cache["conv"].reshape((n_app, cfg.attn_every) + cache["conv"].shape[1:])
        ssm_r = cache["ssm"].reshape((n_app, cfg.attn_every) + cache["ssm"].shape[1:])

        def super_body(h, xs):
            i, mstack, lora_i, kc, vc, conv_i, ssm_i = xs
            shared = jax.tree.map(lambda a: a[i % cfg.n_shared_attn_blocks], p["shared_blocks"])
            h, kc, vc = attn_block(shared, h, kc, vc, lora=lora_i)

            def mamba_body(hh, ys):
                lp, conv, st = ys
                hn = L.norm_apply(cfg, lp["norm"], hh)
                y, conv, st = S.mamba2_decode(cfg, lp["mamba"], hn, conv_state=conv, ssm_state=st)
                return hh + y, (conv, st)
            h, (conv_i, ssm_i) = scan(mamba_body, h, (mstack, conv_i, ssm_i))
            return h, (kc, vc, conv_i, ssm_i)

        x, (k, v, conv, st) = scan(
            super_body, x,
            (jnp.arange(n_app), stacked, p["lora"], cache["k"], cache["v"], conv_r, ssm_r))
        new_cache["k"], new_cache["v"] = k, v
        new_cache["conv"] = conv.reshape(cache["conv"].shape)
        new_cache["ssm"] = st.reshape(cache["ssm"].shape)
    elif fam == "encdec":
        posv = jnp.asarray(pos)
        if posv.ndim == 0:
            x = x + lax.dynamic_slice_in_dim(p["dec_pos"], pos, 1, axis=0)[None]
        else:
            x = x + jnp.take(p["dec_pos"], posv, axis=0)[:, None, :]

        def body(h, xs):
            lp, kc, vc, ck, cv = xs
            h, kc, vc = attn_block(lp, h, kc, vc, cross_kv=(ck, cv))
            return h, (kc, vc)
        x, (k, v) = scan(body, x, (p["dec_layers"], cache["k"], cache["v"],
                                       cache["ck"], cache["cv"]))
        new_cache["k"], new_cache["v"] = k, v

    x = L.norm_apply(cfg, p["final_norm"], x)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = constrain(x @ head, "logits")
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ------------------------------------------------------- chunked prefill ---


def prefill_chunk(cfg: ModelConfig, params, batch, cache, length=None, *,
                  constrain=None, unroll=False):
    """Advance a decode cache through a C-token prompt chunk.

    The chunk's tokens sit at positions [pos, pos+C) where `pos = cache["pos"]`
    (a scalar — chunked prefill is per-sequence); attention K/V is written at
    those positions and queries attend everything up to their own position,
    so feeding a prompt through successive chunks is exact for every family
    (SSM/conv state advances through the same recurrence decode uses, with
    the carried conv window prepended). `pos` may be traced: one jit
    signature per chunk *length* serves every offset.

    `length` (optional, traced): true token count when the chunk is
    right-padded to a fixed shape — with it, every chunk of a prompt reuses
    one jit signature. Padded positions write garbage K/V past the true end,
    which is harmless: later chunks/decode overwrite those positions before
    any query is allowed to attend them (position-gated masks), logits are
    taken at the last real position, and SSM/conv state is frozen past
    `length` (dt=0, conv tail sliced at the real boundary).

    batch: {"tokens": (B, C)}; vlm may add "image_embeds" on the first chunk
    (image tokens are prepended, count toward the cache position, and are
    always real — `length` counts text tokens only); encdec requires
    cache["ck"]/["cv"] already populated (see `encode_cross_kv`).
    Returns (last-position logits (B, 1, V), new cache).
    """
    constrain = constrain or _id_constrain
    p = _cast(params, cfg.dtype)
    pos = cache["pos"]
    tokens = batch["tokens"]
    x = jnp.take(p["embed"], tokens, axis=0)
    n_img = 0
    if cfg.family == "vlm" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(cfg.dtype)
        img = jax.nn.gelu(img @ p["mm_proj"]["w1"]) @ p["mm_proj"]["w2"]
        x = jnp.concatenate([img, x], axis=1)
        n_img = img.shape[1]
    C = x.shape[1]
    x = constrain(x, "hidden")
    start = pos
    fam = cfg.family
    new_cache = dict(cache)
    scan = lambda f, init, xs: lax.scan(f, init, xs, unroll=unroll)

    def attn_block(lp, h, kc, vc, lora=None, cross_kv=None):
        hh = L.norm_apply(cfg, lp["attn_norm"], h)
        a, (kc, vc) = L.attn_chunk_apply(cfg, lp["attn"], hh, start=start,
                                         k_cache=kc, v_cache=vc, lora=lora)
        h = h + a
        if cross_kv is not None:
            hh = L.norm_apply(cfg, lp["cross_norm"], h)
            a, _ = L.attn_chunk_apply(cfg, lp["cross_attn"], hh, start=start,
                                      k_cache=cross_kv[0], v_cache=cross_kv[1],
                                      cross=True)
            h = h + a
        hh = L.norm_apply(cfg, lp["mlp_norm"], h)
        if "moe" in lp:
            h = h + L.moe_apply(cfg, lp["moe"], hh, constrain=constrain)
        else:
            h = h + L.mlp_apply(cfg, lp["mlp"], hh)
        return h, kc, vc

    if fam in ("dense", "vlm", "moe"):
        if cfg.use_mla:
            def body(h, xs):
                lp, ckv, kr = xs
                hh = L.norm_apply(cfg, lp["attn_norm"], h)
                a, (ckv, kr) = L.mla_chunk_apply(cfg, lp["attn"], hh,
                                                 start=start, ckv_cache=ckv,
                                                 krope_cache=kr)
                h = h + a
                hh = L.norm_apply(cfg, lp["mlp_norm"], h)
                if "moe" in lp:
                    h = h + L.moe_apply(cfg, lp["moe"], hh)
                else:
                    h = h + L.mlp_apply(cfg, lp["mlp"], hh)
                return h, (ckv, kr)
            nd = cfg.first_dense_layers
            if fam == "moe" and nd:
                x, (ckv_d, kr_d) = scan(
                    body, x, (p["dense_layers"], cache["ckv"][:nd], cache["krope"][:nd]))
                x, (ckv_m, kr_m) = scan(
                    body, x, (p["layers"], cache["ckv"][nd:], cache["krope"][nd:]))
                new_cache["ckv"] = jnp.concatenate([ckv_d, ckv_m], axis=0)
                new_cache["krope"] = jnp.concatenate([kr_d, kr_m], axis=0)
            else:
                x, (ckv, kr) = scan(body, x, (p["layers"], cache["ckv"], cache["krope"]))
                new_cache["ckv"], new_cache["krope"] = ckv, kr
        else:
            def body(h, xs):
                lp, kc, vc = xs
                h, kc, vc = attn_block(lp, h, kc, vc)
                return h, (kc, vc)
            nd = cfg.first_dense_layers if fam == "moe" else 0
            if nd:
                x, (k_d, v_d) = scan(body, x, (p["dense_layers"], cache["k"][:nd], cache["v"][:nd]))
                x, (k_m, v_m) = scan(body, x, (p["layers"], cache["k"][nd:], cache["v"][nd:]))
                new_cache["k"] = jnp.concatenate([k_d, k_m], axis=0)
                new_cache["v"] = jnp.concatenate([v_d, v_m], axis=0)
            else:
                x, (k, v) = scan(body, x, (p["layers"], cache["k"], cache["v"]))
                new_cache["k"], new_cache["v"] = k, v
    elif fam == "ssm":
        def body(h, xs):
            lp, conv, st = xs
            hh = L.norm_apply(cfg, lp["norm"], h)
            y, conv, st = S.mamba1_chunk(cfg, lp["mamba"], hh,
                                         conv_state=conv, ssm_state=st,
                                         length=length)
            return h + y, (conv, st)
        x, (conv, st) = scan(body, x, (p["layers"], cache["conv"], cache["ssm"]))
        new_cache["conv"], new_cache["ssm"] = conv.astype(cache["conv"].dtype), st
    elif fam == "hybrid":
        n_app = cfg.num_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_app, cfg.attn_every) + a.shape[1:]), p["layers"])
        conv_r = cache["conv"].reshape((n_app, cfg.attn_every) + cache["conv"].shape[1:])
        ssm_r = cache["ssm"].reshape((n_app, cfg.attn_every) + cache["ssm"].shape[1:])

        def super_body(h, xs):
            i, mstack, lora_i, kc, vc, conv_i, ssm_i = xs
            shared = jax.tree.map(lambda a: a[i % cfg.n_shared_attn_blocks], p["shared_blocks"])
            h, kc, vc = attn_block(shared, h, kc, vc, lora=lora_i)

            def mamba_body(hh, ys):
                lp, conv, st = ys
                hn = L.norm_apply(cfg, lp["norm"], hh)
                y, conv, st = S.mamba2_chunk(cfg, lp["mamba"], hn,
                                             conv_state=conv, ssm_state=st,
                                             length=length)
                return hh + y, (conv, st)
            h, (conv_i, ssm_i) = scan(mamba_body, h, (mstack, conv_i, ssm_i))
            return h, (kc, vc, conv_i, ssm_i)

        x, (k, v, conv, st) = scan(
            super_body, x,
            (jnp.arange(n_app), stacked, p["lora"], cache["k"], cache["v"], conv_r, ssm_r))
        new_cache["k"], new_cache["v"] = k, v
        new_cache["conv"] = conv.reshape(cache["conv"].shape).astype(cache["conv"].dtype)
        new_cache["ssm"] = st.reshape(cache["ssm"].shape)
    elif fam == "encdec":
        # clipped take, not dynamic_slice: a padded chunk near the position
        # limit must never shift the real tokens' embeddings
        posv = jnp.clip(start + jnp.arange(C), 0, p["dec_pos"].shape[0] - 1)
        x = x + jnp.take(p["dec_pos"], posv, axis=0)[None]

        def body(h, xs):
            lp, kc, vc, ck, cv = xs
            h, kc, vc = attn_block(lp, h, kc, vc, cross_kv=(ck, cv))
            return h, (kc, vc)
        x, (k, v) = scan(body, x, (p["dec_layers"], cache["k"], cache["v"],
                                   cache["ck"], cache["cv"]))
        new_cache["k"], new_cache["v"] = k, v

    if length is None:
        x_last, adv = x[:, -1:], C
    else:
        x_last = lax.dynamic_slice_in_dim(x, n_img + length - 1, 1, axis=1)
        adv = n_img + length
    x = L.norm_apply(cfg, p["final_norm"], x_last)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = constrain(x @ head, "logits")
    new_cache["pos"] = pos + adv
    return logits, new_cache


def verify_chunk(cfg: ModelConfig, params, batch, cache, *, constrain=None,
                 unroll=False):
    """Speculative-decoding verification forward (DESIGN.md §14).

    Advances a decode cache through the C candidate tokens of a draft/verify
    round — the pending token plus the drafted continuation — and, unlike
    `prefill_chunk`, returns the logits at *every* position (the acceptance
    test needs the greedy target after each candidate) plus per-position
    state checkpoints so a rejected suffix can be rolled back exactly:

      attention KV  — written in place at [pos, pos+C); rollback is position
                      truncation (decode masks are pos-gated) plus the
                      engine's page scrub, so no checkpoint is needed;
      SSM/conv      — recurrent state cannot be truncated, so `ckpts` carries
                      "ssm" (layer_axis, B, C, ...): the scan state after
                      each position, and "conv" (layer_axis, B, K-1+C, ...):
                      the raw pre-conv input history including the carried
                      window — the state after keeping j tokens is
                      ckpts["ssm"][:, :, j-1] / ckpts["conv"][:, :, j:j+K-1].

    `cache["pos"]` may be a scalar or a per-row (B,) vector: the serving
    engine verifies all live slots in ONE batched forward, each row's chunk
    at its own decode position. Returns (logits (B, C, V), new_cache, ckpts).
    Rows are independent; callers discard rows/suffixes they reject.
    """
    constrain = constrain or _id_constrain
    p = _cast(params, cfg.dtype)
    pos = cache["pos"]
    tokens = batch["tokens"]
    x = jnp.take(p["embed"], tokens, axis=0)
    B, C = tokens.shape
    x = constrain(x, "hidden")
    start = pos
    fam = cfg.family
    new_cache = dict(cache)
    ckpts = {}
    scan = lambda f, init, xs: lax.scan(f, init, xs, unroll=unroll)

    def attn_block(lp, h, kc, vc, lora=None, cross_kv=None):
        hh = L.norm_apply(cfg, lp["attn_norm"], h)
        a, (kc, vc) = L.attn_chunk_apply(cfg, lp["attn"], hh, start=start,
                                         k_cache=kc, v_cache=vc, lora=lora)
        h = h + a
        if cross_kv is not None:
            hh = L.norm_apply(cfg, lp["cross_norm"], h)
            a, _ = L.attn_chunk_apply(cfg, lp["cross_attn"], hh, start=start,
                                      k_cache=cross_kv[0], v_cache=cross_kv[1],
                                      cross=True)
            h = h + a
        hh = L.norm_apply(cfg, lp["mlp_norm"], h)
        if "moe" in lp:
            h = h + L.moe_apply(cfg, lp["moe"], hh, constrain=constrain)
        else:
            h = h + L.mlp_apply(cfg, lp["mlp"], hh)
        return h, kc, vc

    if fam in ("dense", "vlm", "moe"):
        if cfg.use_mla:
            def body(h, xs):
                lp, ckv, kr = xs
                hh = L.norm_apply(cfg, lp["attn_norm"], h)
                a, (ckv, kr) = L.mla_chunk_apply(cfg, lp["attn"], hh,
                                                 start=start, ckv_cache=ckv,
                                                 krope_cache=kr)
                h = h + a
                hh = L.norm_apply(cfg, lp["mlp_norm"], h)
                if "moe" in lp:
                    h = h + L.moe_apply(cfg, lp["moe"], hh)
                else:
                    h = h + L.mlp_apply(cfg, lp["mlp"], hh)
                return h, (ckv, kr)
            nd = cfg.first_dense_layers
            if fam == "moe" and nd:
                x, (ckv_d, kr_d) = scan(
                    body, x, (p["dense_layers"], cache["ckv"][:nd], cache["krope"][:nd]))
                x, (ckv_m, kr_m) = scan(
                    body, x, (p["layers"], cache["ckv"][nd:], cache["krope"][nd:]))
                new_cache["ckv"] = jnp.concatenate([ckv_d, ckv_m], axis=0)
                new_cache["krope"] = jnp.concatenate([kr_d, kr_m], axis=0)
            else:
                x, (ckv, kr) = scan(body, x, (p["layers"], cache["ckv"], cache["krope"]))
                new_cache["ckv"], new_cache["krope"] = ckv, kr
        else:
            def body(h, xs):
                lp, kc, vc = xs
                h, kc, vc = attn_block(lp, h, kc, vc)
                return h, (kc, vc)
            nd = cfg.first_dense_layers if fam == "moe" else 0
            if nd:
                x, (k_d, v_d) = scan(body, x, (p["dense_layers"], cache["k"][:nd], cache["v"][:nd]))
                x, (k_m, v_m) = scan(body, x, (p["layers"], cache["k"][nd:], cache["v"][nd:]))
                new_cache["k"] = jnp.concatenate([k_d, k_m], axis=0)
                new_cache["v"] = jnp.concatenate([v_d, v_m], axis=0)
            else:
                x, (k, v) = scan(body, x, (p["layers"], cache["k"], cache["v"]))
                new_cache["k"], new_cache["v"] = k, v
    elif fam == "ssm":
        def body(h, xs):
            lp, conv, st = xs
            hh = L.norm_apply(cfg, lp["norm"], h)
            y, hist, hs = S.mamba1_chunk_states(cfg, lp["mamba"], hh,
                                                conv_state=conv, ssm_state=st)
            return h + y, (hist, hs)
        x, (hist, hs) = scan(body, x, (p["layers"], cache["conv"], cache["ssm"]))
        new_cache["conv"] = hist[:, :, C:].astype(cache["conv"].dtype)
        new_cache["ssm"] = hs[:, :, -1]
        ckpts = {"conv": hist, "ssm": hs}
    elif fam == "hybrid":
        n_app = cfg.num_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_app, cfg.attn_every) + a.shape[1:]), p["layers"])
        conv_r = cache["conv"].reshape((n_app, cfg.attn_every) + cache["conv"].shape[1:])
        ssm_r = cache["ssm"].reshape((n_app, cfg.attn_every) + cache["ssm"].shape[1:])

        def super_body(h, xs):
            i, mstack, lora_i, kc, vc, conv_i, ssm_i = xs
            shared = jax.tree.map(lambda a: a[i % cfg.n_shared_attn_blocks], p["shared_blocks"])
            h, kc, vc = attn_block(shared, h, kc, vc, lora=lora_i)

            def mamba_body(hh, ys):
                lp, conv, st = ys
                hn = L.norm_apply(cfg, lp["norm"], hh)
                y, hist, hst = S.mamba2_chunk_states(cfg, lp["mamba"], hn,
                                                     conv_state=conv,
                                                     ssm_state=st)
                return hh + y, (hist, hst)
            h, (hist_i, hs_i) = scan(mamba_body, h, (mstack, conv_i, ssm_i))
            return h, (kc, vc, hist_i, hs_i)

        x, (k, v, hist, hs) = scan(
            super_body, x,
            (jnp.arange(n_app), stacked, p["lora"], cache["k"], cache["v"],
             conv_r, ssm_r))
        new_cache["k"], new_cache["v"] = k, v
        hist = hist.reshape((cfg.num_layers,) + hist.shape[2:])
        hs = hs.reshape((cfg.num_layers,) + hs.shape[2:])
        new_cache["conv"] = hist[:, :, C:].astype(cache["conv"].dtype)
        new_cache["ssm"] = hs[:, :, -1]
        ckpts = {"conv": hist, "ssm": hs}
    elif fam == "encdec":
        posv = jnp.clip(L.chunk_positions(start, B, C), 0,
                        p["dec_pos"].shape[0] - 1)
        x = x + jnp.take(p["dec_pos"], posv, axis=0)

        def body(h, xs):
            lp, kc, vc, ck, cv = xs
            h, kc, vc = attn_block(lp, h, kc, vc, cross_kv=(ck, cv))
            return h, (kc, vc)
        x, (k, v) = scan(body, x, (p["dec_layers"], cache["k"], cache["v"],
                                   cache["ck"], cache["cv"]))
        new_cache["k"], new_cache["v"] = k, v

    x = L.norm_apply(cfg, p["final_norm"], x)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = constrain(x @ head, "logits")
    new_cache["pos"] = pos + C
    return logits, new_cache, ckpts


def encode_cross_kv(cfg: ModelConfig, params, frames, *, constrain=None,
                    unroll=False):
    """Run the encoder once and project per-decoder-layer cross K/V —
    the encdec prerequisite for `prefill_chunk` (full `prefill` computes
    these inside the decoder blocks). Returns (ck, cv), each
    (num_layers, B, encoder_seq, n_kv_heads, head_dim)."""
    constrain = constrain or _id_constrain
    p = _cast(params, cfg.dtype)
    enc_out = _encoder(cfg, p, frames.astype(cfg.dtype), constrain,
                       remat=False, unroll=unroll)

    def body(_, lp):
        ck = jnp.einsum("bsd,dhe->bshe", enc_out, lp["cross_attn"]["wk"])
        cv = jnp.einsum("bsd,dhe->bshe", enc_out, lp["cross_attn"]["wv"])
        return None, (ck, cv)
    _, (ck, cv) = lax.scan(body, None, p["dec_layers"], unroll=unroll)
    return ck, cv


# ------------------------------------------------------------- prefill -----


def prefill(cfg: ModelConfig, params, batch, max_len: int, length=None, *,
            constrain=None, remat=False, unroll=False):
    """Process the prompt, fill the cache, return last-position logits.

    Implemented as forward + KV collection for attention archs; for SSM archs
    the scan's final state is the cache.

    `length` (optional, traced): true token count when `batch["tokens"]` is
    right-padded to a bucketed shape — one jit signature then serves every
    prompt length in the bucket. Exactness is preserved: logits are taken at
    the last *real* position, `cache["pos"]` gates attention so padded K/V
    is never attended, and SSM/conv state is frozen past `length` (padded
    positions get dt=0, the conv tail is sliced at the real boundary).
    """
    constrain = constrain or _id_constrain
    p = _cast(params, cfg.dtype)
    tokens = batch["tokens"]
    B = tokens.shape[0]
    cache = init_decode_cache(cfg, B, max_len)
    x = jnp.take(p["embed"], tokens, axis=0)
    n_img = 0
    if cfg.family == "vlm":
        img = batch["image_embeds"].astype(cfg.dtype)
        img = jax.nn.gelu(img @ p["mm_proj"]["w1"]) @ p["mm_proj"]["w2"]
        x = jnp.concatenate([img, x], axis=1)
        n_img = img.shape[1]
    S_in = x.shape[1]
    positions = jnp.arange(S_in)[None, :]
    x = constrain(x, "hidden")

    def pad_to_cache(arr):  # (L?, B, S, ...) -> (..., max_len, ...) on axis=2
        assert arr.shape[2] <= max_len, (
            f"prompt ({arr.shape[2]} incl. image/frame tokens) exceeds cache max_len={max_len}")
        pad = [(0, 0)] * arr.ndim
        pad[2] = (0, max_len - arr.shape[2])
        return jnp.pad(arr, pad)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        x, kvs, _ = _scan_blocks(cfg, p["layers"], x, positions, constrain,
                                 moe=False, remat=remat, unroll=unroll)
        if cfg.use_mla:
            cache["ckv"] = pad_to_cache(kvs[0].astype(cache["ckv"].dtype))
            cache["krope"] = pad_to_cache(kvs[1].astype(cache["krope"].dtype))
        else:
            cache["k"] = pad_to_cache(kvs[0].astype(cache["k"].dtype))
            cache["v"] = pad_to_cache(kvs[1].astype(cache["v"].dtype))
    elif fam == "moe":
        parts_k, parts_v = [], []
        if cfg.first_dense_layers:
            x, kvs, _ = _scan_blocks(cfg, p["dense_layers"], x, positions, constrain,
                                     moe=False, remat=remat, unroll=unroll)
            parts_k.append(kvs[0]); parts_v.append(kvs[1])
        x, kvs, _ = _scan_blocks(cfg, p["layers"], x, positions, constrain,
                                 moe=True, remat=remat, unroll=unroll)
        parts_k.append(kvs[0]); parts_v.append(kvs[1])
        k = jnp.concatenate(parts_k, 0) if len(parts_k) > 1 else parts_k[0]
        v = jnp.concatenate(parts_v, 0) if len(parts_v) > 1 else parts_v[0]
        if cfg.use_mla:
            cache["ckv"] = pad_to_cache(k.astype(cache["ckv"].dtype))
            cache["krope"] = pad_to_cache(v.astype(cache["krope"].dtype))
        else:
            cache["k"] = pad_to_cache(k.astype(cache["k"].dtype))
            cache["v"] = pad_to_cache(v.astype(cache["v"].dtype))
    elif fam == "ssm":
        def body(carry, lp):
            h = carry
            hh = L.norm_apply(cfg, lp["norm"], h)
            x_in, z = S._mamba1_ssm_inputs(cfg, lp["mamba"], hh)
            xc = jax.nn.silu(S.causal_depthwise_conv(x_in, lp["mamba"]["conv_w"], lp["mamba"]["conv_b"]))
            dt, A, B_m, C_m = S._mamba1_scan_params(cfg, lp["mamba"], xc)
            if length is not None:
                # dt=0 on padded positions: decay 1, contribution 0 — the
                # recurrent state is exactly the state at `length`.
                dt = dt * (jnp.arange(S_in)[None, :, None] < length)
            y, hfin = S.mamba1_scan_ref(xc, dt, A, B_m, C_m, lp["mamba"]["D"])
            out = (y * jax.nn.silu(z)) @ lp["mamba"]["out_proj"]
            # zero left-pad so a prompt shorter than the conv window gets
            # real zero history, not a short/misaligned window
            hist = jnp.pad(x_in, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
            if length is None:
                conv_tail = hist[:, S_in:, :]
            else:
                conv_tail = lax.dynamic_slice_in_dim(
                    hist, length, cfg.ssm_conv - 1, axis=1)
            return h + out, (conv_tail, hfin)
        x, (conv, st) = lax.scan(body, x, p["layers"], unroll=unroll)
        cache["conv"] = conv.astype(cache["conv"].dtype)
        cache["ssm"] = st
    elif fam == "hybrid":
        n_app = cfg.num_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_app, cfg.attn_every) + a.shape[1:]), p["layers"])

        def super_body(carry, xs):
            h = carry
            i, mstack, lora_i = xs
            shared = jax.tree.map(lambda a: a[i % cfg.n_shared_attn_blocks], p["shared_blocks"])
            h, kv, _ = _dense_block(cfg, shared, h, positions, constrain, lora=lora_i)

            def mamba_body(hh, lp):
                hn = L.norm_apply(cfg, lp["norm"], hh)
                zz, xbc_raw, dt_raw = S._mamba2_proj(cfg, lp["mamba"], hn)
                xbc = jax.nn.silu(S.causal_depthwise_conv(xbc_raw, lp["mamba"]["conv_w"], lp["mamba"]["conv_b"]))
                di, N = cfg.d_inner, cfg.ssm_state
                x_i, B_m, C_m = xbc[..., :di], xbc[..., di:di + N], xbc[..., di + N:]
                dt = jax.nn.softplus(dt_raw + lp["mamba"]["dt_bias"])
                if length is not None:
                    dt = dt * (jnp.arange(S_in)[None, :, None] < length)
                A = -jnp.exp(lp["mamba"]["A_log"].astype(jnp.float32))
                Bsz, S_len = x_i.shape[0], x_i.shape[1]
                y, hfin = S.mamba2_ssd_ref(
                    x_i.reshape(Bsz, S_len, cfg.n_ssm_heads, cfg.mamba_headdim),
                    dt, A, B_m, C_m, lp["mamba"]["D"], chunk=cfg.ssm_chunk)
                y = y.reshape(Bsz, S_len, di)
                y = L.rms_norm(y * jax.nn.silu(zz), lp["mamba"]["norm_w"], cfg.norm_eps)
                # raw pre-conv inputs, zero-padded history (see ssm branch)
                hist = jnp.pad(xbc_raw,
                               ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
                if length is None:
                    conv_tail = hist[:, S_in:, :]
                else:
                    conv_tail = lax.dynamic_slice_in_dim(
                        hist, length, cfg.ssm_conv - 1, axis=1)
                return hh + y @ lp["mamba"]["out_proj"], (conv_tail, hfin)

            h, (conv_i, ssm_i) = lax.scan(mamba_body, h, mstack, unroll=unroll)
            return h, (kv[0], kv[1], conv_i, ssm_i)

        x, (k, v, conv, st) = lax.scan(super_body, x,
                                       (jnp.arange(n_app), stacked, p["lora"]), unroll=unroll)
        cache["k"] = pad_to_cache(k.astype(cache["k"].dtype))
        cache["v"] = pad_to_cache(v.astype(cache["v"].dtype))
        cache["conv"] = conv.reshape(cache["conv"].shape).astype(cache["conv"].dtype)
        cache["ssm"] = st.reshape(cache["ssm"].shape)
    elif fam == "encdec":
        enc_out = _encoder(cfg, p, batch["frames"].astype(cfg.dtype), constrain, remat=remat, unroll=unroll)
        pos_emb = lax.dynamic_slice_in_dim(p["dec_pos"], 0, tokens.shape[1], axis=0)
        x = x + pos_emb[None]

        def body(h, lp):
            h, kv, ckv = _decoder_block(cfg, lp, h, positions, enc_out, constrain)
            return h, (kv, ckv)
        x, (kvs, ckvs) = lax.scan(body, x, p["dec_layers"], unroll=unroll)
        cache["k"] = pad_to_cache(kvs[0].astype(cache["k"].dtype))
        cache["v"] = pad_to_cache(kvs[1].astype(cache["v"].dtype))
        cache["ck"] = ckvs[0].astype(cache["ck"].dtype)
        cache["cv"] = ckvs[1].astype(cache["cv"].dtype)

    if length is None:
        x_last, true_len = x[:, -1:], S_in
    else:
        x_last = lax.dynamic_slice_in_dim(x, n_img + length - 1, 1, axis=1)
        true_len = n_img + length
    x = L.norm_apply(cfg, p["final_norm"], x_last)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = constrain(x @ head, "logits")
    cache["pos"] = jnp.asarray(true_len, jnp.int32)
    return logits, cache
