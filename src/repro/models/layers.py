"""Core neural layers shared by all architectures (pure-JAX, pytree params).

Everything here is a pure function: ``init_*`` builds a param pytree,
``*_apply`` consumes it. No framework dependency (flax/optax absent in this
container by design) — params are plain nested dicts of jnp arrays, which
keeps pjit/shard_map sharding specs trivial to express.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig

# ---------------------------------------------------------------- norms ----


def rms_norm(x, w, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layer_norm(x, w, b, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_init(cfg: ModelConfig, dim: int):
    if cfg.use_layernorm:
        return {"w": jnp.ones((dim,), jnp.float32), "b": jnp.zeros((dim,), jnp.float32)}
    return {"w": jnp.ones((dim,), jnp.float32)}


def norm_apply(cfg: ModelConfig, p, x):
    if cfg.use_layernorm:
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


# ----------------------------------------------------------------- rope ----


def rope_freqs(hd: int, theta: float):
    return theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) (hd even); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------------------------------------------ activation ----


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


# ------------------------------------------------------------------ mlp ----


def mlp_init(cfg: ModelConfig, key, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    std = 0.02
    p = {"w_down": jax.random.normal(k2, (ff, d), jnp.float32) * std / math.sqrt(2 * cfg.num_layers)}
    if cfg.gated_mlp:
        p["w_gate"] = jax.random.normal(k1, (d, ff), jnp.float32) * std
        p["w_up"] = jax.random.normal(k3, (d, ff), jnp.float32) * std
    else:
        p["w_in"] = jax.random.normal(k1, (d, ff), jnp.float32) * std
    return p


def mlp_apply(cfg: ModelConfig, p, x):
    act = activation_fn(cfg.activation)
    if cfg.gated_mlp:
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = act(x @ p["w_in"])
    return h @ p["w_down"]


# ------------------------------------------------------------ attention ----
# q is grouped for GQA: (B, S, Hkv, G, hd); k/v: (B, S, Hkv, hd).

_ATTN_OVERRIDE = None  # None | "dense" | "blockwise"  (roofline probes)


def set_attention_impl(mode):
    global _ATTN_OVERRIDE
    assert mode in (None, "dense", "blockwise"), mode
    _ATTN_OVERRIDE = mode


def _mask_bias(q_pos, kv_pos, causal: bool, kv_len=None):
    """Additive fp32 mask bias of shape (Sq, Skv)."""
    ok = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        ok &= kv_pos[None, :] <= q_pos[:, None]
    if kv_len is not None:
        ok &= kv_pos[None, :] < kv_len
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def sdpa(q, k, v, *, causal: bool, q_offset=0, kv_len=None, scale=None):
    """Dense grouped attention. q: (B,Sq,Hkv,G,hd); k,v: (B,Skv,Hkv,hd)."""
    B, Sq, Hkv, G, hd = q.shape
    Skv = k.shape[1]
    scale = scale or hd ** -0.5
    s = jnp.einsum("bqhgd,bshd->bhgqs", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    s = s + _mask_bias(q_pos, kv_pos, causal, kv_len)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v.dtype), v)
    return out


def blockwise_attention(q, k, v, *, causal: bool, q_block=512, kv_block=1024,
                        q_offset=0, scale=None):
    """Flash-style online-softmax attention in pure jnp (XLA path).

    Memory O(q_block*kv_block) instead of O(Sq*Skv); numerically identical to
    `sdpa`. This is the math the `flash_attention` Pallas kernel implements
    with VMEM tiles on real TPU; here it bounds the dry-run working set.
    """
    B, Sq, Hkv, G, hd = q.shape
    Skv = k.shape[1]
    vd = v.shape[-1]                       # may differ from hd (MLA)
    scale = scale or hd ** -0.5

    def pick_block(n, pref):
        if n <= pref:
            return n
        for cand in range(pref, 0, -1):    # largest divisor <= pref
            if n % cand == 0:
                return cand
        return n

    q_block = pick_block(Sq, min(q_block, Sq))
    kv_block = pick_block(Skv, min(kv_block, Skv))
    nq, nk = Sq // q_block, Skv // kv_block

    def one_q_block(qi):
        qb = lax.dynamic_slice_in_dim(q, qi * q_block, q_block, axis=1)
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_step(carry, ki):
            m, l, acc = carry
            kb = lax.dynamic_slice_in_dim(k, ki * kv_block, kv_block, axis=1)
            vb = lax.dynamic_slice_in_dim(v, ki * kv_block, kv_block, axis=1)
            s = jnp.einsum("bqhgd,bshd->bhgqs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            kv_pos = ki * kv_block + jnp.arange(kv_block)
            s = s + _mask_bias(q_pos, kv_pos, causal, None)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(v.dtype), vb)
            acc = acc * alpha[..., None].astype(acc.dtype) + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, vd), v.dtype)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return jnp.moveaxis(out, 3, 1)  # (B, q_block, Hkv, G, vd)

    outs = lax.map(one_q_block, jnp.arange(nq))          # (nq, B, qb, ...)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hkv, G, vd)
    return out


def decode_attention(q, k_cache, v_cache, cache_len, *, scale=None):
    """One-token attention against a (possibly padded) cache.

    q: (B, 1, Hkv, G, hd); caches: (B, Smax, Hkv, hd); cache_len: scalar or (B,).
    """
    hd = q.shape[-1]
    scale = scale or hd ** -0.5
    s = jnp.einsum("bqhgd,bshd->bhgqs", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    kv_pos = jnp.arange(k_cache.shape[1])
    length = jnp.asarray(cache_len)
    if length.ndim == 0:
        ok = kv_pos < length
        s = jnp.where(ok[None, None, None, None, :], s, -jnp.inf)
    else:
        ok = kv_pos[None, :] < length[:, None]
        s = jnp.where(ok[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v_cache.dtype), v_cache)
    return out


# Self-attention module (GQA, optional bias / qk-norm / rope / LoRA delta).


def attn_init(cfg: ModelConfig, key, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    std = 0.02
    p = {
        "wq": jax.random.normal(ks[0], (d, nq, hd), jnp.float32) * std,
        "wk": jax.random.normal(ks[1], (d, nkv, hd), jnp.float32) * std,
        "wv": jax.random.normal(ks[2], (d, nkv, hd), jnp.float32) * std,
        "wo": jax.random.normal(ks[3], (nq, hd, d), jnp.float32) * std / math.sqrt(2 * cfg.num_layers),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((nq, hd), jnp.float32)
        p["bk"] = jnp.zeros((nkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((nkv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def lora_init(cfg: ModelConfig, key, n_app: int):
    """Stacked per-application LoRA deltas for the zamba2 shared block."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv, r = cfg.n_heads, cfg.n_kv_heads, cfg.shared_lora_rank
    ks = jax.random.split(key, 8)
    z = lambda k, shape: jax.random.normal(k, shape, jnp.float32) * 0.02
    return {
        "a_q": z(ks[0], (n_app, d, r)), "b_q": jnp.zeros((n_app, r, nq * hd)),
        "a_k": z(ks[1], (n_app, d, r)), "b_k": jnp.zeros((n_app, r, nkv * hd)),
        "a_v": z(ks[2], (n_app, d, r)), "b_v": jnp.zeros((n_app, r, nkv * hd)),
        "a_o": z(ks[3], (n_app, d, r)), "b_o": jnp.zeros((n_app, r, d)),
    }


def _project_qkv(cfg: ModelConfig, p, x, lora=None):
    B, S, d = x.shape
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if lora is not None:
        q = q + ((x @ lora["a_q"]) @ lora["b_q"]).reshape(B, S, nq, hd)
        k = k + ((x @ lora["a_k"]) @ lora["b_k"]).reshape(B, S, nkv, hd)
        v = v + ((x @ lora["a_v"]) @ lora["b_v"]).reshape(B, S, nkv, hd)
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_apply(cfg: ModelConfig, p, x, *, positions, causal=True, lora=None,
               kv_override=None, block_threshold=8192):
    """Full-sequence self-attention (train / prefill). Returns (out, (k, v)).

    kv_override: (k, v) for cross-attention (already projected+rotated).
    """
    B, S, _ = x.shape
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if kv_override is None:
        q, k, v = _project_qkv(cfg, p, x, lora)
        if cfg.use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
        if cfg.attn_bias:
            q = q + p["bq"]
        k, v = kv_override
    G = nq // nkv
    qg = q.reshape(B, S, nkv, G, hd)
    dense = S * k.shape[1] <= block_threshold * block_threshold // 16 or S <= 2048
    if _ATTN_OVERRIDE is not None:
        dense = _ATTN_OVERRIDE == "dense"
    if dense:
        out = sdpa(qg, k, v, causal=causal)
    else:
        out = blockwise_attention(qg, k, v, causal=causal)
    out = out.reshape(B, S, nq, hd)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    if lora is not None:
        flat = out  # LoRA on output proj applied to attention output
        out = out + (flat @ lora["a_o"]) @ lora["b_o"]
    return out, (k, v)


def cache_write(cache, new, pos):
    """Write one token's K/V at `pos` (scalar) or per-row positions ((B,))."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype),
                                               pos, axis=1)
    B = cache.shape[0]
    return cache.at[jnp.arange(B), pos].set(new[:, 0].astype(cache.dtype))


def cache_write_chunk(cache, new, start):
    """Write a C-token chunk's K/V at positions [start, start+C). `start` is
    a scalar (chunked prefill is per-sequence: every row shares the offset)
    or a per-row (B,) vector (batched speculative verification: each row's
    chunk lands at its own decode position)."""
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        return lax.dynamic_update_slice(
            cache, new.astype(cache.dtype),
            (0, start) + (0,) * (cache.ndim - 2))

    C = new.shape[1]

    def one_row(c_row, n_row, s):               # (Smax, ...), (C, ...)
        # scatter with OOB *drop*, not dynamic_update_slice: a verify chunk
        # is fixed-width, so a row near the cache bound would otherwise have
        # its start clamped backward, silently overwriting valid earlier KV.
        # Real (acceptable) candidates are always in-bounds — only padding
        # positions ever fall past the end, and those must vanish.
        return c_row.at[s + jnp.arange(C)].set(n_row.astype(c_row.dtype),
                                               mode="drop")
    return jax.vmap(one_row)(cache, new, start)


def chunk_positions(start, B: int, C: int):
    """(B, C) query positions for a chunk at `start` (scalar or (B,))."""
    start = jnp.asarray(start, jnp.int32)
    pos = jnp.reshape(start, (-1, 1)) + jnp.arange(C, dtype=jnp.int32)
    return jnp.broadcast_to(pos, (B, C))


def attn_chunk_apply(cfg: ModelConfig, p, x, *, start, k_cache, v_cache,
                     lora=None, cross=False):
    """Chunked-prefill attention: C query tokens at positions
    [start, start+C) attend the cache up to their own position (causal
    within the chunk, full over the already-filled prefix). Generalizes
    `attn_decode_apply` from C=1; `start` may be traced, so one jit
    signature serves every chunk offset.

    x: (B, C, d). Caches (B, Smax, Hkv, hd). Returns (out, (k_cache, v_cache))
    with the chunk's K/V written into the caches (cross: caches untouched).
    `start` may also be a per-row (B,) vector (batched speculative
    verification: every row's chunk sits at its own decode position).
    """
    B, C, _ = x.shape
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q_pos = chunk_positions(start, B, C)                      # (B, C)
    if cross:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
        if cfg.attn_bias:
            q = q + p["bq"]
    else:
        q, k, v = _project_qkv(cfg, p, x, lora)
        if cfg.use_rope:
            q = apply_rope(q, q_pos, cfg.rope_theta)
            k = apply_rope(k, q_pos, cfg.rope_theta)
        k_cache = cache_write_chunk(k_cache, k, start)
        v_cache = cache_write_chunk(v_cache, v, start)
    qg = q.reshape(B, C, nkv, nq // nkv, hd)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    kv_pos = jnp.arange(k_cache.shape[1])
    ok = (kv_pos[None, None, :] <= q_pos[:, :, None]) if not cross else \
        jnp.ones((B, C, k_cache.shape[1]), bool)
    s = jnp.where(ok[:, None, None, :, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", pr.astype(v_cache.dtype), v_cache)
    out = out.reshape(B, C, nq, hd)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    if lora is not None:
        out = out + (out @ lora["a_o"]) @ lora["b_o"]
    return out, (k_cache, v_cache) if not cross else (None, None)


def attn_decode_apply(cfg: ModelConfig, p, x, *, pos, k_cache, v_cache, lora=None,
                      cross=False, cache_len=None, attn_impl=None):
    """Single-token decode. x: (B, 1, d). Caches (B, Smax, Hkv, hd).
    `pos` may be a scalar or a per-row (B,) vector (continuous batching).

    Returns (out, (k_new, v_new)) — k_new/v_new are this step's projections
    (None for cross-attention); caller owns the cache update.
    """
    B, S, _ = x.shape
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if cross:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
        if cfg.attn_bias:
            q = q + p["bq"]
        k_new = v_new = None
        length = k_cache.shape[1] if cache_len is None else cache_len
    else:
        q, k, v = _project_qkv(cfg, p, x, lora)
        if cfg.use_rope:
            pp = jnp.broadcast_to(jnp.asarray(pos).reshape(-1, 1) if jnp.asarray(pos).ndim
                                  else jnp.full((B, S), pos), (B, S))
            q = apply_rope(q, pp, cfg.rope_theta)
            k = apply_rope(k, pp, cfg.rope_theta)
        k_new, v_new = k, v
        k_cache = cache_write(k_cache, k, pos)
        v_cache = cache_write(v_cache, v, pos)
        length = pos + 1
    qg = q.reshape(B, S, nkv, nq // nkv, hd)
    impl = attn_impl or decode_attention
    out = impl(qg, k_cache, v_cache, length)
    out = out.reshape(B, S, nq, hd)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    if lora is not None:
        out = out + (out @ lora["a_o"]) @ lora["b_o"]
    return out, (k_cache, v_cache) if not cross else (None, None)


# ---------------------------------------------------------------- MLA ------


def mla_init(cfg: ModelConfig, key):
    d, H = cfg.d_model, cfg.n_heads
    r, nd, rd, vd = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    std = 0.02
    return {
        "wq": jax.random.normal(ks[0], (d, H, nd + rd), jnp.float32) * std,
        "w_dkv": jax.random.normal(ks[1], (d, r + rd), jnp.float32) * std,
        "w_uk": jax.random.normal(ks[2], (r, H, nd), jnp.float32) * std,
        "w_uv": jax.random.normal(ks[3], (r, H, vd), jnp.float32) * std,
        "wo": jax.random.normal(ks[4], (H, vd, d), jnp.float32) * std / math.sqrt(2 * cfg.num_layers),
        "kv_norm": jnp.ones((r,), jnp.float32),
    }


def mla_project(cfg: ModelConfig, p, x, positions):
    """Shared q / compressed-kv projections. Returns q_nope,q_rope,c_kv,k_rope."""
    nd, rd = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    dkv = x @ p["w_dkv"]
    c_kv, k_rope = dkv[..., : cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(cfg: ModelConfig, p, x, *, positions):
    """Full-sequence MLA (train/prefill). Returns (out, (c_kv, k_rope))."""
    B, S, _ = x.shape
    H, vd = cfg.n_heads, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = mla_project(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, cfg.qk_rope_dim))], axis=-1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    qg = q[:, :, :, None, :]
    dense = S <= 2048
    if _ATTN_OVERRIDE is not None:
        dense = _ATTN_OVERRIDE == "dense"
    if dense:
        out = sdpa(qg, k, v, causal=True, scale=scale)
    else:
        out = blockwise_attention(qg, k, v, causal=True, scale=scale)
    out = out.reshape(B, S, H, vd)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return out, (c_kv, k_rope)


def mla_decode_apply(cfg: ModelConfig, p, x, *, pos, ckv_cache, krope_cache):
    """Absorbed-matmul MLA decode (DeepSeek-V2's own optimization): the
    per-head K/V up-projections fold into the query/context sides so the
    cache stays compressed (r + rope_dim per token). `pos` scalar or (B,)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    pp = jnp.broadcast_to(jnp.asarray(pos).reshape(-1, 1) if jnp.asarray(pos).ndim
                          else jnp.full((B, S), pos), (B, S))
    q_nope, q_rope, c_kv, k_rope = mla_project(cfg, p, x, pp)
    ckv_cache = cache_write(ckv_cache, c_kv, pos)
    krope_cache = cache_write(krope_cache, k_rope, pos)
    q_abs = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])          # (B,1,H,r)
    s = jnp.einsum("bshr,btr->bhst", q_abs, ckv_cache, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bshe,bte->bhst", q_rope, krope_cache, preferred_element_type=jnp.float32)
    s = s * (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    posv = jnp.asarray(pos)
    if posv.ndim == 0:
        ok = jnp.arange(ckv_cache.shape[1])[None] <= posv
    else:
        ok = jnp.arange(ckv_cache.shape[1])[None, :] <= posv[:, None]
    s = jnp.where(ok[:, None, None, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", pr.astype(ckv_cache.dtype), ckv_cache)
    out = jnp.einsum("bshr,rhe->bshe", ctx, p["w_uv"])
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return out, (ckv_cache, krope_cache)


def mla_chunk_apply(cfg: ModelConfig, p, x, *, start, ckv_cache, krope_cache):
    """Chunked-prefill MLA (absorbed form, same math as `mla_decode_apply`
    with C query tokens): the chunk's compressed KV is written at
    [start, start+C) and queries attend the cache up to their own position.
    `start` may be a scalar or a per-row (B,) vector (batched verify)."""
    B, C, _ = x.shape
    q_pos = chunk_positions(start, B, C)                      # (B, C)
    q_nope, q_rope, c_kv, k_rope = mla_project(cfg, p, x, q_pos)
    ckv_cache = cache_write_chunk(ckv_cache, c_kv, start)
    krope_cache = cache_write_chunk(krope_cache, k_rope, start)
    q_abs = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])
    s = jnp.einsum("bshr,btr->bhst", q_abs, ckv_cache,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bshe,bte->bhst", q_rope, krope_cache,
                       preferred_element_type=jnp.float32)
    s = s * (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    ok = jnp.arange(ckv_cache.shape[1])[None, None, :] <= q_pos[:, :, None]
    s = jnp.where(ok[:, None, :, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", pr.astype(ckv_cache.dtype), ckv_cache)
    out = jnp.einsum("bshr,rhe->bshe", ctx, p["w_uv"])
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return out, (ckv_cache, krope_cache)


# ---------------------------------------------------------------- MoE ------

_MOE_GROUPS = 0  # >1: grouped-local dispatch (expert-parallel layouts)


def set_moe_groups(g):
    global _MOE_GROUPS
    _MOE_GROUPS = int(g)


def moe_init(cfg: ModelConfig, key):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    std = 0.02
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * std,
        "w_gate": jax.random.normal(ks[1], (E, d, ff), jnp.float32) * std,
        "w_up": jax.random.normal(ks[2], (E, d, ff), jnp.float32) * std,
        "w_down": jax.random.normal(ks[3], (E, ff, d), jnp.float32) * std / math.sqrt(2 * cfg.num_layers),
    }
    if cfg.n_shared_experts:
        sh_ff = ff * cfg.n_shared_experts
        sub = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": jax.random.normal(sub[0], (d, sh_ff), jnp.float32) * std,
            "w_up": jax.random.normal(sub[1], (d, sh_ff), jnp.float32) * std,
            "w_down": jax.random.normal(sub[2], (sh_ff, d), jnp.float32) * std / math.sqrt(2 * cfg.num_layers),
        }
    return p


def moe_capacity(cfg: ModelConfig, T: int) -> int:
    C = int(math.ceil(cfg.capacity_factor * cfg.moe_top_k * T / cfg.n_experts))
    return max(8, -(-C // 8) * 8)  # round up to multiple of 8


def _moe_dispatch_group(cfg: ModelConfig, p, x2, C):
    """Dispatch+compute+combine for one token group (no cross-group refs:
    under a (groups=data-shards) reshape every index op stays shard-local)."""
    T, d = x2.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    logits = (x2 @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    flat_e = top_i.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = top_w.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)
    xe = jnp.zeros((E * C + 1, d), x2.dtype).at[slot].set(x2[flat_t])
    xe = xe[: E * C].reshape(E, C, d)
    act = activation_fn(cfg.activation)
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) *         jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    ye_flat = jnp.concatenate([ye.reshape(E * C, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    back = ye_flat[slot] * (flat_w * keep)[:, None].astype(ye.dtype)
    return jnp.zeros((T, d), x2.dtype).at[flat_t].add(back)


def moe_apply(cfg: ModelConfig, p, x, *, return_aux=False, constrain=None):
    """Capacity-based top-k MoE with gather/scatter dispatch (no giant one-hot
    einsums). x: (B, S, d). Tokens over capacity are dropped (GShard-style).

    `constrain(x, kind)` hook: under expert parallelism the launcher pins
    the dispatch buffer to P(data, None, None) (experts sharded over data) so
    the scatter becomes a token all-to-all instead of index all-gathers."""
    B, S, d = x.shape
    if _MOE_GROUPS > 1 and (B * S) % _MOE_GROUPS == 0:
        G = _MOE_GROUPS
        xg = x.reshape(G, B * S // G, d)
        C_g = moe_capacity(cfg, B * S // G)
        y = jax.vmap(lambda xx: _moe_dispatch_group(cfg, p, xx, C_g))(xg)
        if constrain is not None:
            y = constrain(y, "moe_grouped")
        y = y.reshape(B * S, d)
        if cfg.n_shared_experts:
            sp = p["shared"]
            act = activation_fn(cfg.activation)
            x2s = x.reshape(B * S, d)
            y = y + (act(x2s @ sp["w_gate"]) * (x2s @ sp["w_up"])) @ sp["w_down"]
        y = y.reshape(B, S, d)
        if not return_aux:
            return y
        return y, jnp.float32(0.0)
    x2 = x.reshape(B * S, d)
    T, E, K = B * S, cfg.n_experts, cfg.moe_top_k
    C = moe_capacity(cfg, T)

    logits = (x2 @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(probs, K)                     # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(-1)                             # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = top_w.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (T*K, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)        # E*C = drop slot

    xe = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(x2[flat_t])
    xe = xe[: E * C].reshape(E, C, d)
    if constrain is not None:
        xe = constrain(xe, "moe_dispatch")
    act = activation_fn(cfg.activation)
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])        # (E, C, d)
    if constrain is not None:
        ye = constrain(ye, "moe_dispatch")

    ye_flat = jnp.concatenate([ye.reshape(E * C, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    back = ye_flat[slot] * (flat_w * keep)[:, None].astype(ye.dtype)
    y = jnp.zeros((T, d), x.dtype).at[flat_t].add(back)

    if cfg.n_shared_experts:
        sp = p["shared"]
        y = y + (act(x2 @ sp["w_gate"]) * (x2 @ sp["w_up"])) @ sp["w_down"]

    y = y.reshape(B, S, d)
    if not return_aux:
        return y
    # load-balance aux loss (Switch/GShard): E * sum_e f_e * P_e
    frac_tokens = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
