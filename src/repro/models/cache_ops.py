"""Decode-cache pytree surgery: slot slicing/merging and prefix snapshots.

The serving engine keeps one batched decode cache (leading layer axis,
batch axis 1 — see `init_decode_cache`); requests prefill into a B=1
sub-cache which is then merged into their slot. The shared-prefix KV cache
(`serving/prefix_cache.py`) additionally stores *trimmed* B=1 sub-caches:
length-indexed buffers (`k`/`v`/`ckv`/`krope`, token axis 2) are sliced to
the prefix length so a snapshot costs O(prefix) memory, while pure-state
buffers (SSM `conv`/`ssm`, enc-dec `ck`/`cv`) are kept whole — they are the
exact recurrent/cross state *after* the prefix, which is why snapshots must
be taken by prefilling exactly the prefix (never by slicing a longer
prompt's final state).

Attention masks in decode are gated by `pos` (`layers.attn_decode_apply`
masks `kv_pos < pos+1`), so the zero tail a restored snapshot is padded
with is never attended to.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Buffers indexed by token position on axis 2 ((L, B, max_len, ...)); all
# other cache entries are per-slot state copied whole.
LENGTH_KEYS = ("k", "v", "ckv", "krope")


def slot_cache(cache: dict, slot: int) -> dict:
    """Extract one slot of a batched decode cache as a B=1 sub-cache."""
    sub = {}
    for k, a in cache.items():
        if k == "pos":
            sub[k] = a[slot] if a.ndim else a
        else:
            sub[k] = a[:, slot:slot + 1]
    return sub


def write_slot(cache: dict, sub: dict, slot: int) -> dict:
    """Merge a B=1 sub-cache into `slot` of a batched decode cache."""
    out = dict(cache)
    for k in cache:
        if k == "pos":
            pos = cache["pos"]
            out[k] = (pos.at[slot].set(jnp.asarray(sub["pos"], pos.dtype))
                      if pos.ndim else jnp.asarray(sub["pos"], pos.dtype))
        else:
            out[k] = cache[k].at[:, slot].set(sub[k][:, 0].astype(cache[k].dtype))
    return out


def prefix_snapshot(sub: dict, prefix_len: int) -> dict:
    """Trim a B=1 sub-cache (taken right after prefilling exactly the
    prefix) to O(prefix_len) storage."""
    snap = {}
    for k, a in sub.items():
        if k == "pos":
            snap[k] = jnp.asarray(prefix_len, jnp.int32)
        elif k in LENGTH_KEYS:
            snap[k] = a[:, :, :prefix_len]
        else:
            snap[k] = a
    return snap


def expand_snapshot(snap: dict, max_len: int) -> dict:
    """Zero-pad a trimmed snapshot's token axes back to `max_len` so it is
    shape-compatible with the engine's decode cache."""
    sub = {}
    for k, a in snap.items():
        if k in LENGTH_KEYS and a.shape[2] < max_len:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, max_len - a.shape[2])
            sub[k] = jnp.pad(a, pad)
        else:
            sub[k] = a
    return sub


def cache_nbytes(tree: dict) -> int:
    """Device bytes held by a cache pytree (for eviction budgets)."""
    return sum(int(a.size) * a.dtype.itemsize
               for a in tree.values() if hasattr(a, "size"))


# ---------------------------------------------------------- paged KV -------
#
# vLLM-style block layout: the length-indexed KV buffers live in a shared
# pool of fixed-size pages instead of per-slot contiguous slabs. A sequence
# is a *page table* (block index -> physical page id); a shared prefix is a
# run of page ids referenced by many tables at once (ref-counted), so a
# prefix-cache hit splices ids instead of copying KV, with copy-on-write on
# the one partially-filled boundary page. Pure-state buffers (SSM conv/ssm,
# enc-dec ck/cv) are not length-indexed and stay in the per-slot state cache.

PAGE_SINK = 0  # reserved page id: scatter target for dead rows, never read


class PagePoolExhausted(RuntimeError):
    """The fixed page pool has no free page left (after prefix eviction)."""


class PageAllocator:
    """Fixed-size KV page pool: free-list allocation + ref-counting.

    Owns the device pools — one array per length-indexed cache key, shaped
    (layer_axis, num_pages, page_size, *tail) — and the host-side page
    metadata. Page 0 is the *sink*: a scratch page dead batch rows scatter
    into; it is never allocated and never read.
    """

    def __init__(self, cfg, num_pages: int, page_size: int):
        from repro.models import init_decode_cache  # local: avoid cycle
        assert num_pages >= 2, "need at least the sink plus one real page"
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        template = init_decode_cache(cfg, 1, self.page_size)
        self.pools = {}
        for key in LENGTH_KEYS:
            if key in template:
                a = template[key]            # (Lax, 1, page_size, *tail)
                shape = (a.shape[0], self.num_pages) + a.shape[2:]
                self.pools[key] = jnp.zeros(shape, a.dtype)
        self.refcount = [0] * self.num_pages
        self._free = list(range(self.num_pages - 1, 0, -1))  # sink excluded

    def shard_pools(self, mesh) -> None:
        """Lay the device pools out over a serving mesh (DESIGN.md §15):
        pages replicated (host-local page ids must dereference identically
        on every device), heads/features over the `model` axis. Call once,
        right after construction — page contents are preserved."""
        from repro.distributed.sharding import pool_specs, to_shardings
        self.pools = jax.device_put(
            self.pools, to_shardings(mesh, pool_specs(self.pools, mesh)))

    # ------------------------------------------------------------ queries --

    @property
    def page_nbytes(self) -> int:
        """Device bytes of one page across every pooled buffer."""
        return sum(int(a[:, 0].size) * a.dtype.itemsize
                   for a in self.pools.values())

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - 1 - len(self._free)

    @property
    def nbytes_in_use(self) -> int:
        return self.used_pages * self.page_nbytes

    # --------------------------------------------------------- allocation --

    def alloc(self, n: int) -> list:
        """Allocate `n` pages (refcount 1 each). All-or-nothing: raises
        PagePoolExhausted without allocating anything if fewer are free."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"need {n} pages, {len(self._free)} free "
                f"(pool={self.num_pages}, page_size={self.page_size})")
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self.refcount[i] = 1
        return ids

    def retain(self, ids) -> None:
        """Add a reference to already-live pages (prefix sharing)."""
        for i in ids:
            if self.refcount[i] <= 0:
                raise RuntimeError(f"retain of free page {i}")
            self.refcount[i] += 1

    def release(self, ids) -> None:
        """Drop a reference; a page returns to the free list at zero.
        Releasing an already-free page is a hard error (double free)."""
        for i in ids:
            if self.refcount[i] <= 0:
                raise RuntimeError(f"double free of page {i}")
            self.refcount[i] -= 1
            if self.refcount[i] == 0:
                self._free.append(i)

    def copy_page(self, src: int) -> int:
        """Copy-on-write: allocate a fresh page holding `src`'s contents."""
        (dst,) = self.alloc(1)
        for k in self.pools:
            self.pools[k] = _copy_page_op(self.pools[k], src, dst)
        return dst


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page_op(pool, src, dst):
    """One-page copy with the pool buffer donated: the update lowers to an
    in-place scatter instead of a whole-pool rewrite per CoW."""
    return pool.at[:, dst].set(pool[:, src])


# Device-side page ops (jit-friendly; page ids arrive as traced int arrays).


def gather_page_views(pools: dict, table) -> dict:
    """Assemble contiguous per-row KV views through a page table.

    table: (B, nb) int32 of page ids. Returns, per pooled key, a dense
    (layer_axis, B, nb*page_size, *tail) view — the layout `decode_step` /
    `prefill_chunk` already consume, so the paged engine runs the exact
    same model code over gathered views.
    """
    out = {}
    for k, pool in pools.items():
        g = pool[:, table]                       # (Lax, B, nb, ps, *tail)
        out[k] = g.reshape(g.shape[:2] + (g.shape[2] * g.shape[3],) + g.shape[4:])
    return out


def scatter_token_pages(pools: dict, dense: dict, write_ids, block_starts,
                        page_size: int) -> dict:
    """Write back each row's active page after a decode step.

    dense: per-key (Lax, B, S, *tail) views returned by the model; the only
    page a decode step dirties for row b is the one holding `pos`, whose
    view offset is block_starts[b]. write_ids[b] is its physical page
    (PAGE_SINK for dead rows). Returns updated pools.
    """
    starts = jnp.asarray(block_starts, jnp.int32)
    out = dict(pools)
    for k, pool in pools.items():
        view = dense[k]

        def one_row(row, s):                     # (Lax, S, *tail) -> page
            return jax.lax.dynamic_slice_in_dim(row, s, page_size, axis=1)
        pages = jax.vmap(one_row, in_axes=(1, 0), out_axes=1)(view, starts)
        out[k] = pool.at[:, jnp.asarray(write_ids, jnp.int32)].set(
            pages.astype(pool.dtype))
    return out


def scatter_chunk_pages_rows(pools: dict, view: dict, write_tables, block0s,
                             page_size: int, n_blocks: int) -> dict:
    """Per-row `scatter_chunk_pages` for batched speculative verification.

    view: per-key (Lax, B, nb_ctx*ps, *tail) gathered contexts the verify
    chunk was computed over; row b dirtied blocks [block0s[b], block0s[b] +
    n_blocks) of its own view, whose physical pages are write_tables[b]
    ((B, n_blocks) int32, PAGE_SINK past each row's allocation). Rows never
    share writable pages (the engine CoWs shared boundary pages at insert),
    so duplicate sink ids are the only collisions and the sink is never read.
    """
    b0 = jnp.asarray(block0s, jnp.int32)
    ids = jnp.asarray(write_tables, jnp.int32)               # (B, nb)
    out = dict(pools)
    for k, pool in pools.items():
        v = view[k]
        blocked = v.reshape((v.shape[0], v.shape[1], -1, page_size) + v.shape[3:])

        def one_row(row, s):                     # (Lax, nb_ctx, ps, *tail)
            return jax.lax.dynamic_slice_in_dim(row, s, n_blocks, axis=1)
        pages = jax.vmap(one_row, in_axes=(1, 0), out_axes=1)(blocked, b0)
        flat = pages.reshape((pages.shape[0], -1) + pages.shape[3:])
        out[k] = pool.at[:, ids.reshape(-1)].set(flat.astype(pool.dtype))
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_range_op(pool, pid, lo, hi):
    """Zero positions [lo, hi) of one page, pool donated: lowers to an
    in-place scatter (like `_copy_page_op`) instead of a whole-pool copy
    per scrub — pid/lo/hi are traced, so one compile serves every rollback."""
    ps = pool.shape[2]
    mask = (jnp.arange(ps) >= lo) & (jnp.arange(ps) < hi)
    page = pool[:, pid]
    page = jnp.where(mask.reshape((1, ps) + (1,) * (page.ndim - 2)),
                     jnp.zeros((), pool.dtype), page)
    return pool.at[:, pid].set(page)


def truncate_pages(pools: dict, page_ids: list, start: int, end: int,
                   page_size: int) -> dict:
    """Page-truncate (speculative rollback, DESIGN.md §14): zero the KV at
    logical positions [start, end) of a sequence whose block table is
    `page_ids`. Positions past the allocation are skipped (they were
    scattered into the sink). Zeroing — rather than relying only on the
    pos-gated masks — restores the pool bit-exactly to its pre-speculation
    state, so shared/CoW invariants and byte-level page comparisons hold.
    All arguments are host values; returns updated pools.
    """
    out = dict(pools)
    for b in range(start // page_size, -(-end // page_size)):
        if b >= len(page_ids):
            break
        lo = max(start - b * page_size, 0)
        hi = min(end - b * page_size, page_size)
        if lo >= hi:
            continue
        pid = int(page_ids[b])
        for k, pool in out.items():
            out[k] = _zero_range_op(pool, pid, lo, hi)
    return out


def release_trailing_pages(alloc, pages: list, keep_blocks: int) -> list:
    """Ref-release (speculative rollback): drop the references a rejected
    suffix held past the kept block high-water mark. Returns the trimmed
    page table; the released pages return to the allocator's free list at
    refcount zero."""
    keep_blocks = max(0, int(keep_blocks))
    if keep_blocks >= len(pages):
        return pages
    alloc.release(pages[keep_blocks:])
    return pages[:keep_blocks]


def scatter_chunk_pages(pools: dict, view: dict, write_ids, block0,
                        page_size: int, n_blocks: int) -> dict:
    """Write back the pages a B=1 prefill chunk dirtied.

    view: per-key (Lax, 1, nb_ctx*ps, *tail) gathered context the chunk was
    computed over (chunk K/V written in place); blocks [block0, block0 +
    n_blocks) cover the chunk (plus CoW slack), write_ids (n_blocks,) their
    physical pages (padded with PAGE_SINK past the allocation).
    """
    b0 = jnp.asarray(block0, jnp.int32)
    out = dict(pools)
    for k, pool in pools.items():
        v = view[k]
        blocked = v.reshape((v.shape[0], -1, page_size) + v.shape[3:])
        pages = jax.lax.dynamic_slice_in_dim(blocked, b0, n_blocks, axis=1)
        out[k] = pool.at[:, jnp.asarray(write_ids, jnp.int32)].set(
            pages.astype(pool.dtype))
    return out
