"""Decode-cache pytree surgery: slot slicing/merging and prefix snapshots.

The serving engine keeps one batched decode cache (leading layer axis,
batch axis 1 — see `init_decode_cache`); requests prefill into a B=1
sub-cache which is then merged into their slot. The shared-prefix KV cache
(`serving/prefix_cache.py`) additionally stores *trimmed* B=1 sub-caches:
length-indexed buffers (`k`/`v`/`ckv`/`krope`, token axis 2) are sliced to
the prefix length so a snapshot costs O(prefix) memory, while pure-state
buffers (SSM `conv`/`ssm`, enc-dec `ck`/`cv`) are kept whole — they are the
exact recurrent/cross state *after* the prefix, which is why snapshots must
be taken by prefilling exactly the prefix (never by slicing a longer
prompt's final state).

Attention masks in decode are gated by `pos` (`layers.attn_decode_apply`
masks `kv_pos < pos+1`), so the zero tail a restored snapshot is padded
with is never attended to.
"""
from __future__ import annotations

import jax.numpy as jnp

# Buffers indexed by token position on axis 2 ((L, B, max_len, ...)); all
# other cache entries are per-slot state copied whole.
LENGTH_KEYS = ("k", "v", "ckv", "krope")


def slot_cache(cache: dict, slot: int) -> dict:
    """Extract one slot of a batched decode cache as a B=1 sub-cache."""
    sub = {}
    for k, a in cache.items():
        if k == "pos":
            sub[k] = a[slot] if a.ndim else a
        else:
            sub[k] = a[:, slot:slot + 1]
    return sub


def write_slot(cache: dict, sub: dict, slot: int) -> dict:
    """Merge a B=1 sub-cache into `slot` of a batched decode cache."""
    out = dict(cache)
    for k in cache:
        if k == "pos":
            pos = cache["pos"]
            out[k] = (pos.at[slot].set(jnp.asarray(sub["pos"], pos.dtype))
                      if pos.ndim else jnp.asarray(sub["pos"], pos.dtype))
        else:
            out[k] = cache[k].at[:, slot].set(sub[k][:, 0].astype(cache[k].dtype))
    return out


def prefix_snapshot(sub: dict, prefix_len: int) -> dict:
    """Trim a B=1 sub-cache (taken right after prefilling exactly the
    prefix) to O(prefix_len) storage."""
    snap = {}
    for k, a in sub.items():
        if k == "pos":
            snap[k] = jnp.asarray(prefix_len, jnp.int32)
        elif k in LENGTH_KEYS:
            snap[k] = a[:, :, :prefix_len]
        else:
            snap[k] = a
    return snap


def expand_snapshot(snap: dict, max_len: int) -> dict:
    """Zero-pad a trimmed snapshot's token axes back to `max_len` so it is
    shape-compatible with the engine's decode cache."""
    sub = {}
    for k, a in snap.items():
        if k in LENGTH_KEYS and a.shape[2] < max_len:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, max_len - a.shape[2])
            sub[k] = jnp.pad(a, pad)
        else:
            sub[k] = a
    return sub


def cache_nbytes(tree: dict) -> int:
    """Device bytes held by a cache pytree (for eviction budgets)."""
    return sum(int(a.size) * a.dtype.itemsize
               for a in tree.values() if hasattr(a, "size"))
