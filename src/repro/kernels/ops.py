"""Jit'd public wrappers for the kernel layer.

Backend selection: "pallas" lowers the Pallas TPU kernels (interpret=True on
CPU so the same kernel body is validated in this container); "xla" runs the
mathematically identical jnp path (used by the distributed dry-run, where
Pallas-for-CPU cannot be compiled ahead-of-time). Default: xla on CPU,
pallas on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref

_FORCE_BACKEND = None  # test hook


def set_backend(name):
    global _FORCE_BACKEND
    _FORCE_BACKEND = name


def backend() -> str:
    if _FORCE_BACKEND:
        return _FORCE_BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------- topk_l2 -----


@functools.partial(jax.jit, static_argnums=(2,))
def _topk_l2_xla(db, q, k):
    return ref.topk_l2_ref(db, q, k)


def topk_l2(db, q, k: int):
    """Top-k nearest (L2) database rows per query. db: (N,D), q: (M,D)."""
    db = jnp.asarray(db, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    if backend() == "pallas" and db.shape[0] >= 256:
        from .topk_l2 import topk_l2_pallas
        return topk_l2_pallas(db, q, k, interpret=_interpret())
    return _topk_l2_xla(db, q, k)


# ------------------------------------------------------ flash attention ----


def flash_attention(q, k, v, *, causal: bool = True):
    if backend() == "pallas":
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, interpret=_interpret())
    return ref.flash_attention_ref(q, k, v, causal=causal)


def decode_attention(q, k_cache, v_cache, length):
    if backend() == "pallas":
        from .decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k_cache, v_cache, length,
                                       interpret=_interpret())
    return ref.decode_attention_ref(q, k_cache, v_cache, length)


# ------------------------------------------------------------ ssm scan -----


def ssm_scan(x, dt, A, B_mat, C_mat, D, h0=None):
    if backend() == "pallas":
        from .ssm_scan import ssm_scan_pallas
        return ssm_scan_pallas(x, dt, A, B_mat, C_mat, D, h0=h0,
                               interpret=_interpret())
    return ref.ssm_scan_ref(x, dt, A, B_mat, C_mat, D, h0=h0)


# ---------------------------------------------------------- moe gating -----


def moe_gating(logits, k: int):
    if backend() == "pallas":
        from .moe_gating import moe_gating_pallas
        return moe_gating_pallas(logits, k, interpret=_interpret())
    return ref.moe_gating_ref(logits, k)
