"""Pallas TPU flash attention (prefill hot spot).

Tiling: grid (B, H, Sq/bq, Skv/bk); the innermost kv-block axis is
sequential ("arbitrary") so the online-softmax accumulators live in VMEM
scratch across kv steps. Causal blocks that are fully masked are *skipped*
(pl.when on block indices) — this is the 2x FLOP saving the XLA jnp path
cannot express (DESIGN.md §5). GQA is handled in the k/v index maps
(q head h reads kv head h // G). Block sizes are MXU-aligned (128 lanes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, scale: float, bq: int, bk: int, nk: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (sequential)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new

    if causal:
        # skip kv blocks entirely above the diagonal
        pl.when(j * bk <= (i + 1) * bq - 1)(_compute)
    else:
        _compute()

    last_j = ((i + 1) * bq - 1) // bk if causal else nk - 1

    @pl.when(j == last_j)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, bq=128, bk=128,
                           interpret=False):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D). Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, Skv, bq, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = D ** -0.5

    grid = (B, H, nq, nk)
    kernel = functools.partial(_kernel, causal=causal, scale=scale,
                               bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out
