"""Pallas TPU fused MoE gating: softmax -> top-k -> renormalize.

One pass over the router logits per token tile; iterative arg-max selection
(k is small) avoids a full sort. Outputs renormalized top-k weights and
expert indices, matching `ref.moe_gating_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG = -1e30


def _kernel(x_ref, w_ref, i_ref, *, k: int):
    logits = x_ref[...].astype(jnp.float32)                 # (bt, E)
    m = logits.max(axis=1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / p.sum(axis=1, keepdims=True)

    def pick(_, carry):
        probs, ws, ids, slot = carry
        top = probs.max(axis=1)
        arg = jnp.argmax(probs, axis=1)
        ws = jax.lax.dynamic_update_slice_in_dim(ws, top[:, None], slot, axis=1)
        ids = jax.lax.dynamic_update_slice_in_dim(ids, arg[:, None].astype(jnp.int32),
                                                  slot, axis=1)
        onehot = jax.nn.one_hot(arg, probs.shape[1], dtype=probs.dtype)
        return probs - onehot * (top[:, None] + 1.0), ws, ids, slot + 1

    bt = p.shape[0]
    ws0 = jnp.zeros((bt, k), jnp.float32)
    ids0 = jnp.zeros((bt, k), jnp.int32)
    _, ws, ids, _ = jax.lax.fori_loop(0, k, pick, (p, ws0, ids0, 0))
    ws = jnp.maximum(ws, 0.0)
    w_ref[...] = ws / jnp.maximum(ws.sum(axis=1, keepdims=True), 1e-9)
    i_ref[...] = ids


@functools.partial(jax.jit, static_argnames=("k", "bt", "interpret"))
def moe_gating_pallas(logits, k: int, *, bt=256, interpret=False):
    """logits: (T, E). Returns (weights (T,k), idx (T,k))."""
    T, E = logits.shape
    bt = min(bt, T)
    pad = (-T) % bt
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)), constant_values=NEG)
    Tp = logits.shape[0]
    w, i = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(Tp // bt,),
        in_specs=[pl.BlockSpec((bt, E), lambda t: (t, 0))],
        out_specs=[pl.BlockSpec((bt, k), lambda t: (t, 0)),
                   pl.BlockSpec((bt, k), lambda t: (t, 0))],
        out_shape=[jax.ShapeDtypeStruct((Tp, k), jnp.float32),
                   jax.ShapeDtypeStruct((Tp, k), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(logits)
    return w[:T], i[:T]
