"""Version compatibility for the Pallas TPU kernel layer.

jax 0.5+ names the TPU compiler params `pltpu.CompilerParams`; 0.4.x
`pltpu.TPUCompilerParams`. Kernels import the alias from here so a future
rename is one edit (and no third-party module gets monkeypatched).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
