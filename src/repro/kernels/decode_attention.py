"""Pallas TPU flash-decoding (single-token attention over a long KV cache).

One query token per (batch, head); the KV sequence is tiled and reduced
sequentially with online-softmax accumulators in VMEM scratch. Padded cache
positions (>= length) are masked. This kernel is the per-device leaf of the
sequence-sharded decode path (distributed/decode.py): shard_map splits S
over the `model` mesh axis, each device runs this kernel on its shard, and
the partial (max, denom, acc) combine happens with tiny collectives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bk: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    q = q_ref[0, 0, :].astype(jnp.float32)                  # (D,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.sum(k * q[None, :], axis=1) * (q.shape[0] ** -0.5)   # (bk,)
    pos = j * bk + jax.lax.iota(jnp.int32, bk)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_scr[0, 0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[0, 0] = l_scr[0, 0] * alpha + p.sum()
    acc_scr[0, :] = acc_scr[0, :] * alpha + jnp.sum(p[:, None] * v, axis=0)
    m_scr[0, 0] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0, :] = (acc_scr[0, :] / jnp.maximum(l_scr[0, 0], 1e-30)
                          ).astype(o_ref.dtype)


# ------------------------------------------------------------ paged --------
#
# Paged flash-decoding: the KV cache is a pool of fixed-size pages shared by
# every sequence (serving/engine.py kv_layout="paged"); each row owns a page
# *table* mapping its block index to a physical page. The table rides in as
# a scalar-prefetch operand, so the KV BlockSpec index_map dereferences it —
# the kernel walks pages in logical order without ever materializing a
# gathered copy of the cache (the host-side reference path, `cache_ops.
# gather_page_views`, pays that copy; this kernel is why TPUs don't).


def _paged_kernel(len_ref, ptab_ref, q_ref, k_ref, v_ref, o_ref, m_scr,
                  l_scr, acc_scr, *, ps: int, nk: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    q = q_ref[0, 0, :].astype(jnp.float32)                  # (D,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (ps, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.sum(k * q[None, :], axis=1) * (q.shape[0] ** -0.5)   # (ps,)
    pos = j * ps + jax.lax.iota(jnp.int32, ps)              # logical positions
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_scr[0, 0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[0, 0] = l_scr[0, 0] * alpha + p.sum()
    acc_scr[0, :] = acc_scr[0, :] * alpha + jnp.sum(p[:, None] * v, axis=0)
    m_scr[0, 0] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0, :] = (acc_scr[0, :] / jnp.maximum(l_scr[0, 0], 1e-30)
                          ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_pool, v_pool, page_table, lengths, *,
                                  interpret=False):
    """Flash-decoding through a page table.

    q: (B, H, D); pools: (P, ps, Hkv, D) — the *shared* page pool, no batch
    axis; page_table: (B, nb) int32 physical page per logical block;
    lengths: (B,) valid tokens per row. -> (B, H, D).
    """
    B, H, D = q.shape
    P, ps, Hkv, _ = k_pool.shape
    G = H // Hkv
    nb = page_table.shape[1]

    grid = (B, H, nb)
    kernel = functools.partial(_paged_kernel, ps=ps, nk=nb)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, D), lambda b, h, j, lens, ptab: (b, h, 0)),
                pl.BlockSpec((1, ps, 1, D),
                             lambda b, h, j, lens, ptab: (ptab[b, j], 0, h // G, 0)),
                pl.BlockSpec((1, ps, 1, D),
                             lambda b, h, j, lens, ptab: (ptab[b, j], 0, h // G, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j, lens, ptab: (b, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(lengths, jnp.int32), jnp.asarray(page_table, jnp.int32),
      q, k_pool, v_pool)
    return out


def _paged_verify_kernel(start_ref, ptab_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, ps: int, nk: int):
    """Batched-verify flash-decoding: C candidate tokens per (batch, head)
    attend the row's paged KV causally from its decode position. The online
    softmax accumulators carry one (max, denom, acc) row per candidate."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    start = start_ref[b]
    q = q_ref[0, 0, :, :].astype(jnp.float32)               # (C, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (ps, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    C = q.shape[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) \
        * (q.shape[1] ** -0.5)                              # (C, ps)
    kv_pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (C, ps), 1)
    q_pos = start + jax.lax.broadcasted_iota(jnp.int32, (C, ps), 0)
    s = jnp.where(kv_pos <= q_pos, s, NEG_INF)              # causal per row

    m_prev = m_scr[:, 0]                                    # (C,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(p, v)
    m_scr[:, 0] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
                             ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention_pallas(q, k_pool, v_pool, page_table, starts, *,
                                  interpret=False):
    """Speculative-verification attention through a page table
    (DESIGN.md §14): every row scores its C candidate tokens (pending +
    drafts, already written to the row's pages at [starts[b], starts[b]+C))
    in one pass — the batched generalization of flash-decoding from C=1.

    q: (B, H, C, D); pools: (P, ps, Hkv, D) shared page pool; page_table:
    (B, nb) int32; starts: (B,) decode position of each row's first
    candidate. -> (B, H, C, D).
    """
    B, H, C, D = q.shape
    P, ps, Hkv, _ = k_pool.shape
    G = H // Hkv
    nb = page_table.shape[1]

    grid = (B, H, nb)
    kernel = functools.partial(_paged_verify_kernel, ps=ps, nk=nb)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, C, D),
                             lambda b, h, j, starts, ptab: (b, h, 0, 0)),
                pl.BlockSpec((1, ps, 1, D),
                             lambda b, h, j, starts, ptab: (ptab[b, j], 0, h // G, 0)),
                pl.BlockSpec((1, ps, 1, D),
                             lambda b, h, j, starts, ptab: (ptab[b, j], 0, h // G, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, C, D),
                                   lambda b, h, j, starts, ptab: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((C, 1), jnp.float32),
                pltpu.VMEM((C, 1), jnp.float32),
                pltpu.VMEM((C, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, C, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(starts, jnp.int32), jnp.asarray(page_table, jnp.int32),
      q, k_pool, v_pool)
    return out


def paged_verify_attention_ref(q, k_pool, v_pool, page_table, starts):
    """jnp oracle: gather pages into dense rows, causal masked attention."""
    B, H, C, D = q.shape
    _, ps, Hkv, _ = k_pool.shape
    kg = k_pool[page_table]
    vg = v_pool[page_table]
    S = kg.shape[1] * ps
    kg = kg.reshape(B, S, Hkv, D)
    vg = vg.reshape(B, S, Hkv, D)
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, C, D)
    s = jnp.einsum("bhgcd,bshd->bhgcs", qg, kg,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    q_pos = jnp.asarray(starts)[:, None] + jnp.arange(C)[None, :]   # (B, C)
    ok = jnp.arange(S)[None, None, :] <= q_pos[:, :, None]          # (B, C, S)
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgcs,bshd->bhgcd", p.astype(vg.dtype), vg)
    return out.reshape(B, H, C, D)


def paged_decode_attention_ref(q, k_pool, v_pool, page_table, lengths):
    """jnp oracle: gather pages into dense rows, then masked attention."""
    B, H, D = q.shape
    _, ps, Hkv, _ = k_pool.shape
    kg = k_pool[page_table]                     # (B, nb, ps, Hkv, D)
    vg = v_pool[page_table]
    S = kg.shape[1] * ps
    kg = kg.reshape(B, S, Hkv, D)
    vg = vg.reshape(B, S, Hkv, D)
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kg,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    ok = jnp.arange(S)[None, :] < jnp.asarray(lengths)[:, None]
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p.astype(vg.dtype), vg).reshape(B, H, D)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, length, *, bk=512,
                            interpret=False):
    """q: (B, H, D); caches: (B, S, Hkv, D); length: scalar int. -> (B, H, D)."""
    B, H, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = H // Hkv
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    lengths = jnp.full((1,), length, jnp.int32)

    grid = (B, H, nk)
    kernel = functools.partial(_kernel, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, D), lambda b, h, j, lens: (b, h, 0)),
                pl.BlockSpec((1, bk, 1, D), lambda b, h, j, lens: (b, j, h // G, 0)),
                pl.BlockSpec((1, bk, 1, D), lambda b, h, j, lens: (b, j, h // G, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j, lens: (b, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
    return out
