"""Pallas TPU fused L2-distance + running top-k (QUEST index retrieval).

The database is tiled over the sequential grid axis; each step computes a
(bm, bn) distance tile on the MXU (|q|^2 + |db|^2 - 2 q.db) and merges it
into a running per-query top-k held in VMEM scratch via a sort-based merge.
This keeps the whole corpus scan at one HBM pass with no (M, N) distance
materialization — the adaptation of QUEST's PQ/HNSW retrieval to dense TPU
compute (DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

BIG = 1e30


def _kernel(q_ref, db_ref, od_ref, oi_ref, bd_scr, bi_scr, *,
            k: int, bn: int, nn: int, n_total: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bd_scr[...] = jnp.full_like(bd_scr, BIG)
        bi_scr[...] = jnp.zeros_like(bi_scr)

    q = q_ref[...].astype(jnp.float32)                     # (bm, D)
    db = db_ref[...].astype(jnp.float32)                   # (bn, D)
    d2 = (jnp.sum(q * q, axis=1)[:, None]
          + jnp.sum(db * db, axis=1)[None, :]
          - 2.0 * jax.lax.dot_general(q, db, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32))
    idx = j * bn + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where(idx < n_total, d2, BIG)                 # tail padding

    cand_d = jnp.concatenate([bd_scr[...], d2], axis=1)    # (bm, k + bn)
    cand_i = jnp.concatenate([bi_scr[...], idx], axis=1)
    order = jnp.argsort(cand_d, axis=1)[:, :k]
    bd_scr[...] = jnp.take_along_axis(cand_d, order, axis=1)
    bi_scr[...] = jnp.take_along_axis(cand_i, order, axis=1)

    @pl.when(j == nn - 1)
    def _finalize():
        od_ref[...] = jnp.sqrt(jnp.maximum(bd_scr[...], 0.0))
        oi_ref[...] = bi_scr[...]


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "interpret"))
def topk_l2_pallas(db, q, k: int, *, bm=8, bn=256, interpret=False):
    """db: (N, D); q: (M, D). Returns (dists (M, k), idx (M, k)) ascending."""
    N, D = db.shape
    M, _ = q.shape
    bm = min(bm, M)
    bn = min(bn, N)
    m_pad = (-M) % bm
    n_pad = (-N) % bn
    if m_pad:
        q = jnp.pad(q, ((0, m_pad), (0, 0)))
    if n_pad:
        db = jnp.pad(db, ((0, n_pad), (0, 0)))
    Mp, Np = q.shape[0], db.shape[0]
    nm, nn = Mp // bm, Np // bn

    kernel = functools.partial(_kernel, k=k, bn=bn, nn=nn, n_total=N)
    dists, idx = pl.pallas_call(
        kernel,
        grid=(nm, nn),
        in_specs=[
            pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, k), jnp.float32),
            jax.ShapeDtypeStruct((Mp, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, k), jnp.float32),
            pltpu.VMEM((bm, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, db)
    return dists[:M], idx[:M]
