"""Pallas TPU selective scan (Mamba1 hot spot).

TPU adaptation (DESIGN.md §5): channels ride the 128-wide VPU lanes, time is
sequential *inside* the kernel with the SSM state held in VMEM scratch —
one HBM read per input element and one write per output element, no state
round-trips (the CUDA version's shared-memory prefix scan becomes a
lane-vectorized VMEM-resident recurrence). The sequence is tiled over the
sequential grid axis so the working set stays a (chunk x bd) tile.

Grid: (B, di/bd, S/chunk), state scratch (bd, N) persists across chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref,
            h_scr, *, chunk: int, nc: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = a_ref[...].astype(jnp.float32)                       # (bd, N)
    Dp = d_ref[...].astype(jnp.float32)                      # (1, bd)

    def step(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)             # (bd,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)           # (bd,)
        B_t = b_ref[0, t, :].astype(jnp.float32)             # (N,)
        C_t = c_ref[0, t, :].astype(jnp.float32)             # (N,)
        da = jnp.exp(dt_t[:, None] * A)                      # (bd, N)
        h = da * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y = jnp.sum(h * C_t[None, :], axis=1) + Dp[0] * x_t
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(s == nc - 1)
    def _finalize():
        h_ref[0, :, :] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def ssm_scan_pallas(x, dt, A, B_mat, C_mat, D, h0=None, *, bd=256, chunk=64,
                    interpret=False):
    """Shapes as mamba1_scan_ref: x/dt (B,S,di); A (di,N); B/C (B,S,N); D (di).
    Returns (y (B,S,di), h_final (B,di,N) fp32)."""
    Bsz, S, di = x.shape
    N = A.shape[-1]
    bd = min(bd, di)
    chunk = min(chunk, S)
    assert di % bd == 0 and S % chunk == 0, (di, bd, S, chunk)
    nd, nc = di // bd, S // chunk
    assert h0 is None, "cache-seeded scan handled by the decode path"

    kernel = functools.partial(_kernel, chunk=chunk, nc=nc)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=(Bsz, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),   # x
            pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),   # dt
            pl.BlockSpec((bd, N), lambda b, d, s: (d, 0)),             # A
            pl.BlockSpec((1, chunk, N), lambda b, d, s: (b, s, 0)),    # B
            pl.BlockSpec((1, chunk, N), lambda b, d, s: (b, s, 0)),    # C
            pl.BlockSpec((1, bd), lambda b, d, s: (0, d)),             # D
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, bd, N), lambda b, d, s: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, di), x.dtype),
            jax.ShapeDtypeStruct((Bsz, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B_mat, C_mat, D.reshape(1, di))
    return y, h_fin
