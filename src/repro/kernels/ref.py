"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q: (B, Sq, H, d); k/v: (B, Skv, Hkv, d) with H % Hkv == 0."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale or D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k, preferred_element_type=jnp.float32) * scale
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Skv)[None, :] <= (jnp.arange(Sq)[:, None] + (Skv - Sq))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def decode_attention_ref(q, k_cache, v_cache, length):
    """q: (B, H, d); caches: (B, S, Hkv, d); length: scalar valid length."""
    B, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    ok = jnp.arange(k_cache.shape[1]) < length
    s = jnp.where(ok[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, H, D)


def topk_l2_ref(db, q, k: int):
    """db: (N, D); q: (M, D). Returns (dists (M,k), idx (M,k)) ascending."""
    d2 = jnp.sum((q[:, None, :] - db[None, :, :]) ** 2, axis=-1)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx


def ssm_scan_ref(x, dt, A, B_mat, C_mat, D, h0=None):
    """Mamba1 selective scan oracle. Shapes as repro.models.ssm.mamba1_scan_ref."""
    from repro.models.ssm import mamba1_scan_ref
    return mamba1_scan_ref(x, dt, A, B_mat, C_mat, D, h0=h0)


def moe_gating_ref(logits, k: int):
    """logits: (T, E). Returns (weights (T,k) renormalized, indices (T,k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, i = jax.lax.top_k(probs, k)
    return w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9), i
