"""Semantic segmentation (paper §4.1, SemanticChunker-equivalent).

Split into sentences, then greedily merge consecutive sentences while their
embeddings stay similar (cosine of L2-normalized embeddings <=> L2 distance),
bounded by a max segment token budget so each attribute fits one segment.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.tokens import count_tokens, split_sentences


@dataclass
class Segment:
    doc_id: object
    seg_id: int
    text: str
    tokens: int


def segment_document(doc_id, text: str, embedder, *, sim_threshold: float = 0.55,
                     max_tokens: int = 120) -> list[Segment]:
    sents = split_sentences(text)
    if not sents:
        return [Segment(doc_id, 0, text, count_tokens(text))]
    embs = embedder.embed(sents)
    segs: list[list[int]] = [[0]]
    for i in range(1, len(sents)):
        cur = segs[-1]
        sim = float(np.dot(embs[i], embs[i - 1]))
        cur_tokens = sum(count_tokens(sents[j]) for j in cur)
        if sim >= sim_threshold and cur_tokens + count_tokens(sents[i]) <= max_tokens:
            cur.append(i)
        else:
            segs.append([i])
    out = []
    for si, idxs in enumerate(segs):
        t = " ".join(sents[j] for j in idxs)
        out.append(Segment(doc_id, si, t, count_tokens(t)))
    return out


def key_sentences(text: str, max_sentences: int = 8) -> str:
    """Cheap extractive summary for the document-level index (NLTK stand-in):
    lead sentences + sentences dense in entities/numbers (attribute
    carriers), which is what makes a document's *subject* identifiable."""
    sents = split_sentences(text)
    if len(sents) <= max_sentences:
        return " ".join(sents)
    lead = sents[:2]

    def score(s: str) -> float:
        toks = s.split()
        if not toks:
            return 0.0
        carriers = sum(1 for i, t in enumerate(toks)
                       if any(c.isdigit() for c in t) or (i > 0 and t[:1].isupper()))
        return carriers / len(toks)

    rest = sorted(sents[2:], key=score, reverse=True)[: max_sentences - 2]
    return " ".join(lead + rest)
