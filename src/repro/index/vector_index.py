"""Vector indexes over L2 distance on L2-normalized embeddings (paper §4.2:
monotonically equivalent to cosine ranking).

`ExactIndex` is the oracle; `IVFIndex` (k-means coarse quantizer + nprobe)
is the scalable variant used at corpus scale. Both expose the same batched
contract — `search` (top-k), `range_search` (distance threshold tau/gamma),
and `range_search_many` (one fused pass over a probe batch, the API the
cross-document scheduler's `prefetch_segments` drives) — so either can back
a `TwoLevelRetriever` store. The hot loop delegates to
`repro.kernels.ops.topk_l2` (Pallas on TPU, jnp elsewhere).
"""
from __future__ import annotations

import numpy as np

from .kmeans import kmeans


def _topk_l2(db: np.ndarray, q: np.ndarray, k: int):
    from repro.kernels import ops
    return ops.topk_l2(db, q, k)


def _live_distance(emb: np.ndarray, ids: list, dead: np.ndarray,
                   q: np.ndarray, id_) -> float:
    """Distance to the *live* occurrence of `id_` (scanned newest-first:
    a re-added id's tombstoned old row never shadows the live one)."""
    for i in range(len(ids) - 1, -1, -1):
        if ids[i] == id_ and not dead[i]:
            return float(np.sqrt(((emb[i] - q) ** 2).sum()))
    raise ValueError(f"{id_!r} is not in the index")


class ExactIndex:
    """Exact store, now incrementally maintainable (DESIGN.md §17):
    `add` appends rows, `remove` tombstones them (searches filter dead
    rows), and compaction rebuilds the dense arrays once the dead fraction
    crosses `compact_ratio` — removal cost stays amortized O(1) per row
    instead of O(N) per mutation."""

    def __init__(self, embeddings: np.ndarray, ids: list | None = None, *,
                 compact_ratio: float = 0.25):
        self.emb = np.asarray(embeddings, np.float32)
        self.ids = list(ids) if ids is not None else list(range(len(self.emb)))
        self.compact_ratio = compact_ratio
        self._dead = np.zeros(len(self.ids), bool)
        self._n_dead = 0
        self.maint_stats = {"adds": 0, "removes": 0, "compactions": 0}

    def __len__(self):
        return len(self.ids) - self._n_dead

    # -------------------------------------------------------- maintenance --

    @property
    def n_tombstones(self) -> int:
        return self._n_dead

    def live_ids(self) -> list:
        if not self._n_dead:
            return list(self.ids)
        return [id_ for i, id_ in enumerate(self.ids) if not self._dead[i]]

    def add(self, embeddings: np.ndarray, ids: list) -> None:
        embs = np.atleast_2d(np.asarray(embeddings, np.float32))
        self.emb = embs.copy() if not len(self.ids) else \
            np.concatenate([self.emb, embs])
        self.ids.extend(ids)
        self._dead = np.concatenate([self._dead, np.zeros(len(embs), bool)])
        self.maint_stats["adds"] += len(embs)

    def remove(self, ids) -> int:
        """Tombstone every live row carrying one of `ids`; compacts when
        the dead fraction crosses `compact_ratio`. Returns rows removed."""
        idset = set(ids)
        n = 0
        for i, id_ in enumerate(self.ids):
            if id_ in idset and not self._dead[i]:
                self._dead[i] = True
                n += 1
        self._n_dead += n
        self.maint_stats["removes"] += n
        if self.ids and self._n_dead > self.compact_ratio * len(self.ids):
            self.compact()
        return n

    def compact(self) -> None:
        if not self._n_dead:
            return
        keep = ~self._dead
        self.emb = self.emb[keep]
        self.ids = [id_ for i, id_ in enumerate(self.ids) if keep[i]]
        self._dead = np.zeros(len(self.ids), bool)
        self._n_dead = 0
        self.maint_stats["compactions"] += 1

    # ------------------------------------------------------------- search --

    def search(self, q: np.ndarray, k: int):
        """q: (d,) or (m, d). Returns (ids, dists) per query."""
        q = np.atleast_2d(np.asarray(q, np.float32))
        k = min(k, len(self))
        if k == 0 or not len(self):
            return [([], [])] * len(q)
        # over-fetch by the tombstone count so dead rows can never displace
        # live ones from the top-k, then filter per row
        kk = min(k + self._n_dead, len(self.ids))
        dists, idx = _topk_l2(self.emb, q, kk)
        out = []
        for row_d, row_i in zip(np.asarray(dists), np.asarray(idx)):
            if self._n_dead:
                keep = ~self._dead[np.asarray(row_i, int)]
                row_d, row_i = row_d[keep][:k], row_i[keep][:k]
            out.append(([self.ids[int(i)] for i in row_i], [float(d) for d in row_d]))
        return out

    def _ranked(self, qs: np.ndarray):
        """Full ascending ranking per query: (dists (M, N), idx (M, N)).
        Large databases go through the `kernels.topk_l2` kernel with k = N
        (same gate as kernels.ops.topk_l2); small ones use a numpy
        broadcast. Serial and batched range search share this helper, so
        they agree per query at every database size."""
        if len(self.ids) >= 256:
            dists, idx = _topk_l2(self.emb, qs, len(self.ids))
            return np.asarray(dists), np.asarray(idx)
        d = np.sqrt(np.maximum(
            ((self.emb[None] - qs[:, None]) ** 2).sum(-1), 0.0))
        idx = np.argsort(d, axis=1)
        return np.take_along_axis(d, idx, axis=1), idx

    def range_search(self, q: np.ndarray, tau: float):
        """All ids with L2 distance < tau, sorted ascending by distance."""
        (out,) = self.range_search_many(np.asarray(q, np.float32)[None], [tau])
        return out

    def range_search_many(self, qs: np.ndarray, taus):
        """Batched range search: qs (M, D), taus length-M. One fused
        distance + rank pass for the whole probe batch — the vectorized
        path the cross-document scheduler uses to retrieve segments for a
        batch of (doc, attr) pairs at once."""
        qs = np.atleast_2d(np.asarray(qs, np.float32))
        if not len(self):
            return [([], [])] * len(qs)
        dists, idx = self._ranked(qs)
        out = []
        for row_d, row_i, tau in zip(dists, idx, taus):
            keep = row_d < tau
            if self._n_dead:
                keep = keep & ~self._dead[np.asarray(row_i, int)]
            out.append(([self.ids[int(i)] for i in row_i[keep]],
                        [float(d) for d in row_d[keep]]))
        return out

    def distance(self, q: np.ndarray, id_) -> float:
        return _live_distance(self.emb, self.ids, self._dead, q, id_)


class IVFIndex:
    """Inverted-file index: coarse k-means partitions, probe `nprobe` lists.

    Approximate; recall controlled by nprobe. Used for corpus-scale document/
    segment stores (paper cites PQ/HNSW — IVF is the TPU-friendly choice: the
    probed lists become dense tiles for the topk_l2 kernel)."""

    def __init__(self, embeddings: np.ndarray, ids: list | None = None,
                 n_lists: int = 16, nprobe: int = 4, seed: int = 0, *,
                 recluster_ratio: float = 0.5, compact_ratio: float = 0.25):
        self.emb = np.asarray(embeddings, np.float32)
        self.ids = list(ids) if ids is not None else list(range(len(self.emb)))
        n_lists = max(1, min(n_lists, len(self.ids)))
        self.nprobe = max(1, min(nprobe, n_lists))
        centers, assign = kmeans(self.emb, n_lists, seed=seed)
        self.centers = np.array(centers, np.float32)  # writable: reclustering re-centers in place
        self.lists = [np.where(assign == c)[0] for c in range(len(self.centers))]
        # incremental maintenance (DESIGN.md §17): adds route to the nearest
        # center, removes tombstone; once a list's churn (adds+removes since
        # its last recluster) crosses recluster_ratio x its live size, that
        # list alone is re-centered and its members reassigned — bounded by
        # the list, never a global k-means rebuild.
        self.recluster_ratio = recluster_ratio
        self.compact_ratio = compact_ratio
        self._row_list = np.asarray(assign, np.int64).copy()  # row -> list
        self._dead = np.zeros(len(self.ids), bool)
        self._n_dead = 0
        self._churn = np.zeros(len(self.lists), np.int64)
        self.maint_stats = {"adds": 0, "removes": 0, "reclustered_lists": 0,
                            "migrated_rows": 0, "compactions": 0}

    def __len__(self):
        return len(self.ids) - self._n_dead

    # -------------------------------------------------------- maintenance --

    @property
    def n_tombstones(self) -> int:
        return self._n_dead

    def live_ids(self) -> list:
        if not self._n_dead:
            return list(self.ids)
        return [id_ for i, id_ in enumerate(self.ids) if not self._dead[i]]

    def add(self, embeddings: np.ndarray, ids: list) -> None:
        embs = np.atleast_2d(np.asarray(embeddings, np.float32))
        base = len(self.ids)
        self.emb = embs.copy() if not base else np.concatenate([self.emb, embs])
        self.ids.extend(ids)
        self._dead = np.concatenate([self._dead, np.zeros(len(embs), bool)])
        assign = np.argmin(
            ((self.centers[None] - embs[:, None]) ** 2).sum(-1), axis=1)
        self._row_list = np.concatenate([self._row_list, assign])
        touched = set()
        for off, li in enumerate(assign):
            li = int(li)
            self.lists[li] = np.append(self.lists[li], base + off)
            self._churn[li] += 1
            touched.add(li)
        self.maint_stats["adds"] += len(embs)
        for li in touched:
            self._maybe_recluster(li)

    def remove(self, ids) -> int:
        idset = set(ids)
        touched, n = set(), 0
        for i, id_ in enumerate(self.ids):
            if id_ in idset and not self._dead[i]:
                self._dead[i] = True
                li = int(self._row_list[i])
                self._churn[li] += 1
                touched.add(li)
                n += 1
        self._n_dead += n
        self.maint_stats["removes"] += n
        for li in touched:
            self._maybe_recluster(li)
        if self.ids and self._n_dead > self.compact_ratio * len(self.ids):
            self.compact()
        return n

    def _maybe_recluster(self, li: int) -> None:
        """Bounded per-list re-clustering: when churn crosses the ratio,
        drop the list's tombstoned rows, re-center it on its live members
        (k=1 k-means), and migrate members whose nearest center moved —
        work proportional to one list, never the whole index."""
        rows = self.lists[li]
        live = rows[~self._dead[rows]] if len(rows) else rows
        if self._churn[li] <= self.recluster_ratio * max(len(live), 1):
            return
        self._churn[li] = 0
        self.maint_stats["reclustered_lists"] += 1
        if not len(live):
            self.lists[li] = live
            return
        c = self.emb[live].mean(axis=0)
        self.centers[li] = c
        # reassign this list's members only (no recursive recluster: churn
        # lands on the target list and settles on its own threshold)
        assign = np.argmin(
            ((self.centers[None] - self.emb[live][:, None]) ** 2).sum(-1),
            axis=1)
        stay = live[assign == li]
        for row, tgt in zip(live[assign != li], assign[assign != li]):
            tgt = int(tgt)
            self.lists[tgt] = np.append(self.lists[tgt], row)
            self._row_list[row] = tgt
            self._churn[tgt] += 1
            self.maint_stats["migrated_rows"] += 1
        self.lists[li] = stay

    def compact(self) -> None:
        if not self._n_dead:
            return
        keep = ~self._dead
        new_row = np.cumsum(keep) - 1        # old row -> new row (keep only)
        self.emb = self.emb[keep]
        self.ids = [id_ for i, id_ in enumerate(self.ids) if keep[i]]
        self._row_list = self._row_list[keep]
        self.lists = [new_row[rows[keep[rows]]] if len(rows) else rows
                      for rows in self.lists]
        self._dead = np.zeros(len(self.ids), bool)
        self._n_dead = 0
        self.maint_stats["compactions"] += 1

    # ------------------------------------------------------------- search --

    def _probe(self, q: np.ndarray) -> np.ndarray:
        d = ((self.centers - q[None]) ** 2).sum(-1)
        lists = np.argsort(d)[: self.nprobe]
        rows = [self.lists[int(li)] for li in lists]
        rows = [r for r in rows if len(r)]
        probed = np.concatenate(rows) if rows else np.zeros((0,), np.int64)
        if self._n_dead and len(probed):
            probed = probed[~self._dead[probed]]
        return probed

    def _ranked_rows(self, q: np.ndarray):
        """Probed rows of one query, ranked ascending by distance: (rows,
        dists). Large probe sets go through the `kernels.topk_l2` kernel
        with k = |probed| (the same gate as `ExactIndex._ranked`); small
        ones use a numpy broadcast. `search`/`range_search`/
        `range_search_many` all share this helper."""
        rows = self._probe(q)
        if not len(rows):
            return rows, np.zeros((0,), np.float32)
        sub = self.emb[rows]
        if len(rows) >= 256:
            dists, idx = _topk_l2(sub, q[None], len(rows))
            d, order = np.asarray(dists)[0], np.asarray(idx)[0]
        else:
            d = np.sqrt(np.maximum(((sub - q[None]) ** 2).sum(-1), 0.0))
            order = np.argsort(d)
            d = d[order]
        return rows[order], d

    def search(self, q: np.ndarray, k: int):
        q = np.atleast_2d(np.asarray(q, np.float32))
        out = []
        for qq in q:
            rows, d = self._ranked_rows(qq)
            n = min(k, len(rows))
            out.append(([self.ids[int(r)] for r in rows[:n]],
                        [float(x) for x in d[:n]]))
        return out

    def range_search(self, q: np.ndarray, tau: float):
        (out,) = self.range_search_many(np.asarray(q, np.float32)[None], [tau])
        return out

    def range_search_many(self, qs: np.ndarray, taus):
        """Batched range search over the probed lists: qs (M, D), taus
        length-M. Same contract as `ExactIndex.range_search_many` (the
        scheduler's vectorized retrieval path), approximate by nprobe."""
        qs = np.atleast_2d(np.asarray(qs, np.float32))
        out = []
        for qq, tau in zip(qs, taus):
            rows, d = self._ranked_rows(qq)
            keep = d < tau
            out.append(([self.ids[int(r)] for r in rows[keep]],
                        [float(x) for x in d[keep]]))
        return out

    def distance(self, q: np.ndarray, id_) -> float:
        return _live_distance(self.emb, self.ids, self._dead, q, id_)
