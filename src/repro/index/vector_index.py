"""Vector indexes over L2 distance on L2-normalized embeddings (paper §4.2:
monotonically equivalent to cosine ranking).

`ExactIndex` is the oracle; `IVFIndex` (k-means coarse quantizer + nprobe)
is the scalable variant used at corpus scale. Both expose the same batched
contract — `search` (top-k), `range_search` (distance threshold tau/gamma),
and `range_search_many` (one fused pass over a probe batch, the API the
cross-document scheduler's `prefetch_segments` drives) — so either can back
a `TwoLevelRetriever` store. The hot loop delegates to
`repro.kernels.ops.topk_l2` (Pallas on TPU, jnp elsewhere).
"""
from __future__ import annotations

import numpy as np

from .kmeans import kmeans


def _topk_l2(db: np.ndarray, q: np.ndarray, k: int):
    from repro.kernels import ops
    return ops.topk_l2(db, q, k)


def _exact_distance(emb: np.ndarray, ids: list, q: np.ndarray, id_) -> float:
    i = ids.index(id_)
    return float(np.sqrt(((emb[i] - q) ** 2).sum()))


class ExactIndex:
    def __init__(self, embeddings: np.ndarray, ids: list | None = None):
        self.emb = np.asarray(embeddings, np.float32)
        self.ids = list(ids) if ids is not None else list(range(len(self.emb)))

    def __len__(self):
        return len(self.ids)

    def search(self, q: np.ndarray, k: int):
        """q: (d,) or (m, d). Returns (ids, dists) per query."""
        q = np.atleast_2d(np.asarray(q, np.float32))
        k = min(k, len(self.ids))
        if k == 0 or not len(self.ids):
            return [([], [])] * len(q)
        dists, idx = _topk_l2(self.emb, q, k)
        out = []
        for row_d, row_i in zip(np.asarray(dists), np.asarray(idx)):
            out.append(([self.ids[int(i)] for i in row_i], [float(d) for d in row_d]))
        return out

    def _ranked(self, qs: np.ndarray):
        """Full ascending ranking per query: (dists (M, N), idx (M, N)).
        Large databases go through the `kernels.topk_l2` kernel with k = N
        (same gate as kernels.ops.topk_l2); small ones use a numpy
        broadcast. Serial and batched range search share this helper, so
        they agree per query at every database size."""
        if len(self.ids) >= 256:
            dists, idx = _topk_l2(self.emb, qs, len(self.ids))
            return np.asarray(dists), np.asarray(idx)
        d = np.sqrt(np.maximum(
            ((self.emb[None] - qs[:, None]) ** 2).sum(-1), 0.0))
        idx = np.argsort(d, axis=1)
        return np.take_along_axis(d, idx, axis=1), idx

    def range_search(self, q: np.ndarray, tau: float):
        """All ids with L2 distance < tau, sorted ascending by distance."""
        (out,) = self.range_search_many(np.asarray(q, np.float32)[None], [tau])
        return out

    def range_search_many(self, qs: np.ndarray, taus):
        """Batched range search: qs (M, D), taus length-M. One fused
        distance + rank pass for the whole probe batch — the vectorized
        path the cross-document scheduler uses to retrieve segments for a
        batch of (doc, attr) pairs at once."""
        qs = np.atleast_2d(np.asarray(qs, np.float32))
        if not len(self.ids):
            return [([], [])] * len(qs)
        dists, idx = self._ranked(qs)
        out = []
        for row_d, row_i, tau in zip(dists, idx, taus):
            keep = row_d < tau
            out.append(([self.ids[int(i)] for i in row_i[keep]],
                        [float(d) for d in row_d[keep]]))
        return out

    def distance(self, q: np.ndarray, id_) -> float:
        return _exact_distance(self.emb, self.ids, q, id_)


class IVFIndex:
    """Inverted-file index: coarse k-means partitions, probe `nprobe` lists.

    Approximate; recall controlled by nprobe. Used for corpus-scale document/
    segment stores (paper cites PQ/HNSW — IVF is the TPU-friendly choice: the
    probed lists become dense tiles for the topk_l2 kernel)."""

    def __init__(self, embeddings: np.ndarray, ids: list | None = None,
                 n_lists: int = 16, nprobe: int = 4, seed: int = 0):
        self.emb = np.asarray(embeddings, np.float32)
        self.ids = list(ids) if ids is not None else list(range(len(self.emb)))
        n_lists = max(1, min(n_lists, len(self.ids)))
        self.nprobe = max(1, min(nprobe, n_lists))
        self.centers, assign = kmeans(self.emb, n_lists, seed=seed)
        self.lists = [np.where(assign == c)[0] for c in range(len(self.centers))]

    def __len__(self):
        return len(self.ids)

    def _probe(self, q: np.ndarray) -> np.ndarray:
        d = ((self.centers - q[None]) ** 2).sum(-1)
        lists = np.argsort(d)[: self.nprobe]
        rows = [self.lists[int(li)] for li in lists]
        rows = [r for r in rows if len(r)]
        return np.concatenate(rows) if rows else np.zeros((0,), np.int64)

    def _ranked_rows(self, q: np.ndarray):
        """Probed rows of one query, ranked ascending by distance: (rows,
        dists). Large probe sets go through the `kernels.topk_l2` kernel
        with k = |probed| (the same gate as `ExactIndex._ranked`); small
        ones use a numpy broadcast. `search`/`range_search`/
        `range_search_many` all share this helper."""
        rows = self._probe(q)
        if not len(rows):
            return rows, np.zeros((0,), np.float32)
        sub = self.emb[rows]
        if len(rows) >= 256:
            dists, idx = _topk_l2(sub, q[None], len(rows))
            d, order = np.asarray(dists)[0], np.asarray(idx)[0]
        else:
            d = np.sqrt(np.maximum(((sub - q[None]) ** 2).sum(-1), 0.0))
            order = np.argsort(d)
            d = d[order]
        return rows[order], d

    def search(self, q: np.ndarray, k: int):
        q = np.atleast_2d(np.asarray(q, np.float32))
        out = []
        for qq in q:
            rows, d = self._ranked_rows(qq)
            n = min(k, len(rows))
            out.append(([self.ids[int(r)] for r in rows[:n]],
                        [float(x) for x in d[:n]]))
        return out

    def range_search(self, q: np.ndarray, tau: float):
        (out,) = self.range_search_many(np.asarray(q, np.float32)[None], [tau])
        return out

    def range_search_many(self, qs: np.ndarray, taus):
        """Batched range search over the probed lists: qs (M, D), taus
        length-M. Same contract as `ExactIndex.range_search_many` (the
        scheduler's vectorized retrieval path), approximate by nprobe."""
        qs = np.atleast_2d(np.asarray(qs, np.float32))
        out = []
        for qq, tau in zip(qs, taus):
            rows, d = self._ranked_rows(qq)
            keep = d < tau
            out.append(([self.ids[int(r)] for r in rows[keep]],
                        [float(x) for x in d[keep]]))
        return out

    def distance(self, q: np.ndarray, id_) -> float:
        return _exact_distance(self.emb, self.ids, q, id_)
