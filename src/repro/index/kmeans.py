"""K-means in JAX (evidence clustering, paper §4.2; also the IVF coarse
quantizer). k-means++ init (numpy, deterministic) + jit'd Lloyd iterations."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _kmeanspp_init(x: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    n = x.shape[0]
    centers = [x[rng.integers(n)]]
    for _ in range(1, k):
        d2 = np.min(((x[:, None, :] - np.stack(centers)[None]) ** 2).sum(-1), axis=1)
        tot = d2.sum()
        if tot <= 1e-12:
            centers.append(x[rng.integers(n)])
            continue
        centers.append(x[rng.choice(n, p=d2 / tot)])
    return np.stack(centers)


@jax.jit
def _lloyd_step(x, centers):
    d2 = ((x[:, None, :] - centers[None]) ** 2).sum(-1)          # (n, k)
    assign = jnp.argmin(d2, axis=1)
    onehot = jax.nn.one_hot(assign, centers.shape[0], dtype=x.dtype)
    counts = onehot.sum(0)
    sums = onehot.T @ x
    new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1),
                    centers)
    return new, assign


def kmeans(x: np.ndarray, k: int, *, iters: int = 25, seed: int = 0):
    """Returns (centers (k,d), assignments (n,)). Deterministic."""
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    if n == 0:
        return np.zeros((0, x.shape[1] if x.ndim == 2 else 0), np.float32), np.zeros((0,), np.int32)
    k = min(k, n)
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(_kmeanspp_init(x, k, rng))
    xj = jnp.asarray(x)
    assign = None
    for _ in range(iters):
        centers, assign = _lloyd_step(xj, centers)
    return np.asarray(centers), np.asarray(assign)
