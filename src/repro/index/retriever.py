"""Two-level index + evidence-augmented retrieval (paper §4), plus the
ablation/baseline retrieval modes used by the benchmark suite.

Modes:
  quest        two-level index + evidence-augmented segment retrieval
  segment_only no document-level filter (Fig. 8-a ablation)
  no_evidence  query-attr embedding only, no evidence (Fig. 8-b ablation)
  llm_evidence synthetic (template/"LLM"-generated) evidence only (Fig. 8-b)
  rag_topk     classic RAG: top-k segments by query embedding, no doc level
  fulldoc      Lotus-like: the whole document is the "segment"
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data.tokens import count_tokens
from .embedder import HashedEmbedder
from .kmeans import kmeans
from .segmenter import Segment, key_sentences, segment_document
from .vector_index import ExactIndex, IVFIndex


def synth_evidence_texts(attr: str, description: str) -> list[str]:
    """LLM-synthesized-evidence stand-in (paper: prompt the LLM for ~20
    representative segments when the sample yields none)."""
    a = attr.replace("_", " ")
    return [
        description,
        f"The {a} is reported as 42.",
        f"Its {a} was 17 according to the records.",
        f"{a.title()}: Example Value.",
        f"With a {a} of 23, it ranks among the highest.",
        f"The {a} of the subject is Example.",
    ]


@dataclass
class _AttrState:
    evidence_texts: list = field(default_factory=list)
    evidence_docs: list = field(default_factory=list)  # provenance, parallel
    evidence_emb: np.ndarray | None = None
    probes: np.ndarray | None = None       # kmeans centers
    probe_radii: np.ndarray | None = None  # per-cluster radii (beyond-paper)
    gamma: float = 0.9


class TwoLevelRetriever:
    def __init__(self, corpus, embedder: HashedEmbedder | None = None, *,
                 mode: str = "quest", evidence_k: int = 3,
                 tau_init: float = 1.7, gamma_init: float = 1.25,
                 rag_k: int = 3, threshold_slack: float = 0.1,
                 per_evidence_radius: bool = True,
                 cluster_radius_floor: float = 1.3,
                 approx_threshold: int = 2048,
                 ivf_n_lists: int = 64, ivf_nprobe: int = 8,
                 refit_idf: bool = True):
        self.corpus = corpus
        self.embedder = embedder or HashedEmbedder()
        self.mode = mode
        self.evidence_k = evidence_k
        self.tau_init = tau_init
        self.gamma_init = gamma_init
        self.rag_k = rag_k
        self.slack = threshold_slack
        self.per_evidence_radius = per_evidence_radius and mode == "quest"
        self.cluster_radius_floor = cluster_radius_floor
        # stores at/above this many vectors use the approximate IVF index
        # (exact below it — small corpora keep bit-identical retrieval)
        self.approx_threshold = approx_threshold
        self.ivf_n_lists = ivf_n_lists
        self.ivf_nprobe = ivf_nprobe
        # refit_idf=False builds on the embedder's existing idf — the
        # rebuild-from-scratch parity oracle of a live corpus must share the
        # live retriever's frozen idf (live mutation never refits; DESIGN.md
        # §17), so the rebuilt embeddings stay byte-identical.
        self.refit_idf = refit_idf
        self._version = 0
        self._attr_state: dict = {}         # (table, attr) -> _AttrState
        self._tau: dict = {}                # table -> refined tau
        self._doc_center: dict = {}         # table -> evidence-centered query emb
        self._query_emb_cache: dict = {}
        self._seg_cache: dict = {}          # (doc, attr, version) -> [Segment]
        self._margin_cache: dict = {}       # (doc, attr, table, version) -> margin
        # beyond-paper: re-center the document-level query on the summaries
        # of known-relevant sampled docs (evidence augmentation applied to
        # the doc level, symmetric to the paper's segment-level evidence).
        # Disable for the paper-faithful ablation.
        self.doc_evidence = mode == "quest"
        self._build()

    def fork(self) -> "TwoLevelRetriever":
        """Per-query session: shares the (expensive, query-independent)
        indexes but gets fresh evidence/threshold state — query executions
        must not contaminate each other (paper: evidence is collected per
        query during its sampling phase)."""
        new = object.__new__(type(self))
        new.__dict__.update(self.__dict__)
        new._attr_state = {}
        new._tau = {}
        new._doc_center = {}
        new._seg_cache = {}
        new._margin_cache = {}
        new._version = 0
        return new

    # ------------------------------------------------------------- build --

    def _make_index(self, embs: np.ndarray, ids: list):
        """Exact store below `approx_threshold` vectors, IVF at corpus
        scale — both satisfy the same batched search contract."""
        if len(ids) >= self.approx_threshold:
            return IVFIndex(embs, ids, n_lists=self.ivf_n_lists,
                            nprobe=self.ivf_nprobe)
        return ExactIndex(embs, ids)

    def _build(self):
        self.doc_segments: dict = {}
        self.seg_index: dict = {}
        doc_ids, summaries = [], []
        for doc_id, doc in self.corpus.docs.items():
            segs = segment_document(doc_id, doc.text, self.embedder)
            self.doc_segments[doc_id] = segs
            doc_ids.append(doc_id)
            summaries.append(key_sentences(doc.text))
        # idf over the whole segment collection sharpens domain separation
        if self.refit_idf:
            all_seg_texts = [s.text for segs in self.doc_segments.values() for s in segs]
            self.embedder.fit(all_seg_texts)
        for doc_id in doc_ids:
            segs = self.doc_segments[doc_id]
            embs = self.embedder.embed([s.text for s in segs])
            self.seg_index[doc_id] = self._make_index(embs, list(range(len(segs))))
        self.doc_index = self._make_index(self.embedder.embed(summaries), doc_ids)
        self._doc_emb = {d: self.doc_index.emb[i] for i, d in enumerate(doc_ids)}

    # ------------------------------------------------------------ helpers --

    def _attr_query_emb(self, table: str, attr: str) -> np.ndarray:
        key = (table, attr)
        if key not in self._query_emb_cache:
            desc = self.corpus.attr_description(table, attr)
            self._query_emb_cache[key] = self.embedder.embed_one(f"{attr} {desc}")
        return self._query_emb_cache[key]

    def _state(self, table: str, attr: str) -> _AttrState:
        return self._attr_state.setdefault((table, attr), _AttrState(gamma=self.gamma_init))

    def _query_emb(self, table: str, attrs: list) -> np.ndarray:
        embs = [self._attr_query_emb(table, a) for a in attrs]
        e = np.mean(embs, axis=0)
        return e / max(np.linalg.norm(e), 1e-6)

    # --------------------------------------------------- document level ----

    def candidate_docs(self, table: str, attrs: list) -> list:
        """Distance-ranked candidates. Modes without a document-level filter
        still return a *ranked* list (they own the same embeddings; they just
        never prune), so the engine's rank-stratified sampling is fair."""
        table_docs = set(self.corpus.tables[table])
        if self.mode == "fulldoc":
            return sorted(table_docs)
        qe = self._query_emb(table, attrs)
        if self.mode in ("segment_only", "rag_topk"):
            # rank, no filter: computed exactly over the stored doc
            # embeddings — an approximate doc_index (IVF at scale) must not
            # silently drop the unprobed documents these modes never prune
            docs = sorted(table_docs)
            dist = np.linalg.norm(
                np.stack([self._doc_emb[d] for d in docs]) - qe[None], axis=1)
            return [docs[i] for i in np.argsort(dist, kind="stable")]
        tau = self._tau.get(table, self.tau_init)
        center = self._doc_center.get(table, qe)
        ids, _ = self.doc_index.range_search(center, tau)
        return [d for d in ids if d in table_docs]

    def refine_candidates(self, table: str, attrs: list) -> list:
        return self.candidate_docs(table, attrs)

    # ----------------------------------------------------- evidence --------

    def add_evidence(self, table: str, attr: str, segments: list, doc_id=None):
        """`doc_id` records provenance: under a live corpus, evidence
        collected from a document that later mutates must be dropped
        (`absorb_doc_churn`), and provenance is what identifies it."""
        if self.mode in ("no_evidence", "rag_topk", "fulldoc", "llm_evidence"):
            return
        st = self._state(table, attr)
        st.evidence_texts.extend(segments)
        st.evidence_docs.extend([doc_id] * len(segments))
        self._version += 1

    def reset_table_state(self, table: str) -> None:
        """Drop every piece of per-query-derived state for `table`:
        evidence, probes, refined tau, and the evidence-centered doc query.
        The live cascade calls this when a mutation invalidates the sample
        the state was fitted from (DESIGN.md §17) — the next query re-samples
        and re-fits from scratch, exactly like a fresh session."""
        for key in [k for k in self._attr_state if k[0] == table]:
            del self._attr_state[key]
        self._tau.pop(table, None)
        self._doc_center.pop(table, None)
        self._version += 1

    def absorb_doc_churn(self, doc_id) -> int:
        """Drop evidence that originated in `doc_id` and re-fit the probe
        clusters of every attr that held some — incremental absorption of
        segment churn (the evidence cluster geometry follows the corpus
        without a global rebuild). Returns the number of evidence texts
        dropped."""
        dropped = 0
        for (table, attr), st in list(self._attr_state.items()):
            if doc_id not in st.evidence_docs:
                continue
            keep = [i for i, d in enumerate(st.evidence_docs) if d != doc_id]
            dropped += len(st.evidence_docs) - len(keep)
            st.evidence_texts = [st.evidence_texts[i] for i in keep]
            st.evidence_docs = [st.evidence_docs[i] for i in keep]
            if st.probes is not None:
                # state was finalized: re-fit this attr's probes in place
                self._fit_attr_probes(table, attr)
        if dropped:
            self._version += 1
        return dropped

    def finalize_thresholds(self, table: str, attrs: list, stats):
        """Automatic tau/gamma (paper §4.2 'Setting the Threshold')."""
        self._version += 1
        if self.mode in ("rag_topk", "fulldoc"):
            return
        # tau: from sampled docs that yielded values (D_Q^m, relevant) vs.
        # those that yielded none (D_Q^n, irrelevant) — paper §4.2 rule
        # (max relevant distance + slack), widened to the irrelevant margin
        # when the sample shows a clean gap (sampled max underestimates the
        # population max; the gap midpoint is the safer cut).
        sampled, relevant = set(), set()
        for attr in attrs:
            for doc_id, v in stats.sampled_values.get(attr, {}).items():
                sampled.add(doc_id)
                if v is not None:
                    relevant.add(doc_id)
        irrelevant = sampled - relevant
        if relevant and self.mode != "segment_only":
            qe = self._query_emb(table, attrs)
            if self.doc_evidence:
                c = np.mean([self._doc_emb[d] for d in relevant], axis=0)
                qe = c / max(np.linalg.norm(c), 1e-6)
                self._doc_center[table] = qe
            drel = sorted(float(np.linalg.norm(self._doc_emb[d] - qe)) for d in relevant)
            dmax, dmed = drel[-1], drel[len(drel) // 2]
            # sampled max underestimates the population max: extrapolate by
            # the observed upper spread (clamped), never below paper's +slack
            tau = dmax + min(max(self.slack, 2.0 * (dmax - dmed)), 0.35)
            if irrelevant:
                dmin_irr = min(float(np.linalg.norm(self._doc_emb[d] - qe))
                               for d in irrelevant)
                tau = max(tau, dmin_irr - self.slack)
            self._tau[table] = tau
        # gamma_i per attr + evidence clustering
        for attr in attrs:
            self._fit_attr_probes(table, attr)

    def _fit_attr_probes(self, table: str, attr: str) -> None:
        """(Re-)fit one attr's probe clusters from its current evidence —
        the per-attr tail of `finalize_thresholds`, also invoked standalone
        by `absorb_doc_churn` when live mutations drop evidence texts."""
        st = self._state(table, attr)
        texts = st.evidence_texts
        if self.mode == "llm_evidence" or (self.mode == "quest" and not texts):
            texts = synth_evidence_texts(attr, self.corpus.attr_description(table, attr))
            st.evidence_texts = texts
            st.evidence_docs = [None] * len(texts)
        if self.mode == "no_evidence" or not texts:
            st.probes = self._attr_query_emb(table, attr)[None]
            st.gamma = self.gamma_init
            return
        embs = self.embedder.embed(texts)
        st.evidence_emb = embs
        centers, assign = kmeans(embs, min(self.evidence_k, len(texts)), seed=7)
        norms = np.maximum(np.linalg.norm(centers, axis=1, keepdims=True), 1e-6)
        st.probes = centers / norms
        # Beyond-paper (DESIGN.md §8): *per-cluster* radii. The paper's
        # global gamma = max pairwise evidence distance explodes when
        # evidence spans several phrasing templates (it then swallows
        # whole documents on long corpora); each k-means cluster is one
        # template, whose members sit tightly around their center.
        if self.per_evidence_radius:
            radii = []
            for j in range(len(centers)):
                members = embs[assign == j]
                if len(members):
                    dj = np.sqrt(np.maximum(
                        ((members - st.probes[j]) ** 2).sum(-1), 0.0)).max()
                else:
                    dj = 0.0
                radii.append(max(dj + self.slack, self.cluster_radius_floor))
            st.probe_radii = np.asarray(radii)
        if len(embs) >= 2:
            d = np.sqrt(np.maximum(
                ((embs[:, None] - embs[None]) ** 2).sum(-1), 0.0))
            # paper rule, floored at gamma_init: a tight sample must not
            # collapse the radius (used when per_evidence_radius=False)
            st.gamma = max(float(d.max()) + self.slack, self.gamma_init)
        else:
            st.gamma = self.gamma_init

    # ------------------------------------------------------ segment level --

    def _probes_for(self, table: str, attr: str):
        """(probes (P, D), radii length-P) for quest-family modes: evidence
        cluster centers + the base query embedding ("evidence zero") — the
        merge-and-dedup of paper §4.2 across all probes."""
        st = self._state(table, attr)
        qe = self._attr_query_emb(table, attr)
        if st.probes is None:
            return qe[None], [self.gamma_init]
        probes = np.concatenate([st.probes, qe[None]], axis=0)
        if self.per_evidence_radius and st.probe_radii is not None:
            radii = list(st.probe_radii) + [self.gamma_init]
        else:
            radii = [st.gamma] * len(probes)
        return probes, radii

    def _segments_for(self, doc_id, attr: str, table: str | None = None) -> list[Segment]:
        doc = self.corpus.docs[doc_id]
        table = table or doc.table   # evidence state belongs to the QUERY table
        segs = self.doc_segments[doc_id]
        if self.mode == "fulldoc":
            return [Segment(doc_id, -1, doc.text, count_tokens(doc.text))]
        idx = self.seg_index[doc_id]
        if self.mode == "rag_topk":
            (ids, _), = idx.search(self._attr_query_emb(table, attr), self.rag_k)
            return [segs[i] for i in sorted(ids)]
        probes, radii = self._probes_for(table, attr)
        hit: set = set()
        for pe, rad in zip(probes, radii):
            ids, _ = idx.range_search(pe, rad)
            hit.update(ids)
        return [segs[i] for i in sorted(hit)]

    def prefetch_segments(self, pairs) -> None:
        """Batched retrieval (DESIGN.md §9): fill the segment cache for many
        (doc_id, attr, table) pairs at once. All probes of all requested
        attributes of one document go through a single vectorized
        distance+rank pass (`range_search_many`) instead of one range search
        per probe — per query the hits are identical to `segments`."""
        todo: dict = {}
        for doc_id, attr, table in pairs:
            key = (doc_id, attr, table, self._version)
            if key not in self._seg_cache and (doc_id, attr, table) not in todo:
                todo[(doc_id, attr, table)] = key
        by_doc: dict = {}
        for (doc_id, attr, table), key in todo.items():
            if self.mode in ("fulldoc", "rag_topk"):
                self._seg_cache[key] = self._segments_for(doc_id, attr, table)
            else:
                by_doc.setdefault(doc_id, []).append((attr, table, key))
        for doc_id, entries in by_doc.items():
            segs = self.doc_segments[doc_id]
            idx = self.seg_index[doc_id]
            owners, probes_all, radii_all = [], [], []
            for j, (attr, table, _key) in enumerate(entries):
                t = table or self.corpus.docs[doc_id].table
                probes, radii = self._probes_for(t, attr)
                owners.extend([j] * len(probes))
                probes_all.append(probes)
                radii_all.extend(radii)
            res = idx.range_search_many(np.concatenate(probes_all, axis=0),
                                        radii_all)
            hits: list[set] = [set() for _ in entries]
            for j, (ids, _d) in zip(owners, res):
                hits[j].update(ids)
            for (attr, table, key), hit in zip(entries, hits):
                self._seg_cache[key] = [segs[i] for i in sorted(hit)]

    def segments(self, doc_id, attr: str, table: str | None = None) -> list[str]:
        key = (doc_id, attr, table, self._version)
        if key not in self._seg_cache:
            self._seg_cache[key] = self._segments_for(doc_id, attr, table)
        return [s.text for s in self._seg_cache[key]]

    def segment_tokens(self, doc_id, attr: str, table: str | None = None) -> int:
        key = (doc_id, attr, table, self._version)
        if key not in self._seg_cache:
            self._seg_cache[key] = self._segments_for(doc_id, attr, table)
        return sum(s.tokens for s in self._seg_cache[key])

    def score_margin(self, doc_id, attr: str,
                     table: str | None = None):
        """Normalized retrieval confidence in [0, 1] for (doc, attr) —
        the difficulty-estimation signal of DESIGN.md §18: how far inside
        the attribute's probe radii the document's best segment sits
        (1 = dead-center on a known phrasing template, 0 = scraping the
        radius or outside every probe). `rag_topk` has no radii, so its
        margin is measured against `gamma_init`; `fulldoc` retrieval has
        no segment ranking at all and returns None (neutral). Cached per
        index version, so live mutations invalidate exactly like the
        segment cache."""
        doc = self.corpus.docs.get(doc_id)
        if doc is None or doc_id not in self.seg_index:
            return None
        table = table or doc.table
        key = (doc_id, attr, table, self._version)
        if key in self._margin_cache:
            return self._margin_cache[key]
        idx = self.seg_index[doc_id]
        margin = None
        if self.mode != "fulldoc" and len(idx):
            if self.mode == "rag_topk":
                probes = self._attr_query_emb(table, attr)[None]
                radii = [self.gamma_init]
            else:
                probes, radii = self._probes_for(table, attr)
            best = None
            for (ids, dists), rad in zip(idx.search(probes, 1), radii):
                if len(ids) and rad > 0:
                    m = (rad - float(dists[0])) / rad
                    best = m if best is None else max(best, m)
            if best is not None:
                margin = min(1.0, max(0.0, best))
        self._margin_cache[key] = margin
        return margin
