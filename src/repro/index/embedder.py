"""Deterministic JAX text embedder (E5 stand-in; see DESIGN.md §8.2).

Hashed unigram+bigram features -> fixed random projection -> L2 normalize.
Cosine similarity of the embeddings tracks lexical/phrasal overlap, which is
what the two-level index and evidence augmentation exploit; every method in
the benchmarks shares this embedder so comparisons stay controlled.

Batched feature->embedding projection runs under jit (it is also the math
the `topk_l2` Pallas kernel consumes at corpus scale).
"""
from __future__ import annotations

import hashlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokens import words

N_FEATURES = 4096
EMBED_DIM = 256


def _hash(token: str) -> int:
    return int.from_bytes(hashlib.blake2b(token.encode(), digest_size=4).digest(), "little")


def _feature_counts(text: str) -> np.ndarray:
    ws = words(text)
    v = np.zeros((N_FEATURES,), np.float32)
    for w in ws:
        v[_hash(w) % N_FEATURES] += 1.0
    for a, b in zip(ws, ws[1:]):
        v[_hash(a + "_" + b) % N_FEATURES] += 0.5
    return v


class HashedEmbedder:
    """Deterministic tf-idf hashed embedder. `fit(texts)` learns bucket idf
    weights over a reference collection (the corpus segments), which is what
    gives document/domain separation; without fit, idf=1."""

    def __init__(self, dim: int = EMBED_DIM, seed: int = 42):
        self.dim = dim
        key = jax.random.PRNGKey(seed)
        self._proj = jax.random.normal(key, (N_FEATURES, dim), jnp.float32) / np.sqrt(dim)
        self._idf = np.ones((N_FEATURES,), np.float32)
        self._project = jax.jit(self._project_fn)

    def fit(self, texts: list[str]):
        df = np.zeros((N_FEATURES,), np.float32)
        for t in texts:
            nz = _feature_counts(t) > 0
            df += nz
        n = max(len(texts), 1)
        self._idf = np.log((n + 1.0) / (df + 1.0)).astype(np.float32) + 1.0
        return self

    def _project_fn(self, feats):
        emb = feats @ self._proj
        norm = jnp.linalg.norm(emb, axis=-1, keepdims=True)
        return emb / jnp.maximum(norm, 1e-6)

    def embed(self, texts: list[str], _chunk: int = 1024) -> np.ndarray:
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        outs = []
        for i in range(0, len(texts), _chunk):
            feats = np.stack([_feature_counts(t) for t in texts[i:i + _chunk]])
            # (1 + log tf) * idf
            feats = np.log1p(feats) * self._idf[None, :]
            outs.append(np.asarray(self._project(jnp.asarray(feats))))
        return np.concatenate(outs) if len(outs) > 1 else outs[0]

    def embed_one(self, text: str) -> np.ndarray:
        return self.embed([text])[0]
