"""Sampling-phase statistics (paper §2.2 / §4.2).

QUEST samples ~5% of the candidate documents, extracts every query attribute
with the LLM, and derives from that single pass: (a) per-filter selectivities,
(b) average per-attribute extraction costs, (c) evidence segments for
retrieval augmentation, and (d) the automatic thresholds tau / gamma.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional

from .expr import Expr, Filter, iter_filters


def _smooth(frac: float, n: int) -> float:
    """Laplace-style smoothing keeps selectivities off the {0,1} walls so
    expected-cost products stay informative with small samples."""
    return (frac * n + 1.0) / (n + 2.0)


@dataclass
class SampleStats:
    """Statistics for one table, estimated on its sampled documents."""
    table: str
    n_sampled: int = 0
    sampled_values: dict = field(default_factory=dict)   # attr -> {doc_id: value}
    avg_cost: dict = field(default_factory=dict)         # attr -> mean tokens/doc
    evidence_segments: dict = field(default_factory=dict)  # attr -> [segment text]

    def record(self, doc_id, attr: str, value, cost_tokens: int,
               segments: Optional[list] = None):
        self.sampled_values.setdefault(attr, {})[doc_id] = value
        prev_n = self.avg_cost.get(attr, (0.0, 0))
        if isinstance(prev_n, tuple):
            tot, n = prev_n
        else:  # pragma: no cover
            tot, n = prev_n, 1
        self.avg_cost[attr] = (tot + cost_tokens, n + 1)
        if segments:
            self.evidence_segments.setdefault(attr, []).extend(segments)

    def mean_cost(self, attr: str, default: float = 500.0) -> float:
        entry = self.avg_cost.get(attr)
        if not entry:
            return default
        tot, n = entry
        return tot / max(n, 1)

    def selectivity(self, flt: Filter) -> float:
        vals = self.sampled_values.get(flt.attr)
        if not vals:
            return 0.5
        n = len(vals)
        sat = sum(1 for v in vals.values() if flt.evaluate(v))
        return _smooth(sat / n, n)

    def values(self, attr: str) -> list:
        return [v for v in self.sampled_values.get(attr, {}).values() if v is not None]

    def in_filter_selectivity(self, attr: str, allowed: set) -> float:
        vals = self.values(attr)
        if not vals:
            return 0.5
        sat = sum(1 for v in vals if v in allowed)
        return _smooth(sat / len(vals), len(vals))


def sample_size(n_docs: int, rate: float = 0.05, minimum: int = 12, maximum: int = 64) -> int:
    """~5% like the paper, floored so evidence/selectivity stay usable on
    small candidate pools (our lexical embedder needs a few exemplars per
    phrasing template; documented calibration, DESIGN.md §8.2)."""
    return max(min(minimum, n_docs), min(maximum, math.ceil(n_docs * rate)))
