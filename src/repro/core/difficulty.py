"""Per-(doc, attr) extraction difficulty estimation for the model cascade
(DESIGN.md §18).

QUEST's sampling phase and two-level index already compute everything a
routing decision needs, for free:

  * **sampling agreement** — the full-document sweep records, per
    attribute, how often the sampled documents yielded a parseable value
    (`SampleStats.sampled_values`). An attribute that parsed on ~every
    sampled document is *easy*: its phrasing templates are regular enough
    that a small model (or even the retrieved evidence alone) pins the
    value down.
  * **retrieval score margins** — for each (doc, attr) the two-level
    index knows how far the document's best segment sits from the
    attribute's evidence probes relative to their radii
    (`TwoLevelRetriever.score_margin`). A large margin means the segment
    matches a known phrasing template dead-on; a segment scraping the
    radius is ambiguous evidence.
  * **segment cost** — longer retrieved context means more surface for a
    cheap model to get lost in (the same monotonicity the oracle noise
    model encodes).

`DifficultyEstimator` combines the three into a deterministic score in
[0, 1] (0 = trivially easy, 1 = hard), memoized per (doc, attr) so routing
is stable within a session. `CascadeExtractor` routes scores at or below
`threshold` to the small tier; everything else — plus anything the
verifier ever escalated — pays the target model directly.

Live corpora: a mutated document's memoized estimates are stale evidence;
`drop_doc` removes them (wired through `Session.drop_doc_state` /
`live.InvalidationCascade`), and the margin source is version-keyed inside
the retriever, so post-mutation scores are computed fresh.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class DifficultyStats:
    scored: int = 0              # fresh (doc, attr) scores computed
    memo_hits: int = 0           # scores answered from the memo
    tables_folded: int = 0       # fold_sample calls (sampling sweeps seen)
    estimates_dropped: int = 0   # memoized scores dropped by live mutations

    def snapshot(self) -> dict:
        return dict(self.__dict__)


class DifficultyEstimator:
    """Deterministic difficulty scores from sampling stats + index margins.

    Knobs: `threshold` (route small at score <= threshold; 0 forces the
    target tier, 1 trusts the small tier with everything the verifier will
    let it keep), `margin_weight` / `agreement_weight` / `cost_weight`
    (component mix, normalized internally), `cost_scale` (segment tokens
    at which the cost component saturates to "hard").
    """

    def __init__(self, retriever=None, *, threshold: float = 0.6,
                 margin_weight: float = 0.45, agreement_weight: float = 0.35,
                 cost_weight: float = 0.2, cost_scale: float = 160.0):
        self.retriever = retriever
        self.threshold = float(threshold)
        total = max(margin_weight + agreement_weight + cost_weight, 1e-9)
        self.margin_weight = margin_weight / total
        self.agreement_weight = agreement_weight / total
        self.cost_weight = cost_weight / total
        self.cost_scale = max(float(cost_scale), 1.0)
        self._attr: dict = {}     # (table, attr) -> sampling-derived summary
        self._scores: dict = {}   # (doc_id, attr) -> memoized score
        self.stats = DifficultyStats()

    # ------------------------------------------------------------ folding --

    def fold_sample(self, table: str, attrs, stats, sampled=()) -> dict:
        """Fold one table's sampling sweep into per-attr difficulty
        aggregates; returns the summary dict that `TableSample.difficulty`
        carries. Pre-scores the sampled documents so `predicted_split` can
        report the expected tier mix before the query phase runs. Folding
        refreshes the attr-level evidence, so memoized per-doc scores of
        the folded attrs are recomputed on next use."""
        folded: dict = {}
        attrs = sorted(attrs)
        stale = [k for k in self._scores if k[1] in set(attrs)]
        for k in stale:
            del self._scores[k]
        for attr in attrs:
            vals = stats.sampled_values.get(attr, {})
            present = sum(1 for v in vals.values() if v is not None)
            info = {
                "presence": present / len(vals) if vals else 0.0,
                "mean_cost": round(stats.mean_cost(attr), 2),
                "n": len(vals),
            }
            self._attr[(table, attr)] = info
            small = sum(1 for d in sampled
                        if self.score(d, attr, table) <= self.threshold)
            info["predicted_small"] = (round(small / len(sampled), 4)
                                       if sampled else None)
            folded[attr] = dict(info)
        self.stats.tables_folded += 1
        return folded

    def predicted_split(self, table: str, attr: str):
        """{"small": f, "target": 1-f} predicted from the sampled docs'
        scores, or None before the table's sampling phase folded."""
        info = self._attr.get((table, attr))
        if not info or info.get("predicted_small") is None:
            return None
        f = info["predicted_small"]
        return {"small": f, "target": round(1.0 - f, 4)}

    # ------------------------------------------------------------ scoring --

    def _margin_term(self, doc_id, attr: str, table: str) -> float:
        if self.retriever is None:
            return 0.5
        margin = self.retriever.score_margin(doc_id, attr, table)
        return 0.5 if margin is None else 1.0 - margin

    def _agreement_term(self, table: str, attr: str) -> float:
        info = self._attr.get((table, attr))
        if not info or not info["n"]:
            return 0.5
        return 1.0 - info["presence"]

    def _cost_term(self, table: str, attr: str, seg_tokens) -> float:
        if seg_tokens is None:
            info = self._attr.get((table, attr))
            if not info:
                return 0.5
            seg_tokens = info["mean_cost"]
        return min(1.0, max(seg_tokens, 0.0) / self.cost_scale)

    def score(self, doc_id, attr: str, table: str = None,
              seg_tokens=None) -> float:
        """Difficulty in [0, 1] for extracting `attr` from `doc_id`,
        memoized per (doc, attr). `seg_tokens` (the retrieved context
        length, when the caller already has it) sharpens the cost
        component; omitted, the sampling-phase mean cost stands in."""
        key = (doc_id, attr)
        if key in self._scores:
            self.stats.memo_hits += 1
            return self._scores[key]
        s = (self.margin_weight * self._margin_term(doc_id, attr, table)
             + self.agreement_weight * self._agreement_term(table, attr)
             + self.cost_weight * self._cost_term(table, attr, seg_tokens))
        s = round(min(1.0, max(0.0, s)), 6)
        self._scores[key] = s
        self.stats.scored += 1
        return s

    def route(self, doc_id, attr: str, table: str = None,
              seg_tokens=None) -> str:
        """"small" or "target" — the routing rule of DESIGN.md §18."""
        return ("small"
                if self.score(doc_id, attr, table, seg_tokens) <= self.threshold
                else "target")

    # ------------------------------------------------------- invalidation --

    def drop_doc(self, doc_id) -> int:
        """Live-corpus invalidation: a mutated document's memoized
        estimates are stale; drop them so post-mutation routing re-scores
        against the post-mutation index. Returns the drop count."""
        stale = [k for k in self._scores if k[0] == doc_id]
        for k in stale:
            del self._scores[k]
        self.stats.estimates_dropped += len(stale)
        return len(stale)

    def snapshot(self) -> dict:
        out = self.stats.snapshot()
        out["threshold"] = self.threshold
        out["attrs_folded"] = len(self._attr)
        out["memoized"] = len(self._scores)
        return out
