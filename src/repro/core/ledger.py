"""LLM cost accounting — the paper's primary metric (tokens per document).

Every extraction charges input tokens (prompt overhead + relevant-segment
tokens) and output tokens. The ledger is threaded through extractors so
benchmarks report exactly what Table 3 of the paper reports.

Sessions (DESIGN.md §11) use a two-level ledger: the session-wide parent
plus one `child()` per query. Token charges made against a child forward
to its parent, so the session ledger always equals the sum of its queries
(plus any direct charges), while each `QueryResult` carries only its own
query's columns — per-query accounting never double-counts across
`execute()` calls. Batch/prefix counters and wall time are recorded where
they happen (shared rounds on the parent, per-query participation on the
child) and do not forward.

Multi-tenant sessions (DESIGN.md §16) insert a tenant layer: one
`child(tenant=...)` ledger per tenant, whose own children are the
queries, so charges forward query -> tenant -> session and per-tenant
token columns fall out of the same forwarding that per-query ones do.
The `tenant` tag also rides on serving requests so the frontend can
attribute engine work back to the tenant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class CostLedger:
    input_tokens: int = 0
    output_tokens: int = 0
    llm_calls: int = 0
    extractions: int = 0
    wall_time_s: float = 0.0
    per_phase: dict = field(default_factory=dict)   # phase -> token count
    # per-attribute attribution (DESIGN.md §19): tokens/calls by the attr
    # that was being extracted — the "actual" side EXPLAIN ANALYZE joins
    # against explain()'s per-stage estimates. Batch-invariant like every
    # token column (charges are identical, only their grouping changes).
    per_attr: dict = field(default_factory=dict)        # attr -> tokens
    per_attr_calls: dict = field(default_factory=dict)  # attr -> charges
    # per-batch accounting (DESIGN.md §9): token totals are batch-invariant,
    # so batching shows up here and in wall time, never in the token columns
    batches: int = 0
    batched_extractions: int = 0
    max_batch: int = 0
    # prefix-KV-cache accounting (DESIGN.md §10): like batching, prefix
    # reuse is a *serving* saving — the logical prompt is unchanged, so the
    # token columns stay cache-invariant and the saving is reported apart
    prefix_hits: int = 0
    saved_prefill_tokens: int = 0
    # speculative-decoding accounting (DESIGN.md §14): like batching and
    # prefix reuse, speculation changes how tokens are produced, never which
    # — the token columns stay invariant and the draft/verify economy is
    # reported apart (draft tokens proposed, accepted, decode steps saved)
    draft_tokens: int = 0
    accepted_tokens: int = 0
    decode_steps_saved: int = 0
    # model-cascade accounting (DESIGN.md §18): routing an extraction to
    # the small tier changes which *model* produced the value, never which
    # value — token columns stay cascade-invariant and the per-tier economy
    # is reported apart (small-tier extractions kept, verifier escalations,
    # target-model tokens that never had to be spent)
    cascade_small: int = 0
    cascade_escalations: int = 0
    target_tokens_saved: int = 0
    # parent session ledger (child() creates the link); charges forward up
    parent: Optional["CostLedger"] = None
    # admission-control identity: set on per-tenant ledgers (and inherited
    # by their query children) so serving requests can be attributed
    tenant: str = ""

    def child(self, tenant: Optional[str] = None) -> "CostLedger":
        """Per-query (or per-tenant) child: its token charges also land on
        this ledger. `tenant` tags the child; omitted, the child inherits
        this ledger's tenant, so query ledgers under a tenant ledger carry
        the tenant tag without every caller threading it."""
        return CostLedger(parent=self,
                          tenant=self.tenant if tenant is None else tenant)

    def charge(self, *, inp: int, out: int = 0, calls: int = 1,
               phase: str = "query", attr: Optional[str] = None):
        self.input_tokens += inp
        self.output_tokens += out
        self.llm_calls += calls
        self.extractions += 1
        self.per_phase[phase] = self.per_phase.get(phase, 0) + inp + out
        if attr is not None:
            self.per_attr[attr] = self.per_attr.get(attr, 0) + inp + out
            self.per_attr_calls[attr] = self.per_attr_calls.get(attr, 0) + calls
        if self.parent is not None:
            self.parent.charge(inp=inp, out=out, calls=calls, phase=phase,
                               attr=attr)

    def record_batch(self, n: int):
        self.batches += 1
        self.batched_extractions += n
        self.max_batch = max(self.max_batch, n)

    def record_prefix(self, hits: int, saved_tokens: int):
        self.prefix_hits += hits
        self.saved_prefill_tokens += saved_tokens

    def record_spec(self, drafted: int, accepted: int, steps_saved: int):
        self.draft_tokens += drafted
        self.accepted_tokens += accepted
        self.decode_steps_saved += steps_saved

    def record_cascade(self, small: int, escalations: int, saved_tokens: int):
        self.cascade_small += small
        self.cascade_escalations += escalations
        self.target_tokens_saved += saved_tokens

    @property
    def total_tokens(self) -> int:
        return self.input_tokens + self.output_tokens

    def snapshot(self) -> dict:
        return {
            "input_tokens": self.input_tokens,
            "output_tokens": self.output_tokens,
            "total_tokens": self.total_tokens,
            "llm_calls": self.llm_calls,
            "extractions": self.extractions,
            "per_phase": dict(self.per_phase),
            "per_attr": dict(self.per_attr),
            "per_attr_calls": dict(self.per_attr_calls),
            "batches": self.batches,
            "batched_extractions": self.batched_extractions,
            "max_batch": self.max_batch,
            "prefix_hits": self.prefix_hits,
            "saved_prefill_tokens": self.saved_prefill_tokens,
            "draft_tokens": self.draft_tokens,
            "accepted_tokens": self.accepted_tokens,
            "decode_steps_saved": self.decode_steps_saved,
            "cascade_small": self.cascade_small,
            "cascade_escalations": self.cascade_escalations,
            "target_tokens_saved": self.target_tokens_saved,
        }

    def merged(self, other: "CostLedger") -> "CostLedger":
        out = CostLedger(self.input_tokens + other.input_tokens,
                         self.output_tokens + other.output_tokens,
                         self.llm_calls + other.llm_calls,
                         self.extractions + other.extractions,
                         self.wall_time_s + other.wall_time_s)
        out.batches = self.batches + other.batches
        out.batched_extractions = self.batched_extractions + other.batched_extractions
        out.max_batch = max(self.max_batch, other.max_batch)
        out.prefix_hits = self.prefix_hits + other.prefix_hits
        out.saved_prefill_tokens = (self.saved_prefill_tokens +
                                    other.saved_prefill_tokens)
        out.draft_tokens = self.draft_tokens + other.draft_tokens
        out.accepted_tokens = self.accepted_tokens + other.accepted_tokens
        out.decode_steps_saved = (self.decode_steps_saved +
                                  other.decode_steps_saved)
        out.cascade_small = self.cascade_small + other.cascade_small
        out.cascade_escalations = (self.cascade_escalations +
                                   other.cascade_escalations)
        out.target_tokens_saved = (self.target_tokens_saved +
                                   other.target_tokens_saved)
        for d in (self.per_phase, other.per_phase):
            for k, v in d.items():
                out.per_phase[k] = out.per_phase.get(k, 0) + v
        for src, dst in ((self.per_attr, out.per_attr),
                         (other.per_attr, out.per_attr),
                         (self.per_attr_calls, out.per_attr_calls),
                         (other.per_attr_calls, out.per_attr_calls)):
            for k, v in src.items():
                dst[k] = dst.get(k, 0) + v
        return out
