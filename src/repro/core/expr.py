"""Predicate / query AST for QUEST's SPJ queries (paper §2.1).

Filters support equality, open/closed ranges, IN (used by the join
transformation) and substring containment. Expressions are arbitrary
AND/OR trees (paper §3.1.4 expression trees).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterator, Optional, Sequence, Union


class QueryError(ValueError):
    """Malformed query: unknown operator, or a SELECT/WHERE/join reference
    to a table the query does not declare. Raised at construction (and by
    `Session.prepare`, which adds corpus-level checks) — never from deep
    inside plan evaluation mid-extraction."""


VALID_OPS = ("=", "!=", ">", ">=", "<", "<=", "between", "in", "contains")


@dataclass(frozen=True)
class Filter:
    attr: str
    op: str                      # '=' '!=' '>' '>=' '<' '<=' 'between' 'in' 'contains'
    value: Any = None
    value2: Any = None           # upper bound for 'between'
    table: str = ""              # owning table (join queries)

    def __post_init__(self):
        if self.op not in VALID_OPS:
            raise QueryError(
                f"unknown op {self.op!r} for filter on {self.attr!r} "
                f"(valid: {', '.join(VALID_OPS)})")

    def evaluate(self, v) -> bool:
        if v is None:
            return False
        try:
            if self.op == "=":
                return v == self.value
            if self.op == "!=":
                return v != self.value
            if self.op == ">":
                return v > self.value
            if self.op == ">=":
                return v >= self.value
            if self.op == "<":
                return v < self.value
            if self.op == "<=":
                return v <= self.value
            if self.op == "between":
                return self.value <= v <= self.value2
            if self.op == "in":
                return v in self.value
            if self.op == "contains":
                return str(self.value).lower() in str(v).lower()
        except TypeError:
            return False
        raise ValueError(f"unknown op {self.op}")

    @property
    def key(self) -> str:
        return f"{self.table}.{self.attr}" if self.table else self.attr

    def __str__(self):
        if self.op == "between":
            return f"{self.value} <= {self.key} <= {self.value2}"
        if self.op == "in":
            vals = list(self.value)
            shown = vals[:3] + (["..."] if len(vals) > 3 else [])
            return f"{self.key} IN {shown}"
        return f"{self.key} {self.op} {self.value}"


@dataclass(frozen=True)
class And:
    children: tuple
    def __str__(self):
        return "(" + " AND ".join(map(str, self.children)) + ")"


@dataclass(frozen=True)
class Or:
    children: tuple
    def __str__(self):
        return "(" + " OR ".join(map(str, self.children)) + ")"


Expr = Union[Filter, And, Or]


def conj(*children) -> Expr:
    return children[0] if len(children) == 1 else And(tuple(children))


def disj(*children) -> Expr:
    return children[0] if len(children) == 1 else Or(tuple(children))


def iter_filters(expr: Optional[Expr]) -> Iterator[Filter]:
    if expr is None:
        return
    if isinstance(expr, Filter):
        yield expr
    else:
        for c in expr.children:
            yield from iter_filters(c)


def expr_attrs(expr: Optional[Expr]) -> list[str]:
    seen, out = set(), []
    for f in iter_filters(expr):
        if f.attr not in seen:
            seen.add(f.attr)
            out.append(f.attr)
    return out


def filters_for_table(expr: Optional[Expr], table: str) -> Optional[Expr]:
    """Project an expression onto one table (used to split per-table
    conjunctive WHERE clauses of join queries)."""
    if expr is None:
        return None
    if isinstance(expr, Filter):
        return expr if expr.table in ("", table) else None
    kept = [filters_for_table(c, table) for c in expr.children]
    kept = [k for k in kept if k is not None]
    if not kept:
        return None
    if len(kept) == 1:
        return kept[0]
    return And(tuple(kept)) if isinstance(expr, And) else Or(tuple(kept))


def evaluate_expr(expr: Expr, values: dict) -> bool:
    """Eager evaluation given a {attr_key: value} dict (testing oracle)."""
    if isinstance(expr, Filter):
        return expr.evaluate(values.get(expr.key, values.get(expr.attr)))
    if isinstance(expr, And):
        return all(evaluate_expr(c, values) for c in expr.children)
    return any(evaluate_expr(c, values) for c in expr.children)


@dataclass(frozen=True)
class JoinEdge:
    left_table: str
    left_attr: str
    right_table: str
    right_attr: str

    def __str__(self):
        return f"{self.left_table}.{self.left_attr} = {self.right_table}.{self.right_attr}"


@dataclass
class Query:
    """SPJ query. `select`: (table, attr) pairs; `where`: AND/OR tree whose
    leaves carry a `table` tag for multi-table queries; `joins`: equi-join
    edges forming the join graph (paper §2.1)."""
    tables: Sequence[str]
    select: Sequence[tuple]             # [(table, attr)]
    where: Optional[Expr] = None
    joins: Sequence[JoinEdge] = field(default_factory=tuple)

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        """Structural validation: every SELECT / tagged-WHERE / join
        reference must name a table the query declares. Corpus-level checks
        (table exists, attribute known) live in `Session.prepare`."""
        if not self.tables:
            raise QueryError("query declares no tables")
        declared = set(self.tables)
        for t, a in self.select:
            if t not in declared:
                raise QueryError(
                    f"SELECT {t}.{a} references table {t!r} absent from "
                    f"query.tables {sorted(declared)}")
        for f in iter_filters(self.where):
            if f.table and f.table not in declared:
                raise QueryError(
                    f"WHERE filter {f} references table {f.table!r} absent "
                    f"from query.tables {sorted(declared)}")
        for j in self.joins:
            for t in (j.left_table, j.right_table):
                if t not in declared:
                    raise QueryError(
                        f"join {j} references table {t!r} absent from "
                        f"query.tables {sorted(declared)}")

    def select_attrs(self, table: str) -> list[str]:
        return [a for t, a in self.select if t == table]

    def where_for(self, table: str) -> Optional[Expr]:
        return filters_for_table(self.where, table)

    def __str__(self):
        sel = ", ".join(f"{t}.{a}" for t, a in self.select)
        s = f"SELECT {sel} FROM {', '.join(self.tables)}"
        conds = [str(j) for j in self.joins]
        if self.where is not None:
            conds.append(str(self.where))
        if conds:
            s += " WHERE " + " AND ".join(conds)
        return s
