from .expr import And, Filter, JoinEdge, Or, Query, conj, disj
from .executor import Engine, QueryResult
from .ledger import CostLedger
from .ordering import exhaustive_plan, plan_expression, plan_fixed_order
from .scheduler import BatchScheduler, SchedulerStats
from .stats import SampleStats

__all__ = ["Filter", "And", "Or", "Query", "JoinEdge", "conj", "disj",
           "Engine", "QueryResult", "CostLedger", "SampleStats",
           "BatchScheduler", "SchedulerStats",
           "plan_expression", "plan_fixed_order", "exhaustive_plan"]
