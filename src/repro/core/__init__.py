from .expr import (And, Filter, JoinEdge, Or, Query, QueryError, conj, disj)
from .difficulty import DifficultyEstimator, DifficultyStats
from .executor import Engine, QueryResult, QueryRun, TableSample
from .ledger import CostLedger
from .ordering import exhaustive_plan, plan_expression, plan_fixed_order
from .scheduler import BatchScheduler, SchedulerStats
from .session import (PreparedQuery, QueryCancelled, QueryHandle,
                      QueryTimeout, Session, render_explain)
from .stats import SampleStats

__all__ = ["Filter", "And", "Or", "Query", "JoinEdge", "QueryError",
           "conj", "disj",
           "Engine", "QueryResult", "QueryRun", "TableSample",
           "Session", "PreparedQuery", "QueryHandle", "render_explain",
           "QueryCancelled", "QueryTimeout",
           "CostLedger", "SampleStats",
           "DifficultyEstimator", "DifficultyStats",
           "BatchScheduler", "SchedulerStats",
           "plan_expression", "plan_fixed_order", "exhaustive_plan"]
