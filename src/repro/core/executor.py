"""QUEST execution engine: optimize-at-execution-time, per-document plans.

Flow per table (paper §2.2):
  1. document-level index -> candidate docs (generous tau);
  2. sampling phase (~5%): full-document LLM extraction of all query attrs,
     collecting selectivities, avg costs, evidence segments; thresholds
     tau/gamma are tightened from the sample (index side);
  3. per-document execution: each document gets its own filter order from
     `plan_expression` using *its* index-retrieved segment token counts
     (lazy extraction + short-circuit);
  4. joins run through the join transformation (§3.2): pick a side by the
     two-term cost model, execute it, convert the join into an IN filter on
     the other side and let the orderer place it; multi-joins are ordered
     adaptively (left-deep, re-planned after every join).

Execution is organized around the cross-document batch scheduler
(DESIGN.md §9): each document's plan runs as a resumable coroutine that
*yields* its next (doc, attr) extraction need, and `core.scheduler`
batches the needs of all in-flight documents into `extract_batch` rounds.
Within a document the lazy short-circuit order is untouched, so result
rows and ledger token totals are identical at every `batch_size`.

The engine is LLM-agnostic: `extractor` and `retriever` are duck-typed
(OracleExtractor for controlled experiments, ServedExtractor for the real
JAX serving engine; see repro/extract).
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Optional

from .expr import (And, Expr, Filter, JoinEdge, Or, Query, expr_attrs,
                   filters_for_table, iter_filters)
from .ledger import CostLedger
from .ordering import PlanNode, plan_expression
from .scheduler import OUTPUT_TOKENS, PROMPT_OVERHEAD, BatchScheduler
from .stats import SampleStats, sample_size


@dataclass
class TableContext:
    name: str
    doc_ids: list
    where: Optional[Expr]
    stats: SampleStats
    extra_filters: list = field(default_factory=list)   # IN filters from joins

    def full_expr(self) -> Optional[Expr]:
        parts = list(self.extra_filters)
        if self.where is not None:
            parts.append(self.where)
        if not parts:
            return None
        return parts[0] if len(parts) == 1 else And(tuple(parts))


@dataclass
class QueryResult:
    rows: list
    ledger: CostLedger
    plans_sampled: dict = field(default_factory=dict)  # doc -> plan description
    meta: dict = field(default_factory=dict)


class Engine:
    def __init__(self, retriever, extractor, *, sample_rate: float = 0.05,
                 seed: int = 0, ordering: str = "quest",
                 join_strategy: str = "transform",
                 ledger: Optional[CostLedger] = None,
                 batch_size: int = 1, queue_depth: int = 32):
        """ordering: quest | exhaust | avg_cost | selectivity | random
        (paper §5.3 baselines). join_strategy: transform | pushdown
        (paper §5.4: QUEST's join transformation vs. classical Plan (1)).
        batch_size/queue_depth: cross-document batching knobs (DESIGN.md §9);
        batch_size=1 is the serial per-extraction path."""
        self.retriever = retriever
        self.extractor = extractor
        self.sample_rate = sample_rate
        self.rng = random.Random(seed)
        self.ordering = ordering
        self.join_strategy = join_strategy
        self.ledger = ledger if ledger is not None else CostLedger()
        self._cache: dict = {}          # (doc_id, attr) -> value
        self._plan_log: dict = {}
        self._escalated: set = set()    # keys already retried full-doc
        self.scheduler = BatchScheduler(retriever, extractor, self.ledger,
                                        self._cache, batch_size=batch_size,
                                        queue_depth=queue_depth)

    # ------------------------------------------------------------ basics --

    def _extract_co(self, doc_id, attr: str, table: str):
        """Coroutine flavour of `_extract`: yields the (doc, attr, table)
        need when uncached; the scheduler resumes it once the batched
        extraction round has landed in the cache."""
        key = (doc_id, attr)
        if key not in self._cache:
            yield (doc_id, attr, table)
        return self._cache[key]

    def _extract_required(self, keys: list, *, phase: str = "query") -> dict:
        """Batch extraction for *output-critical* attributes (join keys and
        SELECT projections): a None from segment-scoped extraction would
        silently drop a result row, so it escalates once to a full-document
        prompt, honestly charged (DESIGN.md §8.3). Filters never escalate —
        their cheap free-negative semantics are the point of the index."""
        got = self.scheduler.extract_many(keys, phase=phase)
        retry = []
        for doc_id, attr, _table in keys:
            k = (doc_id, attr)
            if got[k] is None and k not in self._escalated:
                self._escalated.add(k)
                retry.append(k)
        bs = self.scheduler.batch_size
        for i in range(0, len(retry), bs):
            chunk = retry[i:i + bs]
            items = [(d, a, [self.extractor.corpus.docs[d].text])
                     for d, a in chunk]
            out = self.extractor.extract_batch(items)
            self.ledger.record_batch(len(items))
            for (d, a), (value, inp_tokens) in zip(chunk, out):
                self.ledger.charge(inp=inp_tokens + PROMPT_OVERHEAD,
                                   out=OUTPUT_TOKENS, phase=phase)
                if value is not None:
                    self._cache[(d, a)] = value
                    got[(d, a)] = value
        return got

    def _filter_cost(self, doc_id, flt: Filter, table: str = None) -> float:
        if (doc_id, flt.attr) in self._cache:
            return 0.0
        t = self.retriever.segment_tokens(doc_id, flt.attr, table or flt.table or None)
        return t + PROMPT_OVERHEAD if t > 0 else 0.0

    # ------------------------------------------------------ sample phase --

    def _prepare_table(self, query: Query, table: str) -> TableContext:
        attrs = sorted(set(
            [f.attr for f in iter_filters(query.where_for(table))]
            + query.select_attrs(table)
            + [j.left_attr if j.left_table == table else j.right_attr
               for j in query.joins if table in (j.left_table, j.right_table)]))
        docs = self.retriever.candidate_docs(table, attrs)
        stats = SampleStats(table=table)
        n = sample_size(len(docs), self.sample_rate)
        if n < len(docs):
            # rank-stratified: candidate_docs is distance-ordered, so picking
            # evenly-spaced ranks from the nearer 60% yields in-domain
            # evidence even when the table's domain is a small fraction of
            # the pool, without collapsing the tau estimate to the very
            # nearest docs; the random half keeps selectivity estimates
            # representative of D_Q (DESIGN.md §8).
            pool = list(docs)
            k_head = (n + 1) // 2
            top = pool[: max(k_head, int(0.6 * len(pool)))]
            step = max(1, len(top) // k_head)
            head = top[::step][:k_head]
            rest = [d for d in pool if d not in head]
            sampled = head + self.rng.sample(rest, n - len(head))
        else:
            sampled = list(docs)
        # sampling goes through the same batched path as query execution:
        # full-document prompts of a chunk share one continuous-batching round
        full = self.scheduler.extract_full_docs(sampled, attrs)
        for doc_id in sampled:
            vals, segs_by_attr, inp_tokens = full[doc_id]
            self.ledger.charge(inp=inp_tokens + PROMPT_OVERHEAD,
                               out=OUTPUT_TOKENS * len(attrs), phase="sampling")
            for attr in attrs:
                v = vals.get(attr)
                segs = segs_by_attr.get(attr, [])
                stats.record(doc_id, attr, v, inp_tokens // max(len(attrs), 1), segs)
                self._cache[(doc_id, attr)] = v
                if segs:
                    self.retriever.add_evidence(table, attr, segs)
        stats.n_sampled = len(sampled)
        self.retriever.finalize_thresholds(table, attrs, stats)
        docs = self.retriever.refine_candidates(table, attrs)
        # keep sampled docs in scope even if threshold refinement dropped them
        doc_set = dict.fromkeys(list(docs) + sampled)
        return TableContext(table, list(doc_set), query.where_for(table), stats)

    # -------------------------------------------------- filter execution --

    def _plan_for_doc(self, ctx: TableContext, doc_id) -> Optional[PlanNode]:
        expr = ctx.full_expr()
        if expr is None:
            return None
        doc_cost = lambda f: self._filter_cost(doc_id, f, ctx.name)
        sel = ctx.stats.selectivity
        if self.ordering == "quest":
            return plan_expression(expr, doc_cost, sel)
        if self.ordering == "exhaust":
            from .ordering import exhaustive_plan
            return exhaustive_plan(expr, doc_cost, sel)
        if self.ordering == "avg_cost":   # global order: sample-mean costs
            return plan_expression(expr, lambda f: ctx.stats.mean_cost(f.attr), sel)
        from .ordering import plan_fixed_order
        if self.ordering == "selectivity":
            return plan_fixed_order(expr, doc_cost, sel, key_fn=lambda n: n.prob)
        if self.ordering == "random":
            return plan_fixed_order(expr, doc_cost, sel,
                                    key_fn=lambda n: self.rng.random())
        raise ValueError(f"unknown ordering {self.ordering!r}")

    def _eval_plan_co(self, node: PlanNode, ctx: TableContext, doc_id):
        """Lazy plan evaluation as a coroutine: extraction needs are yielded
        (and batched across documents by the scheduler); the short-circuit
        order *within* this document is exactly the serial one."""
        if node.kind == "filter":
            v = yield from self._extract_co(doc_id, node.filter.attr, ctx.name)
            return node.filter.evaluate(v)
        if node.kind == "and":
            for c in node.children:
                ok = yield from self._eval_plan_co(c, ctx, doc_id)
                if not ok:
                    return False
            return True
        for c in node.children:
            ok = yield from self._eval_plan_co(c, ctx, doc_id)
            if ok:
                return True
        return False

    def _doc_filter_co(self, ctx: TableContext, doc_id, overlap: list):
        """One document's resumable step-machine: overlap prefetch, then
        plan (costed on *this* doc's cached/pending state), then lazy eval."""
        for attr in overlap:
            yield from self._extract_co(doc_id, attr, ctx.name)
        plan = self._plan_for_doc(ctx, doc_id)
        if plan is not None and len(self._plan_log) < 64:
            self._plan_log[(ctx.name, doc_id)] = plan.describe()
        if plan is None:
            return True
        return (yield from self._eval_plan_co(plan, ctx, doc_id))

    def _execute_filters(self, ctx: TableContext, query: Query) -> list:
        """Returns surviving doc ids (instance-optimized per-doc plans,
        executed as in-flight coroutines under the batch scheduler)."""
        expr = ctx.full_expr()
        select_attrs = set(query.select_attrs(ctx.name))
        # §3.1.3: with a disjunctive root, attrs in both SELECT and WHERE must
        # be extracted regardless — pull them first (cache makes their
        # filters free, so the orderer then front-loads them).
        overlap = []
        if isinstance(expr, Or):
            overlap = [a for a in expr_attrs(expr) if a in select_attrs]
        passed = self.scheduler.run(
            {d: self._doc_filter_co(ctx, d, overlap) for d in ctx.doc_ids})
        return [d for d in ctx.doc_ids if passed[d]]

    # ----------------------------------------------------- cost models ----

    def _table_first_two_terms(self, ctx: TableContext, join_attr: str) -> float:
        """Eq. 9/10 first two terms: expected filter cost + P(pass) * cost of
        extracting the join attribute, summed over the table's documents."""
        total = 0.0
        for doc_id in ctx.doc_ids:
            plan = self._plan_for_doc(ctx, doc_id)
            c_join = self._filter_cost(doc_id, Filter(join_attr, "=", None), ctx.name)
            if plan is None:
                total += c_join
            else:
                total += plan.cost + plan.prob * c_join
        return total

    def _table_in_augmented_cost(self, ctx: TableContext, join_attr: str,
                                 values: set) -> float:
        """Expected cost of the IN-augmented plan on `ctx` (third term)."""
        in_f = Filter(join_attr, "in", frozenset(values), table=ctx.name)
        sel = ctx.stats.in_filter_selectivity(join_attr, set(values))
        base = ctx.full_expr()
        expr = in_f if base is None else And((in_f, base))
        total = 0.0
        for doc_id in ctx.doc_ids:
            plan = plan_expression(
                expr, lambda f: self._filter_cost(doc_id, f, ctx.name),
                lambda f: sel if f is in_f else ctx.stats.selectivity(f))
            total += plan.cost
        return total

    # ------------------------------------------------------------ joins ---

    def _edge_tables(self, edge: JoinEdge):
        return ((edge.left_table, edge.left_attr), (edge.right_table, edge.right_attr))

    def _execute_edge(self, query: Query, edge: JoinEdge, ctxs: dict,
                      done_tables: dict) -> None:
        """Join transformation for one edge. `done_tables`: table ->
        {doc_id: join-ready}, updated in place with survivors."""
        (t1, a1), (t2, a2) = self._edge_tables(edge)
        if t1 in done_tables and t2 in done_tables:
            return
        if t2 in done_tables:       # orient: t1 = side to execute first
            (t1, a1), (t2, a2) = (t2, a2), (t1, a1)
        if t1 not in done_tables:
            # choose direction by the two-term cost model (§3.2.1)
            c12 = self._table_first_two_terms(ctxs[t1], a1)
            c21 = self._table_first_two_terms(ctxs[t2], a2)
            if c21 < c12:
                (t1, a1), (t2, a2) = (t2, a2), (t1, a1)
            survivors = self._execute_filters(ctxs[t1], query)
            done_tables[t1] = survivors
        else:
            survivors = done_tables[t1]
        # extract join attribute on side-1 survivors (one batched sweep)
        got = self._extract_required([(d, a1, t1) for d in survivors])
        values = {v for v in got.values() if v is not None}
        # transform join into IN filter on side 2, re-optimize, execute
        in_f = Filter(a2, "in", frozenset(values), table=t2)
        ctxs[t2].extra_filters.append(in_f)
        done_tables[t2] = self._execute_filters(ctxs[t2], query)

    def _choose_first_edge(self, query: Query, ctxs: dict) -> JoinEdge:
        best, best_cost = None, float("inf")
        for e in query.joins:
            (t1, a1), (t2, a2) = self._edge_tables(e)
            c = min(self._table_first_two_terms(ctxs[t1], a1),
                    self._table_first_two_terms(ctxs[t2], a2))
            if c < best_cost:
                best, best_cost = e, c
        return best

    def _choose_next_edge(self, query: Query, ctxs: dict, done: dict,
                          remaining: list) -> JoinEdge:
        """Adaptive ordering (§3.2.2): among edges touching the joined
        prefix, estimate the IN-augmented cost on the new table."""
        best, best_cost = None, float("inf")
        for e in remaining:
            (t1, a1), (t2, a2) = self._edge_tables(e)
            if t1 in done and t2 in done:
                return e          # closing a cycle: free-ish, do it now
            if t1 not in done and t2 not in done:
                continue
            if t2 in done:
                (t1, a1), (t2, a2) = (t2, a2), (t1, a1)
            # survivors' join values may not all be extracted yet
            got = self._extract_required([(d, a1, t1) for d in done[t1]])
            values = {v for v in got.values() if v is not None}
            c = self._table_in_augmented_cost(ctxs[t2], a2, values)
            if c < best_cost:
                best, best_cost = e, c
        return best if best is not None else remaining[0]

    def _assemble_rows(self, query: Query, done_tables: dict) -> list:
        """Materialize joined rows (hash join over extracted join attrs of
        surviving docs — the expensive extraction is already done)."""
        tables = list(query.tables)
        rows = [{tables[0]: d} for d in done_tables.get(tables[0], [])]
        joined = {tables[0]}
        edges = list(query.joins)
        while edges:
            e = next((e for e in edges if
                      (e.left_table in joined) != (e.right_table in joined)
                      or (e.left_table in joined and e.right_table in joined)), None)
            if e is None:
                break
            edges.remove(e)
            (t1, a1), (t2, a2) = self._edge_tables(e)
            if t1 not in joined:
                (t1, a1), (t2, a2) = (t2, a2), (t1, a1)
            if t2 in joined:      # cycle edge: filter existing rows
                rows = [r for r in rows
                        if self._cache.get((r[t1], a1)) is not None
                        and self._cache.get((r[t1], a1)) == self._cache.get((r[t2], a2))]
                continue
            index = {}
            for d in done_tables.get(t2, []):
                index.setdefault(self._cache.get((d, a2)), []).append(d)
            new_rows = []
            for r in rows:
                v = self._cache.get((r[t1], a1))
                for d in index.get(v, []) if v is not None else []:
                    nr = dict(r)
                    nr[t2] = d
                    new_rows.append(nr)
            rows = new_rows
            joined.add(t2)
        return rows

    # ------------------------------------------------------------- main ---

    def execute(self, query: Query) -> QueryResult:
        t0 = time.time()
        ctxs = {t: self._prepare_table(query, t) for t in query.tables}
        done: dict = {}
        if not query.joins:
            t = query.tables[0]
            done[t] = self._execute_filters(ctxs[t], query)
            rows = [{t: d} for d in done[t]]
        elif self.join_strategy == "pushdown":
            # classical Plan (1): push filters into every table, extract the
            # join attributes of all survivors, hash join.
            for t in query.tables:
                done[t] = self._execute_filters(ctxs[t], query)
            self._extract_required(
                [(d, a, t) for e in query.joins
                 for t, a in self._edge_tables(e) for d in done.get(t, [])])
            rows = self._assemble_rows(query, done)
        else:
            remaining = list(query.joins)
            first = self._choose_first_edge(query, ctxs)
            remaining.remove(first)
            self._execute_edge(query, first, ctxs, done)
            while remaining:
                nxt = self._choose_next_edge(query, ctxs, done, remaining)
                remaining.remove(nxt)
                self._execute_edge(query, nxt, ctxs, done)
            for t in query.tables:      # disconnected tables: plain filters
                if t not in done:
                    done[t] = self._execute_filters(ctxs[t], query)
            rows = self._assemble_rows(query, done)

        # project SELECT attributes (extracted only for surviving rows,
        # in one batched sweep — join rows repeating a doc dedup to one call)
        got = self._extract_required(
            [(r[t], a, t) for r in rows for t, a in query.select])
        out_rows = []
        for r in rows:
            rec = {}
            ok = True
            for t, a in query.select:
                v = got[(r[t], a)]
                rec[f"{t}.{a}"] = v
                if v is None:
                    ok = False
            rec["_docs"] = dict(r)
            if ok:
                out_rows.append(rec)
        self.ledger.wall_time_s += time.time() - t0
        return QueryResult(out_rows, self.ledger, dict(self._plan_log),
                           meta={"survivors": {k: len(v) for k, v in done.items()}})
