"""QUEST execution: optimize-at-execution-time, per-document plans, run as
session-driven per-query state machines.

Flow per table (paper §2.2):
  1. document-level index -> candidate docs (generous tau);
  2. sampling phase (~5%): full-document LLM extraction of all query attrs,
     collecting selectivities, avg costs, evidence segments; thresholds
     tau/gamma are tightened from the sample (index side);
  3. per-document execution: each document gets its own filter order from
     `plan_expression` using *its* index-retrieved segment token counts
     (lazy extraction + short-circuit);
  4. joins run through the join transformation (§3.2): pick a side by the
     two-term cost model, execute it, convert the join into an IN filter on
     the other side and let the orderer place it; multi-joins are ordered
     adaptively (left-deep, re-planned after every join).

Execution is organized in two coroutine layers (DESIGN.md §9 and §11).
Within a query, each document's plan runs as a resumable coroutine that
*yields* its next (doc, attr) extraction need. Around that, the whole
query is itself a state machine: `QueryRun.run_co()` is a generator that
yields *barrier requests* — sampling acquisition, document-coroutine
rounds, bulk extraction sweeps, escalations, result-row emissions — to
the `core.session.Session` multiplexer, which merges the concurrent
barriers of every in-flight query into shared `BatchScheduler` rounds.
Within a document the lazy short-circuit order is untouched, so result
rows and ledger token totals are identical at every `batch_size` and
under any interleaving of disjoint queries.

`Engine` remains as the single-query shim over `Session` so existing call
sites keep working: `Engine.execute(query)` prepares, submits, and blocks
on one query, while `engine.ledger` / `engine.scheduler` expose the
session-wide accounting exactly as before. Per-query state (`plans_sampled`,
`QueryResult.ledger` wall time and token columns) no longer leaks across
`execute()` calls: each query gets a child ledger and its own plan log.

The engine is LLM-agnostic: `extractor` and `retriever` are duck-typed
(OracleExtractor for controlled experiments, ServedExtractor for the real
JAX serving engine; see repro/extract).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Optional

from .expr import (And, Expr, Filter, JoinEdge, Or, Query, expr_attrs,
                   iter_filters)
from .ledger import CostLedger
from .ordering import PlanNode, plan_expression
from .scheduler import OUTPUT_TOKENS, PROMPT_OVERHEAD
from .stats import SampleStats, sample_size


@dataclass
class TableContext:
    name: str
    doc_ids: list
    where: Optional[Expr]
    stats: SampleStats
    extra_filters: list = field(default_factory=list)   # IN filters from joins

    def full_expr(self) -> Optional[Expr]:
        parts = list(self.extra_filters)
        if self.where is not None:
            parts.append(self.where)
        if not parts:
            return None
        return parts[0] if len(parts) == 1 else And(tuple(parts))


@dataclass
class TableSample:
    """One table's paid sampling investment, owned by the session: the
    sample statistics plus the docs whose attr values the sampling phase
    already put in the shared cache. A later query whose attributes are a
    subset of `attrs` reuses this wholesale and skips its sampling phase
    (its `per_phase['sampling']` stays 0)."""
    table: str
    attrs: frozenset
    stats: SampleStats
    sampled: list
    # corpus mutation-log seq at publish time (live corpora only): a sample
    # stamped below the current seq is stale evidence for exact invalidation
    version: int = 0
    # per-attr difficulty summary (DESIGN.md §18), folded at publish time
    # when the session's extractor routes through a DifficultyEstimator:
    # attr -> {presence, mean_cost, n, predicted_small}
    difficulty: dict = field(default_factory=dict)


@dataclass
class QueryResult:
    rows: list
    ledger: CostLedger
    plans_sampled: dict = field(default_factory=dict)  # doc -> plan description
    meta: dict = field(default_factory=dict)


def table_query_attrs(query: Query, table: str) -> list:
    """All attributes a query touches on `table`: WHERE filters, SELECT
    projections, and join keys — the set the sampling phase extracts."""
    return sorted(set(
        [f.attr for f in iter_filters(query.where_for(table))]
        + query.select_attrs(table)
        + [j.left_attr if j.left_table == table else j.right_attr
           for j in query.joins if table in (j.left_table, j.right_table)]))


class QueryRun:
    """Per-query execution state machine (DESIGN.md §11).

    `run_co()` is a generator that yields barrier requests to the session
    multiplexer and receives their results via `send`:

      ("sample_acquire", table, attrs) -> ("own", None) | ("reuse", TableSample)
      ("sample_publish", TableSample)  -> None (immediate)
      ("full_docs", [(doc_id, attrs)]) -> {doc_id: (values, segs, tokens)}
      ("run", {key: doc_coroutine})    -> {key: result}
      ("extract", [(doc, attr, table)])-> {(doc, attr): value}
      ("escalate", [(doc, attr)])      -> {(doc, attr): value}
      ("rows", [row, ...])             -> None (immediate; streamed to handle)

    All mutable state shared across queries (value cache, escalation set,
    retriever thresholds/evidence, sampling investments) lives on the
    session; everything here — rng, plan log, child ledger, table
    contexts — is private to one query, so nothing leaks between
    `execute()` calls.
    """

    def __init__(self, query: Query, *, retriever, extractor, cache: dict,
                 escalated: set, ledger: CostLedger, seed: int = 0,
                 sample_rate: float = 0.05, ordering: str = "quest",
                 join_strategy: str = "transform", batch_size: int = 1,
                 ctx_hook=None):
        self.query = query
        self.retriever = retriever
        self.extractor = extractor
        self._cache = cache
        self._escalated = escalated
        self.ledger = ledger
        self.rng = random.Random(seed)
        self.sample_rate = sample_rate
        self.ordering = ordering
        self.join_strategy = join_strategy
        self.batch_size = max(1, int(batch_size))
        self.ctx_hook = ctx_hook
        self._plan_log: dict = {}
        self.sampling_reused: dict = {}     # table -> bool
        # EXPLAIN ANALYZE actuals (DESIGN.md §19): per-filter short-circuit
        # outcomes, keyed (table, str(filter)) to join with explain() stages
        self.filter_evals: dict = {}        # -> [evaluated, passed]

    # ------------------------------------------------------------ basics --

    def _extract_co(self, doc_id, attr: str, table: str):
        """Coroutine flavour of extraction: yields the (doc, attr, table)
        need when uncached; the scheduler resumes it once the batched
        extraction round has landed in the cache."""
        key = (doc_id, attr)
        if key not in self._cache:
            yield (doc_id, attr, table)
        return self._cache[key]

    def _extract_required_co(self, keys: list):
        """Batch extraction for *output-critical* attributes (join keys and
        SELECT projections): a None from segment-scoped extraction would
        silently drop a result row, so it escalates once to a full-document
        prompt, honestly charged (DESIGN.md §8.3). Filters never escalate —
        their cheap free-negative semantics are the point of the index.

        The escalation memo lives on the *session* and is marked by the
        resolver, so concurrent queries needing the same key in one round
        share a single retry (first owner pays) instead of the laggard
        skipping and dropping its row; a peer's escalated value landing in
        the cache between rounds is picked up by the re-read below."""
        got = yield ("extract", list(keys))
        retry = []
        for doc_id, attr, _table in keys:
            k = (doc_id, attr)
            if got[k] is None:
                if self._cache.get(k) is not None:   # peer escalated it since
                    got[k] = self._cache[k]
                elif k not in self._escalated:
                    retry.append(k)
        if retry:
            esc = yield ("escalate", retry)
            for k in retry:
                if esc.get(k) is not None:
                    got[k] = esc[k]
        return got

    def _filter_cost(self, doc_id, flt: Filter, table: str = None) -> float:
        if (doc_id, flt.attr) in self._cache:
            return 0.0
        t = self.retriever.segment_tokens(doc_id, flt.attr, table or flt.table or None)
        return t + PROMPT_OVERHEAD if t > 0 else 0.0

    # ------------------------------------------------------ sample phase --

    def _prepare_table_co(self, table: str):
        """Sampling phase with session-level reuse: the first query on a
        table pays the ~5% full-document sweep and publishes the resulting
        `TableSample`; later queries whose attrs are covered acquire it and
        skip sampling entirely (their sampling token column stays 0)."""
        query = self.query
        attrs = table_query_attrs(query, table)
        mode, sample = yield ("sample_acquire", table, tuple(attrs))
        if mode == "reuse":
            self.sampling_reused[table] = True
            docs = self.retriever.refine_candidates(table, attrs)
            doc_set = dict.fromkeys(list(docs) + list(sample.sampled))
            ctx = TableContext(table, list(doc_set), query.where_for(table),
                               sample.stats)
            return self._wrap_ctx(ctx)
        self.sampling_reused[table] = False
        # re-sampling an uncovered table widens to the union of our attrs
        # and the prior sample's, so the session's paid coverage only grows
        if sample is not None:
            attrs = sorted(set(attrs) | set(sample.attrs))
        docs = self.retriever.candidate_docs(table, attrs)
        stats = SampleStats(table=table)
        n = sample_size(len(docs), self.sample_rate)
        if n < len(docs):
            # rank-stratified: candidate_docs is distance-ordered, so picking
            # evenly-spaced ranks from the nearer 60% yields in-domain
            # evidence even when the table's domain is a small fraction of
            # the pool, without collapsing the tau estimate to the very
            # nearest docs; the random half keeps selectivity estimates
            # representative of D_Q (DESIGN.md §8).
            pool = list(docs)
            k_head = (n + 1) // 2
            top = pool[: max(k_head, int(0.6 * len(pool)))]
            step = max(1, len(top) // k_head)
            head = top[::step][:k_head]
            rest = [d for d in pool if d not in head]
            sampled = head + self.rng.sample(rest, n - len(head))
        else:
            sampled = list(docs)
        # sampling goes through the same batched path as query execution:
        # full-document prompts of a chunk share one continuous-batching
        # round (merged with any concurrently-sampling query's chunk)
        full = yield ("full_docs", [(d, attrs) for d in sampled])
        for doc_id in sampled:
            vals, segs_by_attr, inp_tokens = full[doc_id]
            self.ledger.charge(inp=inp_tokens + PROMPT_OVERHEAD,
                               out=OUTPUT_TOKENS * len(attrs), phase="sampling")
            for attr in attrs:
                v = vals.get(attr)
                segs = segs_by_attr.get(attr, [])
                stats.record(doc_id, attr, v, inp_tokens // max(len(attrs), 1), segs)
                self._cache[(doc_id, attr)] = v
                if segs:
                    self.retriever.add_evidence(table, attr, segs, doc_id=doc_id)
        stats.n_sampled = len(sampled)
        self.retriever.finalize_thresholds(table, attrs, stats)
        yield ("sample_publish",
               TableSample(table, frozenset(attrs), stats, list(sampled)))
        docs = self.retriever.refine_candidates(table, attrs)
        # keep sampled docs in scope even if threshold refinement dropped them
        doc_set = dict.fromkeys(list(docs) + sampled)
        ctx = TableContext(table, list(doc_set), query.where_for(table), stats)
        return self._wrap_ctx(ctx)

    def _wrap_ctx(self, ctx: TableContext) -> TableContext:
        return ctx if self.ctx_hook is None else self.ctx_hook(ctx, self.query)

    # -------------------------------------------------- filter execution --

    def _plan_for_doc(self, ctx: TableContext, doc_id) -> Optional[PlanNode]:
        expr = ctx.full_expr()
        if expr is None:
            return None
        doc_cost = lambda f: self._filter_cost(doc_id, f, ctx.name)
        sel = ctx.stats.selectivity
        if self.ordering == "quest":
            return plan_expression(expr, doc_cost, sel)
        if self.ordering == "exhaust":
            from .ordering import exhaustive_plan
            return exhaustive_plan(expr, doc_cost, sel)
        if self.ordering == "avg_cost":   # global order: sample-mean costs
            return plan_expression(expr, lambda f: ctx.stats.mean_cost(f.attr), sel)
        from .ordering import plan_fixed_order
        if self.ordering == "selectivity":
            return plan_fixed_order(expr, doc_cost, sel, key_fn=lambda n: n.prob)
        if self.ordering == "random":
            return plan_fixed_order(expr, doc_cost, sel,
                                    key_fn=lambda n: self.rng.random())
        raise ValueError(f"unknown ordering {self.ordering!r}")

    def _eval_plan_co(self, node: PlanNode, ctx: TableContext, doc_id):
        """Lazy plan evaluation as a coroutine: extraction needs are yielded
        (and batched across documents — and queries — by the session); the
        short-circuit order *within* this document is exactly the serial one."""
        if node.kind == "filter":
            v = yield from self._extract_co(doc_id, node.filter.attr, ctx.name)
            ok = node.filter.evaluate(v)
            ev = self.filter_evals.setdefault((ctx.name, str(node.filter)),
                                              [0, 0])
            ev[0] += 1
            ev[1] += 1 if ok else 0
            return ok
        if node.kind == "and":
            for c in node.children:
                ok = yield from self._eval_plan_co(c, ctx, doc_id)
                if not ok:
                    return False
            return True
        for c in node.children:
            ok = yield from self._eval_plan_co(c, ctx, doc_id)
            if ok:
                return True
        return False

    def _doc_filter_co(self, ctx: TableContext, doc_id, overlap: list):
        """One document's resumable step-machine: overlap prefetch, then
        plan (costed on *this* doc's cached/pending state), then lazy eval."""
        for attr in overlap:
            yield from self._extract_co(doc_id, attr, ctx.name)
        plan = self._plan_for_doc(ctx, doc_id)
        if plan is not None and len(self._plan_log) < 64:
            self._plan_log[(ctx.name, doc_id)] = plan.describe()
        if plan is None:
            return True
        return (yield from self._eval_plan_co(plan, ctx, doc_id))

    def _execute_filters_co(self, ctx: TableContext):
        """Returns surviving doc ids (instance-optimized per-doc plans,
        executed as in-flight coroutines under the session's shared rounds)."""
        expr = ctx.full_expr()
        select_attrs = set(self.query.select_attrs(ctx.name))
        # §3.1.3: with a disjunctive root, attrs in both SELECT and WHERE must
        # be extracted regardless — pull them first (cache makes their
        # filters free, so the orderer then front-loads them).
        overlap = []
        if isinstance(expr, Or):
            overlap = [a for a in expr_attrs(expr) if a in select_attrs]
        passed = yield ("run", {d: self._doc_filter_co(ctx, d, overlap)
                                for d in ctx.doc_ids})
        return [d for d in ctx.doc_ids if passed[d]]

    # ----------------------------------------------------- cost models ----

    def _table_first_two_terms(self, ctx: TableContext, join_attr: str) -> float:
        """Eq. 9/10 first two terms: expected filter cost + P(pass) * cost of
        extracting the join attribute, summed over the table's documents."""
        total = 0.0
        for doc_id in ctx.doc_ids:
            plan = self._plan_for_doc(ctx, doc_id)
            c_join = self._filter_cost(doc_id, Filter(join_attr, "=", None), ctx.name)
            if plan is None:
                total += c_join
            else:
                total += plan.cost + plan.prob * c_join
        return total

    def _table_in_augmented_cost(self, ctx: TableContext, join_attr: str,
                                 values: set) -> float:
        """Expected cost of the IN-augmented plan on `ctx` (third term)."""
        in_f = Filter(join_attr, "in", frozenset(values), table=ctx.name)
        sel = ctx.stats.in_filter_selectivity(join_attr, set(values))
        base = ctx.full_expr()
        expr = in_f if base is None else And((in_f, base))
        total = 0.0
        for doc_id in ctx.doc_ids:
            plan = plan_expression(
                expr, lambda f: self._filter_cost(doc_id, f, ctx.name),
                lambda f: sel if f is in_f else ctx.stats.selectivity(f))
            total += plan.cost
        return total

    # ------------------------------------------------------------ joins ---

    def _edge_tables(self, edge: JoinEdge):
        return ((edge.left_table, edge.left_attr), (edge.right_table, edge.right_attr))

    def _execute_edge_co(self, edge: JoinEdge, ctxs: dict, done_tables: dict):
        """Join transformation for one edge. `done_tables`: table ->
        {doc_id: join-ready}, updated in place with survivors."""
        (t1, a1), (t2, a2) = self._edge_tables(edge)
        if t1 in done_tables and t2 in done_tables:
            return
        if t2 in done_tables:       # orient: t1 = side to execute first
            (t1, a1), (t2, a2) = (t2, a2), (t1, a1)
        if t1 not in done_tables:
            # choose direction by the two-term cost model (§3.2.1)
            c12 = self._table_first_two_terms(ctxs[t1], a1)
            c21 = self._table_first_two_terms(ctxs[t2], a2)
            if c21 < c12:
                (t1, a1), (t2, a2) = (t2, a2), (t1, a1)
            survivors = yield from self._execute_filters_co(ctxs[t1])
            done_tables[t1] = survivors
        else:
            survivors = done_tables[t1]
        # extract join attribute on side-1 survivors (one batched sweep)
        got = yield from self._extract_required_co(
            [(d, a1, t1) for d in survivors])
        values = {v for v in got.values() if v is not None}
        # transform join into IN filter on side 2, re-optimize, execute
        in_f = Filter(a2, "in", frozenset(values), table=t2)
        ctxs[t2].extra_filters.append(in_f)
        done_tables[t2] = yield from self._execute_filters_co(ctxs[t2])

    def _choose_first_edge(self, ctxs: dict) -> JoinEdge:
        best, best_cost = None, float("inf")
        for e in self.query.joins:
            (t1, a1), (t2, a2) = self._edge_tables(e)
            c = min(self._table_first_two_terms(ctxs[t1], a1),
                    self._table_first_two_terms(ctxs[t2], a2))
            if c < best_cost:
                best, best_cost = e, c
        return best

    def _choose_next_edge_co(self, ctxs: dict, done: dict, remaining: list):
        """Adaptive ordering (§3.2.2): among edges touching the joined
        prefix, estimate the IN-augmented cost on the new table."""
        best, best_cost = None, float("inf")
        for e in remaining:
            (t1, a1), (t2, a2) = self._edge_tables(e)
            if t1 in done and t2 in done:
                return e          # closing a cycle: free-ish, do it now
            if t1 not in done and t2 not in done:
                continue
            if t2 in done:
                (t1, a1), (t2, a2) = (t2, a2), (t1, a1)
            # survivors' join values may not all be extracted yet
            got = yield from self._extract_required_co(
                [(d, a1, t1) for d in done[t1]])
            values = {v for v in got.values() if v is not None}
            c = self._table_in_augmented_cost(ctxs[t2], a2, values)
            if c < best_cost:
                best, best_cost = e, c
        return best if best is not None else remaining[0]

    def _assemble_rows(self, done_tables: dict) -> list:
        """Materialize joined rows (hash join over extracted join attrs of
        surviving docs — the expensive extraction is already done)."""
        query = self.query
        tables = list(query.tables)
        rows = [{tables[0]: d} for d in done_tables.get(tables[0], [])]
        joined = {tables[0]}
        edges = list(query.joins)
        while edges:
            e = next((e for e in edges if
                      (e.left_table in joined) != (e.right_table in joined)
                      or (e.left_table in joined and e.right_table in joined)), None)
            if e is None:
                break
            edges.remove(e)
            (t1, a1), (t2, a2) = self._edge_tables(e)
            if t1 not in joined:
                (t1, a1), (t2, a2) = (t2, a2), (t1, a1)
            if t2 in joined:      # cycle edge: filter existing rows
                rows = [r for r in rows
                        if self._cache.get((r[t1], a1)) is not None
                        and self._cache.get((r[t1], a1)) == self._cache.get((r[t2], a2))]
                continue
            index = {}
            for d in done_tables.get(t2, []):
                index.setdefault(self._cache.get((d, a2)), []).append(d)
            new_rows = []
            for r in rows:
                v = self._cache.get((r[t1], a1))
                for d in index.get(v, []) if v is not None else []:
                    nr = dict(r)
                    nr[t2] = d
                    new_rows.append(nr)
            rows = new_rows
            joined.add(t2)
        return rows

    # ------------------------------------------------------------- main ---

    def run_co(self):
        """The whole-query state machine. Yields barriers (see class doc),
        emits result rows in streaming chunks as documents clear projection,
        and returns the query's meta dict."""
        query = self.query
        ctxs = {}
        for t in query.tables:
            ctxs[t] = yield from self._prepare_table_co(t)
        done: dict = {}
        if not query.joins:
            t = query.tables[0]
            done[t] = yield from self._execute_filters_co(ctxs[t])
            rows = [{t: d} for d in done[t]]
        elif self.join_strategy == "pushdown":
            # classical Plan (1): push filters into every table, extract the
            # join attributes of all survivors, hash join.
            for t in query.tables:
                done[t] = yield from self._execute_filters_co(ctxs[t])
            yield from self._extract_required_co(
                [(d, a, t) for e in query.joins
                 for t, a in self._edge_tables(e) for d in done.get(t, [])])
            rows = self._assemble_rows(done)
        else:
            remaining = list(query.joins)
            first = self._choose_first_edge(ctxs)
            remaining.remove(first)
            yield from self._execute_edge_co(first, ctxs, done)
            while remaining:
                nxt = yield from self._choose_next_edge_co(ctxs, done, remaining)
                remaining.remove(nxt)
                yield from self._execute_edge_co(nxt, ctxs, done)
            for t in query.tables:      # disconnected tables: plain filters
                if t not in done:
                    done[t] = yield from self._execute_filters_co(ctxs[t])
            rows = self._assemble_rows(done)

        # project SELECT attributes (extracted only for surviving rows), in
        # scheduler-sized chunks so rows *stream* out as their documents
        # clear projection; repeated docs across chunks dedup to one charge
        # through the shared cache, so token totals match the one-sweep path.
        for i in range(0, len(rows), self.batch_size):
            part = rows[i:i + self.batch_size]
            got = yield from self._extract_required_co(
                [(r[t], a, t) for r in part for t, a in query.select])
            out_rows = []
            for r in part:
                rec = {}
                ok = True
                for t, a in query.select:
                    v = got[(r[t], a)]
                    rec[f"{t}.{a}"] = v
                    if v is None:
                        ok = False
                rec["_docs"] = dict(r)
                if ok:
                    out_rows.append(rec)
            if out_rows:
                yield ("rows", out_rows)
        return {"survivors": {k: len(v) for k, v in done.items()},
                "sampling_reused": dict(self.sampling_reused)}


class Engine:
    """Single-query shim over `core.session.Session` (DESIGN.md §11): the
    original blocking entry point. Each `execute()` prepares, submits, and
    drains one query on the engine's session, so sequential queries share
    the session's value cache and sampling investment while their
    `QueryResult`s carry clean per-query ledgers and plan logs."""

    def __init__(self, retriever, extractor, *, sample_rate: float = 0.05,
                 seed: int = 0, ordering: str = "quest",
                 join_strategy: str = "transform",
                 ledger: Optional[CostLedger] = None,
                 batch_size: int = 1, queue_depth: int = 32):
        """ordering: quest | exhaust | avg_cost | selectivity | random
        (paper §5.3 baselines). join_strategy: transform | pushdown
        (paper §5.4: QUEST's join transformation vs. classical Plan (1)).
        batch_size/queue_depth: cross-document batching knobs (DESIGN.md §9);
        batch_size=1 is the serial per-extraction path."""
        from .session import Session
        self.session = Session(retriever, extractor, sample_rate=sample_rate,
                               seed=seed, ordering=ordering,
                               join_strategy=join_strategy, ledger=ledger,
                               batch_size=batch_size, queue_depth=queue_depth,
                               table_context_hook=self._wrap_table_context)

    # session-wide views, kept for existing call sites
    @property
    def retriever(self):
        return self.session.retriever

    @property
    def extractor(self):
        return self.session.extractor

    @property
    def ledger(self) -> CostLedger:
        return self.session.ledger

    @property
    def scheduler(self):
        return self.session.scheduler

    @property
    def _cache(self) -> dict:
        return self.session.cache

    def _wrap_table_context(self, ctx: TableContext, query: Query) -> TableContext:
        """Subclass hook: wrap/replace a freshly-built TableContext (e.g.
        benchmarks substitute ground-truth statistics)."""
        return ctx

    def execute(self, query: Query) -> QueryResult:
        return self.session.execute(query)
