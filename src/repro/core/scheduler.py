"""Cross-document batch scheduler for QUEST extraction (DESIGN.md §9).

QUEST's instance-optimized plans are *per document*: each document decides
lazily, filter by filter, which attribute to extract next. That is exactly
wrong for a continuous-batching LLM substrate, which wants many concurrent
requests. The scheduler reconciles the two: per-document plans run as
resumable coroutines (generators yielding `(doc_id, attr, table)` extraction
needs), and the scheduler accumulates the needs of all in-flight documents,
deduplicates them against the engine cache and within the round, retrieves
their segments in one vectorized pass, and submits them to the extractor as
`extract_batch` rounds — so prefill/decode genuinely interleave across
documents while every document keeps its own lazy short-circuit order.

Because batching happens only *across* documents (never reordering the
filters *within* one), result rows and ledger token totals are identical to
serial execution at every batch size (tests/test_batching.py).

Each round's deduplicated needs are additionally *grouped by shared
prompt prefix* — stable-sorted by (attr, table) before chunking — so
same-attribute extractions land in the same engine round and the serving
engine's prefix KV cache (DESIGN.md §10) prefills the shared template
once per group instead of once per document. Grouping only reorders
independent needs within a round, so result rows and ledger token totals
stay identical.

Under a `core.session.Session` (DESIGN.md §11) a round's needs may come
from several concurrent queries: `resolve_round` accepts the merged,
deduplicated needs of all in-flight queries with an `owners` map routing
each charge to the owning query's child ledger, so cross-query needs
share the same extract_batch rounds and (attr, table) prefix groups while
per-query token accounting stays exact.

Knobs: `batch_size` (max extractions per extract_batch round; 1 = the
serial per-extraction path), `queue_depth` (max in-flight documents),
`round_token_budget` (optional latency budget, DESIGN.md §16: a round is
cut when its cumulative *estimated* tokens — retrieved-segment tokens
plus prompt/answer overhead — would exceed the budget, not only when
`batch_size` items accumulate, bounding how long one extract_batch round
can occupy the engine before other work gets a turn; chunk boundaries
never change values or token columns, so the parity bar is unaffected).
"""
from __future__ import annotations

from collections import deque
from typing import Optional

from repro.data.tokens import count_tokens
from repro.obs import MetricsRegistry, StatsDict, as_tracer
from repro.obs.metrics import SCHEDULER_STATS

PROMPT_OVERHEAD = 40      # instruction tokens per extraction call
OUTPUT_TOKENS = 12        # answer tokens per extraction call


class SchedulerStats:
    """Scheduler counters, registry-backed (DESIGN.md §19): same attribute
    surface as the old dataclass (`stats.rounds += 1`, `snapshot()`), but
    each field lives in a `scheduler.*` instrument of a `MetricsRegistry`
    — so the counters ride the schema (touching an undeclared field is a
    hard error) and export through the registry's Prometheus exposition.
    Fields: rounds (extract_batch submissions), submitted (extractions
    sent), dedup_hits, cache_hits, empty_retrievals, max_batch."""

    def __init__(self, registry: MetricsRegistry = None):
        object.__setattr__(self, "_d",
                           StatsDict(registry or MetricsRegistry(),
                                     "scheduler", SCHEDULER_STATS))

    def __getattr__(self, key):
        try:
            return self.__dict__["_d"][key]
        except KeyError:
            raise AttributeError(key) from None

    def __setattr__(self, key, value) -> None:
        self._d[key] = value

    def snapshot(self) -> dict:
        return self._d.snapshot()


class RunQueue:
    """In-flight document coroutines under queue_depth admission control —
    the drive loop shared by `BatchScheduler.run` (single query) and the
    session multiplexer's run barriers (DESIGN.md §11).

    `collect()` returns one round's needs (one per still-blocked
    coroutine). When an entire admitted wave completes without yielding a
    need — e.g. every value was already in the session cache — the next
    wave is admitted and advanced immediately, so a round never comes back
    empty while work remains (returning empty-handed there would read as a
    stall to the caller)."""

    def __init__(self, coroutines: dict, queue_depth: int):
        self.pending = deque(coroutines.items())
        self.live: list = []
        self.results: dict = {}
        self.queue_depth = max(1, int(queue_depth))

    def collect(self, scheduler: "BatchScheduler") -> list:
        while True:
            while self.pending and len(self.live) < self.queue_depth:
                self.live.append(self.pending.popleft())
            needs, blocked = [], []
            for key, gen in self.live:
                need = scheduler._advance(key, gen, self.results)
                if need is not None:
                    needs.append(need)
                    blocked.append((key, gen))
            self.live = blocked
            if needs or self.done:
                return needs

    @property
    def done(self) -> bool:
        return not self.live and not self.pending


class BatchScheduler:
    """Drives per-document coroutines and batches their extraction needs.

    The coroutine protocol: a generator yields `(doc_id, attr, table)` when
    it needs `cache[(doc_id, attr)]` filled; the scheduler resumes it after
    the batched extraction lands. The generator's return value (via
    StopIteration) is its result.
    """

    def __init__(self, retriever, extractor, ledger, cache: dict, *,
                 batch_size: int = 1, queue_depth: int = 32,
                 round_token_budget: Optional[int] = None,
                 tracer=None, metrics: MetricsRegistry = None):
        self.retriever = retriever
        self.extractor = extractor
        self.ledger = ledger
        self.cache = cache
        self.batch_size = max(1, int(batch_size))
        self.queue_depth = max(1, int(queue_depth))
        self.round_token_budget = round_token_budget
        self.tracer = as_tracer(tracer)
        self.stats = SchedulerStats(metrics)

    # ------------------------------------------------------- coroutines ----

    def run(self, coroutines: dict, *, phase: str = "query") -> dict:
        """Drive {key: generator} to completion; returns {key: result}.

        Up to `queue_depth` coroutines are in flight; each round collects one
        pending extraction per blocked coroutine, resolves the deduplicated
        set in `batch_size` chunks, then resumes everyone.
        """
        queue = RunQueue(coroutines, self.queue_depth)
        while True:
            raw = queue.collect(self)
            if queue.done:
                return queue.results
            needs: dict = {}            # ordered de-dup of this round's keys
            for need in raw:
                if need in needs:
                    self.stats.dedup_hits += 1
                needs[need] = None
            self._resolve(list(needs), phase=phase)

    def _advance(self, key, gen, results):
        """Advance one coroutine until it blocks on an uncached extraction
        (returns the need) or finishes (records its result, returns None)."""
        while True:
            try:
                need = next(gen)
            except StopIteration as stop:
                results[key] = stop.value
                return None
            if (need[0], need[1]) not in self.cache:
                return need
            self.stats.cache_hits += 1

    # ------------------------------------------------------ bulk extract ---

    def extract_many(self, keys, *, phase: str = "query") -> dict:
        """Batch-extract `(doc_id, attr, table)` keys; returns
        {(doc_id, attr): value}. Duplicates and cached keys are charged once
        (or not at all) — the dedup guarantee of DESIGN.md §9."""
        todo, seen = [], set()
        for doc_id, attr, table in keys:
            k = (doc_id, attr)
            if k in seen:
                self.stats.dedup_hits += 1
                continue
            seen.add(k)
            if k in self.cache:
                self.stats.cache_hits += 1
                continue
            todo.append((doc_id, attr, table))
        self._resolve(todo, phase=phase)
        return {(d, a): self.cache.get((d, a)) for d, a, _ in keys}

    def resolve_round(self, needs: list, *, owners: dict = None,
                      phase: str = "query") -> None:
        """Resolve one multiplexed round of already-deduplicated needs —
        possibly spanning several concurrent queries (DESIGN.md §11).
        `owners` maps (doc_id, attr) -> the owning query's child ledger;
        unmapped needs charge the session ledger. Prefix grouping and
        chunking treat the merged round as one stream, so same-attribute
        needs from *different* queries share extract_batch rounds and
        prefix-cache groups."""
        self._resolve(needs, phase=phase, owners=owners)

    def _resolve(self, keys: list, *, phase: str, owners: dict = None) -> None:
        if not keys:
            return
        with self.tracer.span("scheduler.round", kind="scheduler",
                              needs=len(keys), phase=phase):
            keys = self._group_by_prefix(keys)
            for chunk in self._chunks(keys):
                self._extract_chunk(chunk, phase=phase, owners=owners)

    def _chunks(self, keys: list):
        """Cut the grouped round into extract_batch chunks: by count alone
        (legacy), or — with `round_token_budget` — also by cumulative
        estimated tokens, so one chunk never occupies the engine past the
        latency budget. A chunk always takes at least one item (an
        over-budget single extraction must still run)."""
        if self.round_token_budget is None:
            for i in range(0, len(keys), self.batch_size):
                yield keys[i:i + self.batch_size]
            return
        chunk, spent = [], 0
        for key in keys:
            est = self._estimate_tokens(key)
            if chunk and (len(chunk) >= self.batch_size or
                          spent + est > self.round_token_budget):
                yield chunk
                chunk, spent = [], 0
            chunk.append(key)
            spent += est
        if chunk:
            yield chunk

    def _estimate_tokens(self, key) -> int:
        """Pre-retrieval token estimate for one need (segment tokens plus
        the fixed prompt/answer overhead). Retrieval is index work, not LLM
        cost — looking segments up here charges nothing."""
        doc_id, attr, table = key
        segs = self.retriever.segments(doc_id, attr, table)
        return PROMPT_OVERHEAD + OUTPUT_TOKENS + \
            sum(count_tokens(s) for s in segs)

    @staticmethod
    def _group_by_prefix(keys: list) -> list:
        """Stable-group (doc, attr, table) needs by (attr, table): requests
        sharing a prompt prefix become adjacent, so they fall into the same
        extract_batch chunk and the engine's prefix cache hits."""
        order: dict = {}
        for _doc, attr, table in keys:
            order.setdefault((attr, table), len(order))
        return sorted(keys, key=lambda k: order[(k[1], k[2])])

    def _extract_chunk(self, chunk: list, *, phase: str,
                       owners: dict = None) -> None:
        prefetch = getattr(self.retriever, "prefetch_segments", None)
        if prefetch is not None and len(chunk) > 1:
            prefetch(chunk)
        items, slots = [], []
        for doc_id, attr, table in chunk:
            segs = self.retriever.segments(doc_id, attr, table)
            if not segs:
                # no relevant segments -> no LLM call at all (free negative)
                self.cache[(doc_id, attr)] = None
                self.stats.empty_retrievals += 1
                continue
            items.append((doc_id, attr, segs))
            slots.append((doc_id, attr))
        if not items:
            return
        hits0, saved0 = self._prefix_stats()
        spec0 = self._spec_stats()
        casc0 = self._cascade_stats()
        chunk_span = self.tracer.span(
            "scheduler.chunk", kind="scheduler", level=2, items=len(items),
            attrs_grouped=len({(a, t) for _d, a, t in chunk}))
        with chunk_span:
            if owners is not None and getattr(self.extractor,
                                              "accepts_owners", False):
                # opt-in protocol extension: the serving path maps each
                # item's owning child ledger to its tenant for admission
                # control. Gated on the attribute so duck-typed extractors
                # (tests, oracle stubs) keep the positional-only signature.
                out = self.extractor.extract_batch(
                    items, owners=[owners.get(k) for k in slots])
            else:
                out = self.extractor.extract_batch(items)
        hits1, saved1 = self._prefix_stats()
        spec1 = self._spec_stats()
        casc1 = self._cascade_stats()
        self.stats.rounds += 1
        self.stats.submitted += len(items)
        self.stats.max_batch = max(self.stats.max_batch, len(items))
        self.ledger.record_batch(len(items))
        self.ledger.record_prefix(hits1 - hits0, saved1 - saved0)
        self.ledger.record_spec(*(b - a for a, b in zip(spec0, spec1)))
        self.ledger.record_cascade(*(b - a for a, b in zip(casc0, casc1)))
        if owners:
            self.record_owner_batches(owners.get(k) for k in slots)
        for (doc_id, attr), (value, inp_tokens) in zip(slots, out):
            ledger = (owners or {}).get((doc_id, attr)) or self.ledger
            ledger.charge(inp=inp_tokens + PROMPT_OVERHEAD,
                          out=OUTPUT_TOKENS, phase=phase, attr=attr)
            self.cache[(doc_id, attr)] = value

    def record_owner_batches(self, ledgers) -> None:
        """Per-query batch participation for one shared chunk: each child
        ledger appearing in `ledgers` (one entry per chunk item; None or the
        session ledger itself are skipped) records one batch of its own item
        count — the session ledger records the shared round itself."""
        per: dict = {}
        for led in ledgers:
            if led is not None and led is not self.ledger:
                ent = per.setdefault(id(led), [led, 0])
                ent[1] += 1
        for led, n in per.values():
            led.record_batch(n)

    # -------------------------------------------------- sampling phase -----

    def extract_full_docs(self, doc_ids: list, attrs: list) -> dict:
        """Batched sampling-phase extraction (full-document prompts).
        Returns {doc_id: (values, segments_by_attr, input_tokens)} in the
        given order; the served path submits each chunk as one
        continuous-batching round."""
        res = self.extract_full_doc_items([(d, attrs) for d in doc_ids])
        return dict(zip(doc_ids, res))

    def extract_full_doc_items(self, items: list, owners: list = None) -> list:
        """Sampling rounds over `items = [(doc_id, attrs)]`, which may span
        several concurrent queries' sampling phases (DESIGN.md §11) — the
        chunks are shared continuous-batching rounds. `owners` (parallel to
        `items`, entries may be None) carries each item's child ledger for
        per-query batch counters. Returns results parallel to `items`."""
        out: list = []
        for i in range(0, len(items), self.batch_size):
            chunk = items[i:i + self.batch_size]
            hits0, saved0 = self._prefix_stats()
            spec0 = self._spec_stats()
            casc0 = self._cascade_stats()
            samp_span = self.tracer.span("scheduler.sampling_chunk",
                                         kind="scheduler", level=2,
                                         docs=len(chunk))
            with samp_span:
                if owners is not None and getattr(self.extractor,
                                                  "accepts_owners", False):
                    res = self.extractor.extract_full_doc_batch(
                        chunk, owners=owners[i:i + self.batch_size])
                else:
                    res = self.extractor.extract_full_doc_batch(chunk)
            hits1, saved1 = self._prefix_stats()
            spec1 = self._spec_stats()
            casc1 = self._cascade_stats()
            self.ledger.record_batch(len(chunk))
            self.ledger.record_prefix(hits1 - hits0, saved1 - saved0)
            self.ledger.record_spec(*(b - a for a, b in zip(spec0, spec1)))
            self.ledger.record_cascade(*(b - a
                                         for a, b in zip(casc0, casc1)))
            if owners:
                self.record_owner_batches(owners[i:i + self.batch_size])
            out.extend(res)
        return out

    def _prefix_stats(self):
        """(prefix_hits, saved_prefill_tokens) from the extractor, when it
        serves through an engine with the prefix KV cache (0 otherwise)."""
        st = getattr(self.extractor, "stats", None)
        return (getattr(st, "prefix_hits", 0),
                getattr(st, "saved_prefill_tokens", 0))

    def _spec_stats(self):
        """(draft_tokens, accepted_tokens, decode_steps_saved) from the
        extractor, when it serves through an engine with speculative
        decoding on (0 otherwise)."""
        st = getattr(self.extractor, "stats", None)
        return (getattr(st, "draft_tokens", 0),
                getattr(st, "accepted_tokens", 0),
                getattr(st, "decode_steps_saved", 0))

    def _cascade_stats(self):
        """(accepted_small, escalations, target_tokens_saved) from the
        extractor, when it is a model cascade (DESIGN.md §18; 0
        otherwise) — per-round deltas route to `ledger.record_cascade`
        like the prefix/spec counters above."""
        st = getattr(self.extractor, "stats", None)
        return (getattr(st, "accepted_small", 0),
                getattr(st, "escalations", 0),
                getattr(st, "target_tokens_saved", 0))
