"""Session layer: query lifecycle and concurrent multi-query execution
multiplexed over one serving engine (DESIGN.md §11).

A `Session` owns everything whose cost amortizes across queries:

  * the shared attribute-value cache (`(doc_id, attr) -> value`) and the
    escalation memo — a value any query extracted is free for the rest;
  * the per-table sampling investment (`TableSample`): the first query on
    a table pays the ~5% full-document sampling sweep, later queries
    whose attributes are covered reuse the statistics, thresholds, and
    cached sample values and skip their sampling phase entirely;
  * the session-wide `CostLedger`, with one `child()` ledger per query so
    `QueryResult` token columns and wall time are strictly per-query;
  * one `BatchScheduler` over one extractor/serving engine.

Lifecycle: `prepare(query)` validates up front (unknown table / op /
attribute errors surface here, never mid-extraction) and `explain()`s the
logical plan with sample-stat cost/selectivity estimates; `submit()`
starts execution and returns a `QueryHandle`; `QueryHandle.rows()`
streams result rows as documents clear projection, `result()` blocks for
the full `QueryResult`.

Concurrency model: cooperative, no threads. Every submitted query is a
`QueryRun` state machine (executor.py) yielding barrier requests; each
`Session._step()` round collects the pending extraction needs of *all*
in-flight queries, merges and deduplicates them, and resolves them in
shared `BatchScheduler` rounds — so extractions from different queries
batch into the same `extract_batch`/`engine.run()` rounds and group by
(attr, table) for prefix-KV reuse across queries. Any blocking call
(`rows()`, `result()`, `drain()`) advances the whole session, so progress
never depends on which handle the caller happens to be waiting on.

Multi-tenant serving (DESIGN.md §16): `submit(..., tenant=...)` routes
the query's charges through a per-tenant ledger layer (query -> tenant ->
session forwarding), tags its extraction requests so a `ServingFrontend`
can apply per-tenant fair-share admission, and `deadline_s` bounds how
long the query may stay in flight — an expired query is cancelled at the
top of the next `_step` and its `result()` raises `QueryTimeout`.
`Session.cancel(handle)` / `QueryHandle.cancel()` aborts a query early;
both paths release every resource the query held (sampling reservations
roll back exactly as on failure) so concurrent queries never stall on a
dead owner. `QueryHandle.aresult()` / `Session.adrain()` are awaitable
facades over the same cooperative `_step` pump for asyncio callers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterator, Optional, Union

from repro.obs import MetricsRegistry, as_tracer, build_report, render_report

from .executor import QueryResult, QueryRun, TableSample, table_query_attrs
from .expr import Query, QueryError, iter_filters
from .ledger import CostLedger
from .ordering import plan_expression
from .scheduler import (OUTPUT_TOKENS, PROMPT_OVERHEAD, BatchScheduler,
                        RunQueue)
from .stats import SampleStats, sample_size

__all__ = ["Session", "PreparedQuery", "QueryHandle", "QueryError",
           "QueryCancelled", "QueryTimeout"]


class QueryCancelled(RuntimeError):
    """The query was cancelled before completing; raised by `result()` /
    `rows()` of a handle that `Session.cancel()` was called on."""


class QueryTimeout(QueryCancelled):
    """The query's `deadline_s` elapsed before it completed. A subclass of
    QueryCancelled: timeout is cancellation with a clock as the caller."""


# --------------------------------------------------------------- barriers --

# A query's in-flight document coroutines are a scheduler RunQueue: one
# `collect()` per session step mirrors one `BatchScheduler.run` round
# (including immediate re-admission when a whole wave resolves from
# cache), so several queries' rounds land in the same shared chunks.
_RunBarrier = RunQueue


class _OneShotBarrier:
    """Base for barriers resolved wholesale in a single session round."""

    def __init__(self):
        self.ready = False
        self.value = None


class _ExtractBarrier(_OneShotBarrier):
    def __init__(self, keys: list):            # [(doc_id, attr, table)]
        super().__init__()
        self.keys = list(keys)


class _EscalateBarrier(_OneShotBarrier):
    def __init__(self, keys: list):            # [(doc_id, attr)]
        super().__init__()
        self.keys = list(keys)


class _FullDocsBarrier(_OneShotBarrier):
    def __init__(self, items: list):           # [(doc_id, attrs)]
        super().__init__()
        self.items = list(items)


class _SampleWait:
    """Blocked on another query's in-progress sampling of `table`."""

    def __init__(self, table: str, attrs: frozenset):
        self.table = table
        self.attrs = attrs


class _SampleReservation:
    """Marks a table's sampling as in progress, owned by one handle.
    `prior` keeps the previously-published sample (when re-sampling an
    uncovered table) so it can be widened into the new sweep — and
    restored if the owner fails before publishing."""

    def __init__(self, owner: "QueryHandle", prior: TableSample = None):
        self.owner = owner
        self.prior = prior


class _RoundWork:
    """One session round's merged work, deduplicated across queries by
    (doc_id, attr) — the cache key — so the same value is never extracted
    twice in a round no matter how many queries ask for it."""

    def __init__(self):
        self.order: list = []       # (doc_id, attr, table), arrival order
        self.seen: set = set()      # (doc_id, attr)
        self.owners: dict = {}      # (doc_id, attr) -> owning child ledger
        self.extract: list = []     # (handle, _ExtractBarrier)
        self.escalate: list = []    # (handle, _EscalateBarrier)
        self.full: list = []        # (handle, _FullDocsBarrier)

    def add_needs(self, handle: "QueryHandle", needs: list,
                  scheduler: BatchScheduler) -> None:
        for need in needs:
            k = (need[0], need[1])
            if k in self.seen:
                scheduler.stats.dedup_hits += 1
                continue
            self.seen.add(k)
            self.order.append(need)
            self.owners[k] = handle.ledger

    def add_extract(self, handle: "QueryHandle", barrier: _ExtractBarrier,
                    scheduler: BatchScheduler) -> None:
        self.extract.append((handle, barrier))
        for doc_id, attr, table in barrier.keys:
            k = (doc_id, attr)
            if k in scheduler.cache:
                scheduler.stats.cache_hits += 1
            elif k in self.seen:
                scheduler.stats.dedup_hits += 1
            else:
                self.seen.add(k)
                self.order.append((doc_id, attr, table))
                self.owners[k] = handle.ledger

    @property
    def empty(self) -> bool:
        return not (self.order or self.extract or self.escalate or self.full)


# ---------------------------------------------------------------- handles --


class QueryHandle:
    """One in-flight query. `rows()` streams result rows as documents clear
    projection; `result()` blocks for the full `QueryResult`. Iterating or
    blocking on any handle advances the *whole* session, so concurrent
    handles make progress together and share extraction rounds."""

    def __init__(self, session: "Session", prepared: "PreparedQuery", *,
                 tenant: Optional[str] = None, priority: int = 0,
                 deadline_s: Optional[float] = None):
        self.session = session
        self.query = prepared.query
        self.qid = session._next_qid()
        self.tenant = tenant or ""
        self.priority = priority
        # query ledger hangs off the tenant layer when one is named, so
        # charges forward query -> tenant -> session and the ledger's
        # tenant tag rides to the serving tier via scheduler owners=
        parent = (session._tenant_ledger(tenant) if tenant
                  else session.ledger)
        self.ledger = parent.child()
        self._make_run()
        self.reservations: set = set()      # tables whose sampling we own
        self.acquired: set = set()          # tables we hold/held for execution
        self._rows: list = []
        self._done = False
        self._error: Optional[BaseException] = None
        self._result: Optional[QueryResult] = None
        self._t0 = time.time()
        self.deadline = (self._t0 + deadline_s
                         if deadline_s is not None else None)
        self._span = -1                     # tracer id of the lifecycle span

    def _make_run(self) -> None:
        """(Re-)build the query's execution state machine from current
        session state. Called at submit, and again by `LiveSession` when a
        corpus mutation restarts an in-flight query: the fresh QueryRun
        sees the post-mutation snapshot, same seed (sampling parity with a
        fresh session), charges still on this handle's ledger."""
        session = self.session
        self.run = QueryRun(
            self.query, retriever=session.retriever,
            extractor=session.extractor, cache=session.cache,
            escalated=session._escalated, ledger=self.ledger,
            seed=session.seed, sample_rate=session.sample_rate,
            ordering=session.ordering, join_strategy=session.join_strategy,
            batch_size=session.scheduler.batch_size,
            ctx_hook=session.table_context_hook)
        self.gen = self.run.run_co()
        self.barrier = None
        self.send_value = None

    # -- consumption ------------------------------------------------------

    @property
    def done(self) -> bool:
        return self._done

    def cancel(self) -> bool:
        """Abort this query; returns False if it already finished. Its
        `result()`/`rows()` raise `QueryCancelled` from then on."""
        return self.session.cancel(self)

    def rows(self) -> Iterator[dict]:
        """Stream result rows in arrival order, each exactly once per
        iterator. Drives the session until this query finishes."""
        i = 0
        while not self._done or i < len(self._rows):
            if i < len(self._rows):
                yield self._rows[i]
                i += 1
            else:
                self.session._step()
        if self._error is not None:
            raise self._error

    def result(self) -> QueryResult:
        """Block until the query completes; returns the full QueryResult
        (rows identical to what `rows()` streamed)."""
        while not self._done:
            self.session._step()
        if self._error is not None:
            raise self._error
        return self._result

    async def aresult(self) -> QueryResult:
        """Awaitable `result()`: one cooperative session round per event-
        loop turn, yielding control between rounds so other coroutines
        (and other handles' awaiters) interleave. Rows and ledger columns
        are byte-identical to the blocking path — same `_step` pump, the
        event loop just owns the outer loop."""
        import asyncio
        while not self._done:
            self.session._step()
            await asyncio.sleep(0)
        if self._error is not None:
            raise self._error
        return self._result

    def report(self) -> dict:
        """EXPLAIN ANALYZE (DESIGN.md §19): estimated-vs-actual per plan
        stage — selectivity, tokens per invocation, tier split — plus the
        savings columns and (when a tracer is attached) per-kind wall
        attribution. The query must have finished."""
        return build_report(self)

    def report_text(self) -> str:
        return render_report(self.report())

    # -- session-side hooks ----------------------------------------------

    def _emit(self, rows: list) -> None:
        self._rows.extend(rows)

    def _finish(self, meta: dict) -> None:
        self.ledger.wall_time_s += time.time() - self._t0
        self._result = QueryResult(list(self._rows), self.ledger,
                                   dict(self.run._plan_log), meta=dict(meta))
        self._done = True

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self.ledger.wall_time_s += time.time() - self._t0
        self._done = True


@dataclass
class PreparedQuery:
    """A validated query bound to a session: `explain()` before paying for
    anything, `submit()` when ready."""
    session: "Session"
    query: Query

    def explain(self) -> dict:
        """Logical-plan summary with sample-stat cost/selectivity estimates
        per stage (estimates come from the session's sampling investment
        when the table is already sampled, defaults otherwise)."""
        return self.session._explain(self.query)

    def explain_text(self) -> str:
        return render_explain(self.explain())

    def submit(self, *, tenant: Optional[str] = None, priority: int = 0,
               deadline_s: Optional[float] = None) -> QueryHandle:
        return self.session.submit(self, tenant=tenant, priority=priority,
                                   deadline_s=deadline_s)


def render_explain(plan: dict) -> str:
    """Human-readable rendering of `PreparedQuery.explain()`."""
    lines = [f"QUERY  {plan['query']}",
             f"  ordering={plan['ordering']} join_strategy="
             f"{plan['join_strategy']} batch_size={plan['batch_size']}"]
    for t in plan["tables"]:
        s = t["sampling"]
        samp = (f"sampling: reused ({s['n_sampled']} docs already paid)"
                if s.get("reused") else
                f"sampling: will sample ~{s['planned_sample']} docs")
        lines.append(f"  TABLE {t['table']}: {t['candidate_docs']} candidate "
                     f"docs | {samp}")
        for st in t.get("stages", []):
            split = st.get("predicted_tier_split")
            tier = (f", cascade small {split['small']:.0%}" if split else "")
            lines.append(f"    - {st['filter']}  [sel={st['selectivity']}, "
                         f"~{st['mean_cost_tokens']} tok/doc{tier}]")
        if "est_cost_tokens_per_doc" in t:
            lines.append(f"    => est {t['est_cost_tokens_per_doc']} tok/doc x "
                         f"{t['candidate_docs']} docs = "
                         f"~{t['est_total_cost_tokens']} tokens, "
                         f"pass rate {t['est_pass_rate']}")
        if t["select"]:
            lines.append(f"    SELECT {', '.join(t['select'])}")
    for j in plan["joins"]:
        lines.append(f"  JOIN {j}")
    return "\n".join(lines)


# ---------------------------------------------------------------- session --


class Session:
    """See module docstring. `table_context_hook(ctx, query)` is an optional
    wrapper applied to each freshly-built TableContext (benchmarks use it to
    substitute ground-truth statistics)."""

    def __init__(self, retriever, extractor, *, sample_rate: float = 0.05,
                 seed: int = 0, ordering: str = "quest",
                 join_strategy: str = "transform",
                 ledger: Optional[CostLedger] = None,
                 batch_size: int = 1, queue_depth: int = 32,
                 round_token_budget: Optional[int] = None,
                 table_context_hook=None,
                 tracer=None, metrics: Optional[MetricsRegistry] = None):
        self.retriever = retriever
        self.extractor = extractor
        self.sample_rate = sample_rate
        self.seed = seed
        self.ordering = ordering
        self.join_strategy = join_strategy
        self.ledger = ledger if ledger is not None else CostLedger()
        self.table_context_hook = table_context_hook
        self.cache: dict = {}               # (doc_id, attr) -> value
        self._escalated: set = set()        # keys already retried full-doc
        # observability (DESIGN.md §19): tracer defaults to the shared
        # no-op; the registry holds session.* and scheduler.* instruments
        # (share one registry across session/engine/frontend for a single
        # exposition surface — but one registry per engine)
        self.tracer = as_tracer(tracer)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m = {k: self.metrics.counter(f"session.{k}")
                   for k in ("queries", "queries_finished", "queries_failed",
                             "steps")}
        self.scheduler = BatchScheduler(retriever, extractor, self.ledger,
                                        self.cache, batch_size=batch_size,
                                        queue_depth=queue_depth,
                                        round_token_budget=round_token_budget,
                                        tracer=self.tracer,
                                        metrics=self.metrics)
        self._samples: dict = {}    # table -> TableSample | _SampleReservation
        self._active: list = []     # in-flight QueryHandles, submit order
        self._tenant_ledgers: dict = {}     # tenant -> per-tenant CostLedger
        self._qid = 0

    def _next_qid(self) -> int:
        self._qid += 1
        return self._qid

    def _tenant_ledger(self, tenant: str) -> CostLedger:
        """Memoized per-tenant layer between session and query ledgers."""
        led = self._tenant_ledgers.get(tenant)
        if led is None:
            led = self.ledger.child(tenant=tenant)
            self._tenant_ledgers[tenant] = led
        return led

    def tenant_costs(self) -> dict:
        """tenant -> ledger snapshot, for everything charged under it."""
        return {t: led.snapshot()
                for t, led in sorted(self._tenant_ledgers.items())}

    # ------------------------------------------------------------ prepare --

    def prepare(self, query: Query) -> PreparedQuery:
        """Validate up front: structure (tables declared for every SELECT/
        WHERE/join reference — also enforced at Query construction) plus
        corpus-level name resolution. Raises `QueryError`; nothing is
        charged."""
        query.validate()
        corpus = getattr(self.retriever, "corpus", None)
        if corpus is None:
            corpus = getattr(self.extractor, "corpus", None)
        if corpus is not None:
            self._check_names(query, corpus)
        return PreparedQuery(self, query)

    @staticmethod
    def _check_names(query: Query, corpus) -> None:
        for t in query.tables:
            if t not in corpus.tables:
                raise QueryError(
                    f"unknown table {t!r} (corpus tables: "
                    f"{sorted(corpus.tables)})")
        known_any: set = set()
        for t in query.tables:
            known_any |= set(corpus.attr_specs.get(t, {}))
        for t in query.tables:
            known = set(corpus.attr_specs.get(t, {}))
            for a in query.select_attrs(t):
                if a not in known:
                    raise QueryError(
                        f"unknown SELECT attribute {t}.{a} (table has: "
                        f"{sorted(known)})")
        for f in iter_filters(query.where):
            if f.table:
                if f.attr not in corpus.attr_specs.get(f.table, {}):
                    raise QueryError(
                        f"unknown WHERE attribute {f.table}.{f.attr}")
            elif f.attr not in known_any:
                raise QueryError(
                    f"unknown WHERE attribute {f.attr!r} (no queried table "
                    f"defines it)")
        for j in query.joins:
            for t, a in ((j.left_table, j.left_attr),
                         (j.right_table, j.right_attr)):
                if a not in corpus.attr_specs.get(t, {}):
                    raise QueryError(f"unknown join attribute {t}.{a}")

    # ------------------------------------------------------------ explain --

    def _explain(self, query: Query) -> dict:
        out = {"query": str(query), "ordering": self.ordering,
               "join_strategy": self.join_strategy,
               "batch_size": self.scheduler.batch_size,
               "tables": [], "joins": [str(j) for j in query.joins]}
        for t in query.tables:
            attrs = table_query_attrs(query, t)
            sample = self._samples.get(t)
            covered = (isinstance(sample, TableSample)
                       and set(attrs) <= sample.attrs)
            stats = sample.stats if covered else SampleStats(table=t)
            cands = len(self.retriever.candidate_docs(t, attrs))
            entry = {
                "table": t, "attrs": attrs, "candidate_docs": cands,
                "sampling": ({"reused": True, "n_sampled": stats.n_sampled}
                             if covered else
                             {"reused": False, "planned_sample":
                              sample_size(cands, self.sample_rate)}),
                "select": query.select_attrs(t),
            }
            expr = query.where_for(t)
            if expr is not None:
                plan = plan_expression(
                    expr, lambda f: stats.mean_cost(f.attr), stats.selectivity)
                entry["plan"] = plan.describe()
                entry["est_cost_tokens_per_doc"] = round(plan.cost, 2)
                entry["est_total_cost_tokens"] = round(plan.cost * cands)
                entry["est_pass_rate"] = round(plan.prob, 4)
                est = getattr(self.extractor, "difficulty", None)
                entry["stages"] = []
                for f in plan.ordered_filters():
                    stage = {"filter": str(f), "attr": f.attr,
                             "selectivity": round(stats.selectivity(f), 4),
                             "mean_cost_tokens":
                                 round(stats.mean_cost(f.attr), 2)}
                    if est is not None:
                        # predicted cascade tier mix for this stage, from
                        # the sampled docs' difficulty scores (§18); None
                        # until the table's sampling phase has folded
                        stage["predicted_tier_split"] = \
                            est.predicted_split(t, f.attr)
                    entry["stages"].append(stage)
            out["tables"].append(entry)
        return out

    # ------------------------------------------------------------- submit --

    def submit(self, prepared: Union[PreparedQuery, Query], *,
               tenant: Optional[str] = None, priority: int = 0,
               deadline_s: Optional[float] = None) -> QueryHandle:
        """Start executing a prepared query; returns its handle. Execution
        interleaves with every other in-flight handle's from the next
        `_step` on, whoever drives it. `tenant` routes charges through a
        per-tenant ledger and tags the query's serving requests for
        admission control; `deadline_s` cancels the query (with
        `QueryTimeout`) if it is still in flight that many seconds after
        submit."""
        if isinstance(prepared, Query):
            prepared = self.prepare(prepared)
        if prepared.session is not self:
            raise QueryError("prepared query belongs to a different session")
        handle = QueryHandle(self, prepared, tenant=tenant,
                             priority=priority, deadline_s=deadline_s)
        self._active.append(handle)
        self._m["queries"].inc()
        handle._span = self.tracer.begin(
            "session.query", kind="query", qid=handle.qid,
            tenant=handle.tenant, tables=list(handle.query.tables))
        return handle

    def execute(self, query: Union[PreparedQuery, Query]) -> QueryResult:
        """Single-query convenience: prepare + submit + block."""
        return self.submit(query).result()

    def cancel(self, handle: QueryHandle,
               err: Optional[BaseException] = None) -> bool:
        """Abort an in-flight query. Returns False if it already finished
        (a completed result is never retracted). Everything the query
        holds is released — its coroutine is closed, unpublished sampling
        reservations roll back to the prior sample — so queries blocked on
        its sampling re-acquire next round instead of stalling."""
        if handle not in self._active:
            return False
        handle.gen.close()
        self._failed(handle, err or QueryCancelled(
            f"query {handle.qid} cancelled"))
        return True

    def _expire_deadlines(self) -> None:
        now = time.time()
        for h in list(self._active):
            if h.deadline is not None and now >= h.deadline:
                self.cancel(h, QueryTimeout(
                    f"query {h.qid} exceeded deadline of "
                    f"{h.deadline - h._t0:.3f}s"))

    def drain(self) -> None:
        """Drive every in-flight query to completion."""
        while self._active:
            self._step()

    async def adrain(self) -> None:
        """Awaitable `drain()`: one `_step` round per event-loop turn."""
        import asyncio
        while self._active:
            self._step()
            await asyncio.sleep(0)

    # -------------------------------------------------------- multiplexer --

    def _step(self) -> bool:
        """One multiplexed round: pump every in-flight query to its next
        blocking point, merge all pending work, resolve it in shared
        scheduler rounds. Returns False when nothing remains in flight."""
        if not self._active:
            return False
        t0 = time.time()
        self._expire_deadlines()
        if not self._active:
            return False
        self._m["steps"].inc()
        work = _RoundWork()
        progressed = False
        with self.tracer.span("session.step", kind="session",
                              in_flight=len(self._active)):
            for h in list(self._active):
                if h not in self._active:   # cancelled by a hook mid-round
                    continue
                progressed |= self._pump(h, work)
            if not work.empty:
                progressed = True
                self._resolve_work(work)
        self.ledger.wall_time_s += time.time() - t0
        if not progressed and self._active:
            raise RuntimeError(
                "session stalled: in-flight queries cannot make progress")
        return bool(self._active)

    def _pump(self, h: QueryHandle, work: _RoundWork) -> bool:
        """Advance one handle as far as it can go without resolving new
        extractions; contribute its blocked work to the round."""
        progressed = False
        while True:
            b = h.barrier
            if b is None:
                try:
                    op = h.gen.send(h.send_value)
                except StopIteration as stop:
                    self._finish(h, stop.value or {})
                    return True
                except Exception as err:    # noqa: BLE001 — query-scoped
                    self._failed(h, err)
                    return True
                h.send_value = None
                progressed = True
                kind = op[0]
                if self.tracer.enabled(2):
                    self.tracer.instant("query.barrier", kind="query",
                                        level=2, qid=h.qid, barrier=kind)
                if kind == "rows":
                    h._emit(op[1])
                elif kind == "sample_publish":
                    self._publish_sample(h, op[1])
                    self.tracer.instant("query.sample_publish", kind="query",
                                        qid=h.qid, table=op[1].table)
                elif kind == "sample_acquire":
                    got = self._try_acquire(h, op[1], frozenset(op[2]))
                    if got is None:
                        h.barrier = _SampleWait(op[1], frozenset(op[2]))
                        return progressed
                    h.send_value = got
                elif kind == "run":
                    h.barrier = _RunBarrier(op[1], self.scheduler.queue_depth)
                elif kind == "extract":
                    h.barrier = _ExtractBarrier(op[1])
                elif kind == "escalate":
                    h.barrier = _EscalateBarrier(op[1])
                elif kind == "full_docs":
                    h.barrier = _FullDocsBarrier(op[1])
                else:
                    self._failed(h, RuntimeError(f"unknown barrier {kind!r}"))
                    return True
                continue
            if isinstance(b, _SampleWait):
                got = self._try_acquire(h, b.table, b.attrs)
                if got is None:
                    return progressed
                h.barrier, h.send_value = None, got
                progressed = True
                continue
            if isinstance(b, _RunBarrier):
                try:
                    needs = b.collect(self.scheduler)
                except Exception as err:    # noqa: BLE001 — a document
                    # coroutine raised: fail this query only, like the
                    # gen.send path (its uncontributed needs are dropped)
                    self._failed(h, err)
                    return True
                if b.done:
                    h.barrier, h.send_value = None, b.results
                    progressed = True
                    continue
                work.add_needs(h, needs, self.scheduler)
                return progressed
            if b.ready:
                h.barrier, h.send_value = None, b.value
                progressed = True
                continue
            if isinstance(b, _ExtractBarrier):
                work.add_extract(h, b, self.scheduler)
            elif isinstance(b, _EscalateBarrier):
                work.escalate.append((h, b))
            elif isinstance(b, _FullDocsBarrier):
                work.full.append((h, b))
            return progressed

    def _resolve_work(self, work: _RoundWork) -> None:
        # sampling rounds first (a query can only be in one phase at a time,
        # so ordering across barrier kinds never reorders within a query)
        if work.full:
            items, owners, spans = [], [], []
            for h, b in work.full:
                spans.append((b, len(items), len(b.items)))
                items.extend(b.items)
                owners.extend([h.ledger] * len(b.items))
            with self.tracer.span("session.sampling_round", kind="session",
                                  docs=len(items)):
                res = self.scheduler.extract_full_doc_items(items, owners)
            for b, off, n in spans:
                b.value = {d: r for (d, _a), r in
                           zip(b.items, res[off:off + n])}
                b.ready = True
        if work.order:
            self.scheduler.resolve_round(work.order, owners=work.owners)
        for _h, b in work.extract:
            b.value = {(d, a): self.cache.get((d, a)) for d, a, _t in b.keys}
            b.ready = True
        if work.escalate:
            with self.tracer.span("session.escalate_round", kind="session",
                                  queries=len(work.escalate)):
                self._resolve_escalations(work.escalate)

    def _resolve_escalations(self, escalations: list) -> None:
        """Full-document-prompt retries for output-critical attrs
        (DESIGN.md §8.3), batched across queries. The same key requested by
        several queries in one round is retried once (first owner pays,
        everyone receives the value); the session escalation memo is marked
        here, at resolve time, so a query pumped later in the same step
        never mistakes an in-flight retry for an already-settled one."""
        corpus = self.extractor.corpus
        flat = []
        for h, b in escalations:
            for k in b.keys:
                if k in self._escalated:    # settled, or claimed this round
                    continue
                self._escalated.add(k)
                flat.append((k[0], k[1], h))
        bs = self.scheduler.batch_size
        # extractors may expose a dedicated escalation entry point (served:
        # doc-first prompt layout so full-document retries share the doc
        # prefix KV across attrs); default to the plain batch path
        run_batch = getattr(self.extractor, "escalate_batch",
                            self.extractor.extract_batch)
        for i in range(0, len(flat), bs):
            chunk = flat[i:i + bs]
            batch = [(d, a, [corpus.docs[d].text]) for d, a, _h in chunk]
            out = run_batch(batch)
            self.ledger.record_batch(len(batch))
            self.scheduler.record_owner_batches(h.ledger for _d, _a, h in chunk)
            for (d, a, h), (value, inp_tokens) in zip(chunk, out):
                h.ledger.charge(inp=inp_tokens + PROMPT_OVERHEAD,
                                out=OUTPUT_TOKENS, phase="query", attr=a)
                if value is not None:
                    self.cache[(d, a)] = value
        for _h, b in escalations:
            b.value = {k: self.cache.get(k) for k in b.keys}
            b.ready = True

    # --------------------------------------------- live-corpus invalidation --

    def drop_doc_state(self, doc_id) -> dict:
        """Exact per-document invalidation (DESIGN.md §17): remove every
        cached attr value and escalation memo keyed to `doc_id` — plus,
        under a cascade extractor (§18), its memoized difficulty estimates
        and tier-escalation memo entries (post-mutation content deserves a
        fresh routing decision and a fresh shot at the small tier). Called
        by the live cascade when the document mutates — a stale value must
        never satisfy a post-mutation query. Returns drop counts."""
        cache_keys = [k for k in self.cache if k[0] == doc_id]
        for k in cache_keys:
            del self.cache[k]
        esc_keys = [k for k in self._escalated if k[0] == doc_id]
        self._escalated.difference_update(esc_keys)
        est = getattr(self.extractor, "difficulty", None)
        n_difficulty = est.drop_doc(doc_id) if est is not None else 0
        tier_memo = getattr(self.extractor, "tier_memo", None)
        tier_keys = ([k for k in tier_memo if k[0] == doc_id]
                     if tier_memo is not None else [])
        if tier_keys:
            tier_memo.difference_update(tier_keys)
        return {"cache_entries": len(cache_keys),
                "escalations": len(esc_keys),
                "difficulty_estimates": n_difficulty,
                "tier_memo": len(tier_keys)}

    def invalidate_table_sample(self, table: str) -> bool:
        """Drop `table`'s sampling investment: the published sample is
        removed (next query re-samples), and an in-progress reservation
        loses its stale `prior` (the owner's sweep still publishes, built
        from post-mutation extractions). Returns True if anything
        dropped."""
        cur = self._samples.get(table)
        if isinstance(cur, TableSample):
            del self._samples[table]
            return True
        if isinstance(cur, _SampleReservation) and cur.prior is not None:
            cur.prior = None
            return True
        return False

    # ------------------------------------------------- sampling ownership --

    def _try_acquire(self, h: QueryHandle, table: str, attrs: frozenset):
        """Resolve a sample_acquire: reuse a covering published sample,
        wait on another query's in-progress sampling, or reserve the table
        and sample ourselves. An *uncovered* query first waits for every
        in-flight query already executing on the table to finish — its
        re-sampling mutates the shared thresholds/evidence/cache, which
        must never happen under a running query — then re-samples the
        union of its attrs and the prior sample's, so paid coverage only
        ever grows."""
        cur = self._samples.get(table)
        if isinstance(cur, TableSample) and attrs <= cur.attrs:
            h.acquired.add(table)
            return ("reuse", cur)
        if isinstance(cur, _SampleReservation):
            if cur.owner is h:
                return ("own", cur.prior)
            return None
        if isinstance(cur, TableSample):     # published but not covering
            if any(o is not h and table in o.acquired for o in self._active):
                return None                  # wait for the table to go quiet
            self._samples[table] = _SampleReservation(h, prior=cur)
        else:
            self._samples[table] = _SampleReservation(h)
        h.reservations.add(table)
        h.acquired.add(table)
        return ("own", self._samples[table].prior)

    def _publish_sample(self, h: QueryHandle, sample: TableSample) -> None:
        # model cascade (DESIGN.md §18): fold the paid sampling sweep into
        # the difficulty estimator at the moment the sample becomes shared
        # state — the summary rides on the TableSample so explain() and
        # later covered queries see the predicted tier mix without refolding
        est = getattr(self.extractor, "difficulty", None)
        if est is not None:
            sample.difficulty = est.fold_sample(
                sample.table, sample.attrs, sample.stats,
                sampled=sample.sampled)
        self._samples[sample.table] = sample
        h.reservations.discard(sample.table)

    def _release(self, h: QueryHandle) -> None:
        """A finished/failed handle's unpublished reservations are rolled
        back — to the prior published sample when re-sampling, else cleared
        — so waiters re-acquire instead of stalling."""
        for table in list(h.reservations):
            cur = self._samples.get(table)
            if isinstance(cur, _SampleReservation) and cur.owner is h:
                if cur.prior is not None:
                    self._samples[table] = cur.prior
                else:
                    del self._samples[table]
        h.reservations.clear()

    def _finish(self, h: QueryHandle, meta: dict) -> None:
        h._finish(meta)
        self._active.remove(h)
        self._release(h)
        self._m["queries_finished"].inc()
        self.tracer.end(h._span, rows=len(h._rows))

    def _failed(self, h: QueryHandle, err: BaseException) -> None:
        h._fail(err)
        self._active.remove(h)
        self._release(h)
        self._m["queries_failed"].inc()
        self.tracer.end(h._span, error=type(err).__name__)
