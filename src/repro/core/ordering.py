"""Filter ordering (paper §3.1, Algorithm 1).

Per document, each filter gets a cost `c` (tokens of the segments the index
retrieved for its attribute *in that document*) and a selectivity `p`
(estimated on the sample). Conjunctions sort by (1-p)/c descending (Lemma 1),
disjunctions by p/c (Eq. 5), and mixed AND/OR trees are handled by the
recursive decomposition of Eq. 6: each node's children are ordered
independently because the weight (selectivity) of a sub-expression is
order-invariant. Overall O(|filters| log |filters|).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, List

from .expr import And, Expr, Filter, Or

_EPS = 1e-9


@dataclass
class PlanNode:
    kind: str                       # 'filter' | 'and' | 'or'
    filter: Filter | None = None
    children: List["PlanNode"] = field(default_factory=list)  # ordered!
    cost: float = 0.0               # C*: expected evaluation cost
    prob: float = 1.0               # P(node is True)

    def ordered_filters(self) -> list[Filter]:
        if self.kind == "filter":
            return [self.filter]
        out = []
        for c in self.children:
            out.extend(c.ordered_filters())
        return out

    def describe(self) -> str:
        if self.kind == "filter":
            return str(self.filter)
        sep = " AND " if self.kind == "and" else " OR "
        return "(" + sep.join(c.describe() for c in self.children) + ")"


def _flatten(expr: Expr) -> Expr:
    """Merge nested same-operator nodes (same precedence => one ordering
    scope, as in the paper's expression-tree construction)."""
    if isinstance(expr, Filter):
        return expr
    cls = type(expr)
    kids = []
    for c in expr.children:
        fc = _flatten(c)
        if isinstance(fc, cls):
            kids.extend(fc.children)
        else:
            kids.append(fc)
    return cls(tuple(kids))


def _combine(kind: str, planned: list[PlanNode]) -> PlanNode:
    """Expected cost / selectivity of ordered children (Eq. 2 / Eq. 4)."""
    cost, reach = 0.0, 1.0
    for ch in planned:
        cost += ch.cost * reach
        reach *= ch.prob if kind == "and" else (1.0 - ch.prob)
    prob = reach if kind == "and" else 1.0 - reach
    return PlanNode(kind, children=planned, cost=cost, prob=prob)


def plan_expression(expr: Expr,
                    cost_fn: Callable[[Filter], float],
                    sel_fn: Callable[[Filter], float]) -> PlanNode:
    """Algorithm 1: recursive optimal ordering. Returns the planned tree with
    children sorted into execution order and (cost=C*, prob) at every node."""
    expr = _flatten(expr)
    return _plan(expr, cost_fn, sel_fn)


def _plan(expr: Expr, cost_fn, sel_fn) -> PlanNode:
    if isinstance(expr, Filter):
        return PlanNode("filter", filter=expr,
                        cost=float(cost_fn(expr)), prob=float(sel_fn(expr)))
    kind = "and" if isinstance(expr, And) else "or"
    planned = [_plan(c, cost_fn, sel_fn) for c in expr.children]
    if kind == "and":
        planned.sort(key=lambda n: -((1.0 - n.prob) / max(n.cost, _EPS)))
    else:
        planned.sort(key=lambda n: -(n.prob / max(n.cost, _EPS)))
    return _combine(kind, planned)


# ------------------------------------------------------- baselines ---------


def plan_fixed_order(expr: Expr, cost_fn, sel_fn, key_fn) -> PlanNode:
    """Order children by an arbitrary key (Random / Selectivity / Average-cost
    baselines of paper §5.3). key_fn(node) -> sort key (ascending)."""
    expr = _flatten(expr)

    def rec(e):
        if isinstance(e, Filter):
            return PlanNode("filter", filter=e, cost=float(cost_fn(e)),
                            prob=float(sel_fn(e)))
        kind = "and" if isinstance(e, And) else "or"
        planned = [rec(c) for c in e.children]
        planned.sort(key=key_fn)
        return _combine(kind, planned)

    return rec(expr)


def exhaustive_plan(expr: Expr, cost_fn, sel_fn) -> PlanNode:
    """Brute-force optimum over all orders within the tree structure
    (paper's `Exhaust` baseline; exponential — test/benchmark oracle)."""
    expr = _flatten(expr)

    def rec(e):
        if isinstance(e, Filter):
            return PlanNode("filter", filter=e, cost=float(cost_fn(e)),
                            prob=float(sel_fn(e)))
        kind = "and" if isinstance(e, And) else "or"
        planned = [rec(c) for c in e.children]
        best = None
        for perm in itertools.permutations(planned):
            cand = _combine(kind, list(perm))
            if best is None or cand.cost < best.cost - 1e-12:
                best = cand
        return best

    return rec(expr)
