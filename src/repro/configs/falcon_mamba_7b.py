"""falcon-mamba-7b [ssm]: pure Mamba1, attention-free.

64L, d_model=4096 (d_inner=8192), ssm_state=16, dt_rank=256, vocab=65024.
[arXiv:2410.05355; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=65024,
    mamba_version=1, ssm_state=16, ssm_conv=4, ssm_expand=2, dt_rank=256,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, vocab_size=256, ssm_state=8, dt_rank=8,
    dtype="float32",
)
