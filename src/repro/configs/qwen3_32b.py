"""qwen3-32b [dense]: GQA with qk-norm.

64L, d_model=5120, 64H (kv=8), d_ff=25600, vocab=151936.
[hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936,
    qk_norm=True, activation="silu", rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32",
)
