"""nemotron-4-15b [dense]: GQA, squared-ReLU MLP.

32L, d_model=6144, 48H (kv=8), d_ff=24576, vocab=256000.
[arXiv:2402.16819; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000,
    gated_mlp=False, activation="squared_relu",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32",
)
