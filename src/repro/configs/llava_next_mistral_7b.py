"""llava-next-mistral-7b [vlm]: Mistral-7B backbone; anyres vision frontend
stubbed to precomputed patch embeddings (B, 2880, 1024) per the assignment
(`input_specs()` provides them); an in-model 2-layer MM projector maps them
to d_model.

32L, d_model=4096, 32H (kv=8), d_ff=14336, vocab=32000.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    n_image_tokens=2880, rope_theta=1_000_000.0,
    activation="silu",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, n_image_tokens=8, dtype="float32",
)
