"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config``.

One module per assigned architecture; each exposes CONFIG (full, exercised
only via the dry-run) and SMOKE (reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "whisper-medium": "whisper_medium",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen3-32b": "qwen3_32b",
    "deepseek-67b": "deepseek_67b",
    "zamba2-2.7b": "zamba2_2_7b",
    "grok-1-314b": "grok_1_314b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}

ARCH_IDS = list(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE
