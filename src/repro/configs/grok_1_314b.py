"""grok-1-314b [moe]: 8 experts, top-2 routing.

64L, d_model=6144, 48H (kv=8), expert d_ff=32768, vocab=131072.
[hf:xai-org/grok-1; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, moe_top_k=2, expert_d_ff=32768,
    activation="gelu", gated_mlp=True,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=256, n_experts=4, expert_d_ff=64, dtype="float32",
)
