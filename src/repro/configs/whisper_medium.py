"""whisper-medium [audio]: enc-dec, conv frontend stubbed to frame embeddings.

24L enc + 24L dec, d_model=1024, 16H (kv=16), d_ff=4096, vocab=51865.
[arXiv:2212.04356; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, n_encoder_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=51865,
    use_rope=False, use_layernorm=True, gated_mlp=False, activation="gelu",
    encoder_seq=1500, max_position=32768,
)

SMOKE = CONFIG.replace(
    num_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, encoder_seq=16, max_position=256, dtype="float32",
)
