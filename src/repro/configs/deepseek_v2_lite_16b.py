"""deepseek-v2-lite-16b [moe]: MLA attention + fine-grained MoE.

27L, d_model=2048, 16H, vocab=102400. MLA: kv_lora=512, decoupled rope dim 64.
MoE: 2 shared + 64 routed experts, top-6, expert d_ff=1408; first layer dense
(d_ff=10944). Assignment line says both "64e top-6" and "160 routed"; 160
routed belongs to full V2 — V2-*Lite* has 64 routed (see DESIGN.md §3).
[arXiv:2405.04434; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=64, moe_top_k=6, n_shared_experts=2, expert_d_ff=1408,
    first_dense_layers=1,
    activation="silu",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    n_experts=8, moe_top_k=2, n_shared_experts=1, expert_d_ff=32,
    dtype="float32",
)
