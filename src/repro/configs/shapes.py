"""Assigned input shapes (per-arch applicability rules) — 40 cells total.

LM transformer shapes are seq_len x global_batch. decode_* / long_* lower
`serve_step` (one new token against a KV/state cache of seq_len), NOT
`train_step`. long_500k requires sub-quadratic attention: run for SSM /
hybrid archs, skip (and record the skip) for pure full-attention archs.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped). All 40 cells are reported; skips are
    explicit rows per DESIGN.md §3."""
    if shape_name == "long_500k" and not cfg.is_sub_quadratic:
        return False, ("pure full-attention arch: 524k-token decode KV cache is "
                       "not a sane deployment (skip per assignment; see DESIGN.md)")
    return True, ""
