"""zamba2-2.7b [hybrid]: Mamba2 trunk + shared attention blocks.

54 Mamba2 layers, d_model=2560, ssm_state=64; shared transformer block
(32H kv=32, d_ff=10240) applied every 6 layers, 2 alternating shared blocks
with per-application LoRA (rank 128). vocab=32000.
[arXiv:2411.15242; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    mamba_version=2, ssm_state=64, ssm_conv=4, ssm_expand=2, mamba_headdim=64,
    ssm_chunk=64,
    attn_every=6, n_shared_attn_blocks=2, shared_lora_rank=128,
    activation="gelu", gated_mlp=True,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    ssm_state=16, mamba_headdim=16, ssm_chunk=8,
    attn_every=2, shared_lora_rank=8, dtype="float32",
)
