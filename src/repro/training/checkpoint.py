"""Sharded checkpointing: npz payload + JSON manifest, atomic rename, async
writer thread, and *resharding restore* (elastic scaling: a checkpoint taken
on mesh A restores onto mesh B — shardings are recomputed, not stored).
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def save_checkpoint(ckpt_dir, step: int, tree, extra: dict | None = None):
    """Synchronous save. Layout: <dir>/step_<n>/{payload.npz, manifest.json};
    atomic via tmp-dir rename; keeps every step directory."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{os.getpid()}"
    tmp.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(tmp / "payload.npz", **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "extra": extra or {},
        "keys": sorted(arrays),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        import shutil
        shutil.rmtree(final)
    tmp.rename(final)
    return final


class AsyncCheckpointer:
    """Fire-and-forget saver: snapshot to host, write on a worker thread so
    the train loop never blocks on disk."""

    def __init__(self, ckpt_dir):
        self.ckpt_dir = Path(ckpt_dir)
        self._thread = None

    def save(self, step: int, tree, extra=None):
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot before mutation
        self.wait()
        self._thread = threading.Thread(
            target=save_checkpoint, args=(self.ckpt_dir, step, host_tree, extra),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*"))
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`. With `shardings` (a pytree
    of NamedSharding built for the *current* mesh) arrays are placed sharded
    — this is the elastic-rescale path."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    payload = np.load(path / "payload.npz")
    flat, treedef = _flatten(like_tree)
    leaves = []
    for key in flat:
        arr = payload[key]
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest["extra"]
