"""Fault-tolerant training driver: step loop + periodic async checkpoints +
bit-exact resume (params, optimizer state, RNG and data cursor are all part
of the checkpoint). A `failure_at` hook simulates a node crash mid-run for
the restart tests; `resume()` continues from the latest checkpoint.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_params
from repro.models.config import ModelConfig
from repro.training.checkpoint import (AsyncCheckpointer, latest_step,
                                       restore_checkpoint)
from repro.training.optim import OptConfig
from repro.training.train_step import make_train_step


class CrashInjected(RuntimeError):
    pass


@dataclass
class TrainerConfig:
    total_steps: int = 50
    ckpt_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: OptConfig, data,
                 tcfg: TrainerConfig, *, constrain=None, grad_transform=None,
                 jit_kwargs=None, shardings=None):
        self.cfg, self.opt_cfg, self.data, self.tcfg = cfg, opt_cfg, data, tcfg
        init_fn, step_fn = make_train_step(cfg, opt_cfg, remat=False,
                                           constrain=constrain,
                                           grad_transform=grad_transform)
        self._init_opt = init_fn
        self.train_step = jax.jit(step_fn, **(jit_kwargs or {}))
        self.shardings = shardings
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir)
        self.params = None
        self.opt_state = None
        self.step = 0
        self.history = []

    # ------------------------------------------------------------ state ---

    def init(self):
        self.params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        self.opt_state = self._init_opt(self.params)
        self.step = 0

    def state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def resume(self) -> bool:
        last = latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        like = {"params": self.params, "opt": self.opt_state}
        tree, extra = restore_checkpoint(self.tcfg.ckpt_dir, last, like,
                                         shardings=self.shardings)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = extra["step"]
        self.data.restore(extra["data"])
        return True

    # -------------------------------------------------------------- run ---

    def run(self, *, failure_at: int | None = None):
        assert self.params is not None, "call init() or resume() first"
        while self.step < self.tcfg.total_steps:
            if failure_at is not None and self.step == failure_at:
                raise CrashInjected(f"injected failure at step {self.step}")
            batch = self.data.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            self.step += 1
            loss = float(metrics["loss"])
            self.history.append(loss)
            if self.step % self.tcfg.log_every == 0:
                print(f"step {self.step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
            if self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step, self.state_tree(),
                               extra={"step": self.step,
                                      "data": self.data.snapshot()})
        self.ckpt.wait()
        return self.history
