"""Optimizers (pure JAX, pytree states): AdamW, Adafactor, 8-bit Adam.

8-bit Adam (blockwise-quantized moments) and Adafactor (factored second
moment) are the memory levers that keep grok-1-314b / deepseek-67b training
states inside a v5e's 16 GB HBM at 256-chip scale (see EXPERIMENTS.md
§Dry-run memory table). Optimizer states inherit each parameter's sharding.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor | adam8bit
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # 128 divides every sharded trailing-dim tile on the (16,16) mesh —
    # misaligned quant blocks force SPMD gathers of the int8 state (§Perf)
    q_block: int = 128             # adam8bit quantization block


def lr_schedule(cfg: OptConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ------------------------------------------------------------ quant utils --


def _q8(x, block):
    """Blockwise int8 quantization along the LAST axis (layout-preserving:
    the int8 tensor keeps the parameter's shape, so it inherits the
    parameter's sharding with zero SPMD resharding)."""
    shape = x.shape
    nb = shape[-1] // block
    xf = x.reshape(shape[:-1] + (nb, block))
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    q = jnp.round(xf / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q.reshape(shape), scale[..., 0].astype(jnp.float32)


def _dq8(q, scale, block):
    shape = q.shape
    qf = q.reshape(shape[:-1] + (-1, block)).astype(jnp.float32)
    return (qf * scale[..., None]).reshape(shape)


# -------------------------------------------------------------- adamw ------


def adamw_init(params):
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step)
        vhat = v / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}


# ------------------------------------------------------------ adafactor ----


def adafactor_init(params):
    def z(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(z, params,
                              is_leaf=lambda x: isinstance(x, jnp.ndarray)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(f, g, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + 1e-30
        if p.ndim >= 2:
            vr = decay * f["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * f["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = jnp.sqrt(vr[..., None] * vc[..., None, :]
                             / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], 1e-30))
            nf = {"vr": vr, "vc": vc}
        else:
            v = decay * f["v"] + (1 - decay) * g2
            denom = jnp.sqrt(v)
            nf = {"v": v}
        delta = g / jnp.maximum(denom, 1e-12)
        # relative step-size clipping (Adafactor's update clipping)
        rms = jnp.sqrt(jnp.mean(jnp.square(delta)))
        delta = delta / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), nf

    is_f = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    out = jax.tree.map(upd, state["f"], grads, params, is_leaf=is_f)
    # out mirrors params' structure with (new_p, new_f) tuples at leaves
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_f = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"f": new_f, "step": step}


# ------------------------------------------------------------- adam8bit ----


def adam8bit_init(params, q_block=256):
    def z(p):
        if p.ndim == 0 or p.shape[-1] % q_block or p.size < 4 * q_block:
            # small / ragged tensors keep fp32 moments (negligible memory)
            return {"m": jnp.zeros(p.shape, jnp.float32),
                    "v": jnp.zeros(p.shape, jnp.float32)}
        nb = p.shape[-1] // q_block
        return {"mq": jnp.zeros(p.shape, jnp.int8),
                "ms": jnp.zeros(p.shape[:-1] + (nb,), jnp.float32),
                "vq": jnp.zeros(p.shape, jnp.int8),
                "vs": jnp.zeros(p.shape[:-1] + (nb,), jnp.float32)}
    return {"q": jax.tree.map(z, params), "step": jnp.zeros((), jnp.int32)}


def adam8bit_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(q, g, p):
        g = g.astype(jnp.float32)
        if "mq" in q:
            m = _dq8(q["mq"], q["ms"], cfg.q_block)
            v = _dq8(q["vq"], q["vs"], cfg.q_block)
        else:
            m, v = q["m"], q["v"]
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step)
        vhat = v / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(jnp.maximum(vhat, 0.0)) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if "mq" in q:
            mq, ms = _q8(m, cfg.q_block)
            vq, vs = _q8(v, cfg.q_block)
            return new_p, {"mq": mq, "ms": ms, "vq": vq, "vs": vs}
        return new_p, {"m": m, "v": v}

    # NOTE(§Perf): scanning this update over the layer-stack dim was tried
    # to cap fp32 dequant transients; on the CPU-XLA dry-run backend the
    # while-loop operand copies *added* ~7 GiB instead (refuted there;
    # revisit on real TPU where loop operands alias).
    out = jax.tree.map(upd, state["q"], grads, params,
                       is_leaf=lambda x: isinstance(x, dict) and ("mq" in x or "m" in x))
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_q = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"q": new_q, "step": step}


# ------------------------------------------------------------- registry ----


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return adamw_init, partial(adamw_update, cfg)
    if cfg.name == "adafactor":
        return adafactor_init, partial(adafactor_update, cfg)
    if cfg.name == "adam8bit":
        return partial(adam8bit_init, q_block=cfg.q_block), partial(adam8bit_update, cfg)
    raise ValueError(cfg.name)
