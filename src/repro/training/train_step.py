"""Training step factory: CE loss (vocab-sharded-safe), grad clip, optional
microbatch gradient accumulation and a grad_transform hook (used by the
pod-axis int8 gradient compression in repro/distributed/compression.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig
from .optim import OptConfig, clip_by_global_norm, make_optimizer


def cross_entropy(logits, labels, mask=None):
    """logits: (B, S, V) any float dtype; labels: (B, S) int32.

    Computed in fp32 with logsumexp over the (possibly model-sharded) vocab
    axis — GSPMD turns the reductions into partial sums + all-reduce without
    materializing an unsharded logits tensor.
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def make_loss_fn(cfg: ModelConfig, *, remat=True, constrain=None,
                 aux_coef=None, unroll=False):
    aux_coef = cfg.router_aux_coef if aux_coef is None else aux_coef

    def loss_fn(params, batch):
        logits, aux = forward(cfg, params, batch, remat=remat,
                              constrain=constrain, unroll=unroll)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        loss = cross_entropy(logits, labels, mask)
        return loss + aux_coef * aux, {"ce": loss, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, *, remat=True,
                    constrain=None, grad_transform=None, microbatch: int = 0,
                    unroll=False):
    """Returns (init_opt_state, train_step).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics).
    microbatch > 0 splits the batch along axis 0 and accumulates grads with
    lax.scan (activation memory ∝ microbatch, not global batch).
    """
    loss_fn = make_loss_fn(cfg, remat=remat, constrain=constrain, unroll=unroll)
    init_fn, update_fn = make_optimizer(opt_cfg)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if not microbatch:
            (loss, aux), grads = vg(params, batch)
            return loss, aux, grads
        B = batch["tokens"].shape[0]
        n = B // microbatch
        resh = lambda x: x.reshape((n, microbatch) + x.shape[1:])
        mb = jax.tree.map(resh, batch)

        def body(carry, mbatch):
            acc, loss_acc = carry
            (loss, _), grads = vg(params, mbatch)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), mb)
        grads = jax.tree.map(lambda g: g / n, grads)
        return loss_sum / n, {"ce": loss_sum / n, "aux": jnp.float32(0)}, grads

    def train_step(params, opt_state, batch):
        loss, aux, grads = compute_grads(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = update_fn(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm, **aux}
        return params, opt_state, metrics

    return init_fn, train_step
