"""Analytic FLOP/byte models per (arch x shape) — the MODEL_FLOPS side of the
roofline ratio (useful compute), plus detailed per-component estimates used
to correct cost_analysis where XLA while-loops hide trip counts (SSM time
scans). Conventions: 1 MAC = 2 FLOPs; train = 3x forward (fwd + 2x bwd).
"""
from __future__ import annotations

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """The 6*N*D / 2*N*D "useful flops" number (dense: all params; MoE:
    active params only). D = processed tokens."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token per seq


def attention_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Exact attention matmul FLOPs (causal counted as full S^2 for the XLA
    path — the Pallas kernel halves this; see EXPERIMENTS.md)."""
    hd = cfg.resolved_head_dim
    H = cfg.n_heads
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_every
    elif cfg.family == "encdec":
        n_attn = cfg.n_encoder_layers + cfg.num_layers
    else:
        n_attn = cfg.num_layers
    if shape.kind == "decode":
        per = 2 * 2 * H * hd * S                  # qk + pv against cache
        f = n_attn * B * per
    else:
        per = 2 * 2 * H * hd * S * S
        f = n_attn * B * per
        if shape.kind == "train":
            f *= 3
    return f


def ssm_scan_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Recurrence-interior FLOPs hidden inside XLA while loops."""
    if cfg.mamba_version == 0:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    tokens = B * (1 if shape.kind == "decode" else S)
    if cfg.mamba_version == 1:
        per_tok = 9.0 * cfg.d_inner * cfg.ssm_state
    else:
        # SSD chunked matrix form per token (intra approx + states + inter)
        c = cfg.ssm_chunk
        h, p, N = cfg.n_ssm_heads, cfg.mamba_headdim, cfg.ssm_state
        per_tok = 2 * h * c * (p + N) + 6 * h * p * N
    f = cfg.num_layers * tokens * per_tok
    if shape.kind == "train":
        f *= 3
    return f


def hbm_bytes(cfg: ModelConfig, shape: ShapeSpec, dtype_bytes: int = 2) -> float:
    """First-order HBM traffic: weights once per step/token-batch + KV cache
    reads for decode. (Roofline memory term; activations assumed cache/
    fusion-resident at this granularity.)"""
    n = cfg.param_count()
    w = n * dtype_bytes
    if shape.kind == "decode":
        B, S = shape.global_batch, shape.seq_len
        hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
        if cfg.family == "hybrid":
            n_attn = cfg.num_layers // cfg.attn_every
        elif cfg.family == "ssm":
            n_attn = 0
        elif cfg.family == "encdec":
            n_attn = cfg.num_layers
        else:
            n_attn = cfg.num_layers
        if cfg.use_mla:
            kv = n_attn * B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * dtype_bytes
        else:
            kv = n_attn * B * S * 2 * nkv * hd * dtype_bytes
        ssm = 0.0
        if cfg.mamba_version:
            ssm = cfg.num_layers * B * cfg.d_inner * cfg.ssm_state * 4
        return w + kv + ssm
    tokens = shape.global_batch * shape.seq_len
    acts = tokens * cfg.d_model * dtype_bytes * cfg.num_layers * 2
    mult = 3 if shape.kind == "train" else 1
    return mult * (w + acts)
