"""Post-compile HLO inspection: collective byte accounting.

Parses optimized (post-SPMD) HLO text — shapes there are *per device* — and
sums operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. Collectives inside `while` bodies appear
once in the text regardless of trip count, so totals from a scanned model
understate per-step traffic; the roofline pipeline therefore extrapolates
from unrolled reduced-depth probes (launch/roofline.py).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_DEF_RE = re.compile(r"%?([\w.\-_]+)\s*=\s*(\(?)([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_INSTR_RE = re.compile(
    r"%?([\w.\-_]+)\s*=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done)?\(([^)]*)\)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_kind: operand_bytes_summed} (per-device bytes).

    Byte convention: sum of *result* tuple shapes for -start ops is skipped
    (we count each collective once via its non-start form or start form
    only), and operand bytes are taken from the shapes embedded in the
    instruction's own result/operand type strings.
    """
    totals: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    seen_started = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        name, result_types, kind, operands = m.groups()
        is_start = f"{kind}-start(" in line
        is_done = f"{kind}-done(" in line
        if is_done:
            continue
        if is_start:
            seen_started.add(name)
        # operand shapes: prefer explicit types in the operand list; fall
        # back to the result type (same size for all-reduce / permute).
        op_shapes = _SHAPE_RE.findall(operands)
        if op_shapes:
            b = sum(_shape_bytes(dt, dims) for dt, dims in op_shapes)
        else:
            res_shapes = _SHAPE_RE.findall(result_types)
            b = sum(_shape_bytes(dt, dims) for dt, dims in res_shapes)
        totals[kind] += b
        counts[kind] += 1
    totals = dict(totals)
    totals["_counts"] = dict(counts)
    totals["_total"] = sum(v for k, v in totals.items() if not k.startswith("_"))
    return totals


_META_RE = re.compile(r'op_name="([^"]*)"')


def collective_bytes_by_site(hlo_text: str, top: int = 15) -> list:
    """Attribution: (bytes, kind, dtype, op_name) for the largest collective
    sites — the hillclimb diagnosis view."""
    out = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        name, res, kind, operands = m.groups()
        if f"{kind}-done(" in line:
            continue
        shapes = _SHAPE_RE.findall(operands) or _SHAPE_RE.findall(res)
        b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        dt = shapes[0][0] if shapes else "?"
        mm = _META_RE.search(line)
        site = mm.group(1) if mm else "?"
        out.append((b, kind, dt, site[:120]))
    out.sort(reverse=True)
    return out[:top]


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    keep = {}
    for k, v in dict(ca).items():
        if k in ("flops", "bytes accessed", "transcendentals",
                 "bytes accessed0{}", "bytes accessedout{}", "optimal_seconds"):
            keep[k] = float(v)
    keep.setdefault("flops", float(dict(ca).get("flops", 0.0)))
    return keep
