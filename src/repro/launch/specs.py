"""Abstract input/parameter/cache specs for lowering (no allocation).

`input_specs(arch, shape, mesh)` returns ShapeDtypeStructs (with shardings
attached) for every model input of the given (architecture x input-shape)
cell — weak-type-correct, shardable, zero bytes allocated.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models import init_decode_cache, init_params
from repro.models.config import ModelConfig
from repro.models.model import VISION_DIM
from repro.distributed import sharding as sh
from repro.training.optim import OptConfig, make_optimizer
from repro.training.train_step import make_train_step


def sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec or P()))


def abstract_params(cfg: ModelConfig, mesh=None, dtype=None, overrides=None):
    shapes = jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))
    dt = dtype or cfg.dtype
    if mesh is None:
        return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dt), shapes)
    specs = sh.param_specs(cfg, shapes, mesh, overrides)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, dt,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs)


def abstract_opt_state(cfg: ModelConfig, params_abs, opt_cfg: OptConfig, mesh=None):
    init_fn, _ = make_optimizer(opt_cfg)
    shapes = jax.eval_shape(init_fn, params_abs)
    if mesh is None:
        return shapes
    pspecs = sh.param_specs(cfg, params_abs, mesh)
    ospecs = sh.opt_state_specs(cfg, shapes, pspecs, mesh)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, ospecs)


def abstract_cache(cfg: ModelConfig, B: int, max_len: int, mesh=None):
    shapes = jax.eval_shape(partial(init_decode_cache, cfg, B, max_len))
    if mesh is None:
        return shapes
    specs = sh.cache_specs(cfg, shapes, mesh, B)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs)


def _batch_structs(cfg: ModelConfig, shape: ShapeSpec, mesh, *, train: bool):
    B, S = shape.global_batch, shape.seq_len
    bspec = sh.batch_spec(mesh, B) if mesh else ()
    b = bspec if bspec else None
    mk = lambda shp, dt, sp: sds(shp, dt, mesh, sp)
    batch = {}
    if cfg.family == "vlm":
        n_img = cfg.n_image_tokens
        batch["tokens"] = mk((B, S - n_img), jnp.int32, P(b, None))
        batch["image_embeds"] = mk((B, n_img, VISION_DIM), jnp.dtype(cfg.dtype),
                                   P(b, None, None))
    else:
        batch["tokens"] = mk((B, S), jnp.int32, P(b, None))
    if cfg.family == "encdec":
        batch["frames"] = mk((B, cfg.encoder_seq, cfg.d_model),
                             jnp.dtype(cfg.dtype), P(b, None, None))
    if train:
        batch["labels"] = mk(batch["tokens"].shape, jnp.int32, P(b, None))
    return batch


def input_specs(arch: str, shape_name: str, mesh=None, *, opt_cfg=None,
                cfg: ModelConfig | None = None, shard_overrides=None,
                decode_layout: str = "default"):
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    Returns a dict:
      train  : {params, opt_state, batch}
      prefill: {params, batch}
      decode : {params, token, cache}
    """
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    B = shape.global_batch
    if shape.kind == "train":
        params = abstract_params(cfg, mesh, dtype=jnp.float32,
                                 overrides=shard_overrides)
        opt_cfg = opt_cfg or default_opt_cfg(cfg)
        opt = abstract_opt_state(cfg, params, opt_cfg, mesh)
        batch = _batch_structs(cfg, shape, mesh, train=True)
        return {"params": params, "opt_state": opt, "batch": batch,
                "opt_cfg": opt_cfg}
    params = abstract_params(cfg, mesh, overrides=shard_overrides)
    if shape.kind == "prefill":
        return {"params": params,
                "batch": _batch_structs(cfg, shape, mesh, train=False)}
    # decode: one new token against a seq_len cache
    if decode_layout == "ws2d":
        # 2D weight-stationary serving: batch replicated, cache sequence
        # sharded over (data, model) — weights never move, activations do.
        token = sds((B, 1), jnp.int32, mesh, P())
        cache = abstract_cache_ws2d(cfg, B, shape.seq_len, mesh)
        pos = sds((), jnp.int32, mesh, P())
        cache = dict(cache)
        cache["pos"] = pos
        return {"params": params, "token": token, "cache": cache}
    bspec = sh.batch_spec(mesh, B) if mesh else ()
    b = bspec if bspec else None
    token = sds((B, 1), jnp.int32, mesh, P(b, None))
    cache = abstract_cache(cfg, B, shape.seq_len, mesh)
    # decode starts at a full cache position
    pos = jnp.asarray(shape.seq_len - 1, jnp.int32) if mesh is None else \
        sds((), jnp.int32, mesh, P())
    cache = dict(cache)
    cache["pos"] = pos
    return {"params": params, "token": token, "cache": cache}


def default_opt_cfg(cfg: ModelConfig) -> OptConfig:
    """Memory-appropriate optimizer per model size (DESIGN.md §4)."""
    n = cfg.param_count()
    if n > 100e9:
        return OptConfig(name="adam8bit")
    if n > 25e9:
        return OptConfig(name="adafactor")
    return OptConfig(name="adamw")


def default_microbatch(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    """Gradient-accumulation microbatch: keep per-device activation tokens
    bounded (~2k tokens/device/microstep with remat)."""
    if shape.kind != "train":
        return 0
    n_batch_devices = 1
    for a in sh.batch_spec(mesh, shape.global_batch):
        n_batch_devices *= mesh.shape[a]
    per_dev = shape.global_batch // max(n_batch_devices, 1)
    # microbatch must stay divisible by the batch-sharded device count
    mb = shape.global_batch
    while mb > n_batch_devices and (mb // 2) % n_batch_devices == 0 and \
            (mb // 2) * shape.seq_len // n_batch_devices >= 2048:
        mb //= 2
    return mb if mb < shape.global_batch else 0


def abstract_cache_ws2d(cfg: ModelConfig, B: int, max_len: int, mesh):
    """ws2d decode cache: sequence over (data, model), batch replicated."""
    shapes = jax.eval_shape(partial(init_decode_cache, cfg, B, max_len))
    total = 1
    for a in ("data", "model"):
        if a in mesh.axis_names:
            total *= mesh.shape[a]

    def spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        shp = leaf.shape
        seq = ("data", "model")
        if name in ("k", "v"):          # (L, B, S, Hkv, hd)
            s = seq if shp[2] % total == 0 else None
            return P(None, None, s, None, None)
        if name in ("ck", "cv"):
            return P(None, None, None, None, None)
        if name in ("ckv", "krope"):    # (L, B, S, r)
            s = seq if shp[2] % total == 0 else None
            return P(None, None, s, None)
        if name == "ssm":
            s = "model" if shp[2] % mesh.shape["model"] == 0 else None
            return P(None, None, s) + P(*([None] * (len(shp) - 3)))
        if name == "conv":
            s = "model" if shp[3] % mesh.shape["model"] == 0 else None
            return P(None, None, None, s)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = jax.tree_util.tree_unflatten(treedef, [spec(p, l) for p, l in flat])
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs)
