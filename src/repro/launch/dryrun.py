import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count at first init). Hence no `from __future__ import annotations`.

DOC = """Multi-pod dry-run driver (deliverable (e)).

For every (architecture x input shape) cell, builds the abstract inputs
(`input_specs`, ShapeDtypeStruct only — no allocation), lowers and compiles
the corresponding step function (train_step / prefill / serve_step) on the
production mesh, and records memory_analysis / cost_analysis / per-device
collective bytes into an incremental JSON file consumed by EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --all
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --arch qwen3-32b --shape train_4k
  ... --probe 2  (reduced-depth unrolled probe for roofline extrapolation)
"""

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, SHAPE_ORDER, applicable
from repro.distributed import sharding as sh
from repro.launch import flops as F
from repro.launch.hlo_analysis import (collective_bytes, cost_analysis_dict,
                                       memory_analysis_dict)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (default_microbatch, default_opt_cfg,
                                input_specs)
from repro.models import decode_step, prefill
from repro.models import layers as mlayers
from repro.models.config import ModelConfig
from repro.training.train_step import make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results.json"


def probe_config(cfg: ModelConfig, n: int) -> ModelConfig:
    """Reduced-depth config for unrolled cost probes (same widths)."""
    if cfg.family == "hybrid":
        return cfg.replace(num_layers=n * cfg.attn_every)
    if cfg.family == "encdec":
        return cfg.replace(num_layers=n, n_encoder_layers=n)
    if cfg.family == "moe" and cfg.first_dense_layers:
        return cfg.replace(num_layers=cfg.first_dense_layers + n)
    return cfg.replace(num_layers=n)


from repro.distributed.sharding import _ROLES as _BASE_ROLES

VARIANT_OVERRIDES = {
    # expert parallelism: experts over `data`, ff over `model` — dispatch
    # moves tokens (all-to-all), weights stay put
    "ep_moe": {"w_gate": "f.t", "w_up": "f.t", "w_down": "ft."},
    # grouped-local dispatch: groups = data shards; expert weights replicated
    # over data (ff over model) so per-group expert compute is fully local
    "ep_grouped": {"w_gate": "..t", "w_up": "..t", "w_down": ".t."},
    # weight-stationary serving: drop FSDP (replicate over `data`), keep TP —
    # decode must move activations (tiny), not weights (huge)
    "serve_ws": {n: r.replace("f", ".") for n, r in _BASE_ROLES.items()},
    "serve_ws_seqdec": {n: r.replace("f", ".") for n, r in _BASE_ROLES.items()},
}


def build_lowered(arch: str, shape_name: str, mesh, *, probe: int = 0,
                  unroll: bool = False, dense_attn: bool = False,
                  variant: str = "baseline"):
    cfg = get_config(arch)
    if probe:
        cfg = probe_config(cfg, probe)
    shape = SHAPES[shape_name]
    mlayers.set_attention_impl("dense" if dense_attn else None)
    attn_impl = None
    if variant == "ep_grouped":
        mlayers.set_moe_groups(mesh.shape["data"])
    if variant in ("seq_decode", "serve_ws_seqdec"):
        from repro.distributed.decode import make_seq_sharded_decode_attn
        attn_impl = make_seq_sharded_decode_attn(mesh)
    elif variant == "serve_ws2d_seqdec":
        from repro.distributed.decode import make_seq_sharded_decode_attn
        attn_impl = make_seq_sharded_decode_attn(mesh, axis=("data", "model"),
                                                 batch_axis=None)
    try:
        specs = input_specs(arch, shape_name, mesh, cfg=cfg,
                            shard_overrides=VARIANT_OVERRIDES.get(variant),
                            decode_layout="ws2d" if variant.startswith("serve_ws2d") else "default")
        constrain = (sh.make_constrain(mesh, shape.global_batch)
                     if not variant.startswith("serve_ws2d") else None)
        if shape.kind == "train":
            opt_cfg = specs["opt_cfg"]
            mb = 0 if probe else default_microbatch(cfg, shape, mesh)
            _, train_step = make_train_step(cfg, opt_cfg, remat=True,
                                            constrain=constrain, microbatch=mb,
                                            unroll=unroll)

            def fn(params, opt_state, batch):
                return train_step(params, opt_state, batch)

            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(
                specs["params"], specs["opt_state"], specs["batch"])
            meta = {"opt": opt_cfg.name, "microbatch": mb}
        elif shape.kind == "prefill":
            def fn(params, batch):
                return prefill(cfg, params, batch, shape.seq_len,
                               constrain=constrain, remat=False, unroll=unroll)

            lowered = jax.jit(fn).lower(specs["params"], specs["batch"])
            meta = {}
        else:
            def fn(params, token, cache):
                return decode_step(cfg, params, token, cache,
                                   constrain=constrain, unroll=unroll,
                                   attn_impl=attn_impl)

            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                specs["params"], specs["token"], specs["cache"])
            meta = {}
        return cfg, shape, lowered, meta
    finally:
        mlayers.set_attention_impl(None)
        mlayers.set_moe_groups(0)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, probe: int = 0,
             unroll: bool = False, dense_attn: bool = False,
             variant: str = "baseline") -> dict:
    cfg_full = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg_full, shape_name)
    key = f"{arch}|{shape_name}|{mesh_kind}" + (f"|probe{probe}" if probe else "")
    if variant != "baseline":
        key += f"|{variant}"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "probe": probe,
           "variant": variant}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return key, rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        cfg, shape, lowered, meta = build_lowered(
            arch, shape_name, mesh, probe=probe,
            unroll=unroll or bool(probe), dense_attn=dense_attn or bool(probe),
            variant=variant)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "n_devices": mesh.devices.size,
            "memory": memory_analysis_dict(compiled),
            "cost": cost_analysis_dict(compiled),
            "collectives": collective_bytes(compiled.as_text()),
            **meta,
        })
        if not probe:
            rec["model_flops"] = F.model_flops(cfg, shape)
            rec["attention_flops"] = F.attention_flops(cfg, shape)
            rec["ssm_scan_flops"] = F.ssm_scan_flops(cfg, shape)
            rec["param_count"] = cfg.param_count()
            rec["param_count_active"] = cfg.param_count(active_only=True)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return key, rec


def load_results() -> dict:
    if RESULTS.exists():
        return json.loads(RESULTS.read_text())
    return {}


def save_result(key: str, rec: dict):
    res = load_results()
    res[key] = rec
    RESULTS.write_text(json.dumps(res, indent=1, default=float))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=SHAPE_ORDER + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--probe", type=int, default=0,
                    help="reduced depth (unrolled, dense-attn) cost probe")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "seq_decode", "ep_moe", "serve_ws",
                             "serve_ws_seqdec", "serve_ws2d", "serve_ws2d_seqdec",
                             "ep_grouped"])
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else SHAPE_ORDER
    existing = load_results()
    for arch in archs:
        for shape_name in shapes:
            key = f"{arch}|{shape_name}|{args.mesh}" + (
                f"|probe{args.probe}" if args.probe else "")
            if args.variant != "baseline":
                key += f"|{args.variant}"
            if not args.force and key in existing and \
                    existing[key].get("status") in ("ok", "skipped"):
                print(f"[skip-cached] {key}")
                continue
            print(f"[run] {key}", flush=True)
            k, rec = run_cell(arch, shape_name, args.mesh, probe=args.probe,
                              variant=args.variant)
            save_result(k, rec)
            st = rec["status"]
            extra = ""
            if st == "ok":
                mem = rec["memory"].get("temp_size_in_bytes", 0)
                extra = (f" compile={rec['compile_s']}s "
                         f"temp={mem/2**30:.2f}GiB "
                         f"flops={rec['cost'].get('flops', 0):.3e} "
                         f"coll={rec['collectives'].get('_total', 0)/2**20:.1f}MiB")
            elif st == "error":
                extra = " " + rec["error"][:200]
            print(f"[done] {key}: {st}{extra}", flush=True)


if __name__ == "__main__":
    main()
