"""Persistent JAX compilation cache (ROADMAP open item 5, first cut).

Repeated bench/serving runs over the smoke models re-pay jit compilation
on every process start — for the tiny configs the compile wall dominates
the compute wall. JAX ships a persistent on-disk compilation cache that
keys compiled executables by (HLO, jaxlib version, backend); enabling it
makes the second run of the same bench skip re-jit entirely.

`enable_compilation_cache(dir)` turns it on for the current process,
dropping the default entry-size/compile-time floors so even the smoke
configs' sub-second compiles are cached (the floors exist to keep
production caches small; a bench cache wants everything). Exposed as the
`compilation_cache_dir=` knob on `ServingEngine` and as
`--compilation-cache` on the bench/example drivers.

Safe to call more than once (idempotent per directory) and a no-op on jax
builds without the config knobs — callers never have to guard it.
"""
from __future__ import annotations

import os
from typing import Optional

_enabled_dir: Optional[str] = None


def enable_compilation_cache(cache_dir: str, *,
                             min_entry_size_bytes: int = 0,
                             min_compile_time_secs: float = 0.0) -> bool:
    """Point jax's persistent compilation cache at `cache_dir` (created if
    missing). Returns True when the cache is active, False when this jax
    build lacks the knobs. Subsequent calls with the same directory are
    no-ops; a different directory re-points the cache."""
    global _enabled_dir
    import jax

    cache_dir = os.path.abspath(os.path.expanduser(str(cache_dir)))
    if _enabled_dir == cache_dir:
        return True
    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_enable_compilation_cache", True)
    except (AttributeError, ValueError, OSError):
        return False
    # floors default to 'worth persisting in production'; benches want the
    # tiny smoke-model compiles cached too, so drop them to the caller's
    for knob, val in (("jax_persistent_cache_min_entry_size_bytes",
                       int(min_entry_size_bytes)),
                      ("jax_persistent_cache_min_compile_time_secs",
                       float(min_compile_time_secs))):
        try:
            jax.config.update(knob, val)
        except (AttributeError, ValueError):
            pass  # older jax: the cache still works with default floors
    _enabled_dir = cache_dir
    return True


def compilation_cache_dir() -> Optional[str]:
    """The directory the persistent cache was enabled with (None = off)."""
    return _enabled_dir
