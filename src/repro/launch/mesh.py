"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — `pod` carries
data parallelism across the slower inter-pod links (one gradient all-reduce
per step, optionally int8-compressed), `model` stays intra-pod on ICI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for CPU tests (requires XLA_FLAGS host device override)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def parse_mesh_shape(spec) -> tuple:
    """"2x2" / "1,4" / (2, 2) -> (n_data, n_model)."""
    if isinstance(spec, (tuple, list)):
        shape = tuple(int(x) for x in spec)
    else:
        shape = tuple(int(x) for x in str(spec).replace(",", "x").split("x"))
    if len(shape) != 2 or min(shape) < 1:
        raise ValueError(f"mesh shape must be (n_data, n_model), got {spec!r}")
    return shape


def make_serving_mesh(shape=(1, 2)):
    """Serving mesh with axes (data, model) — `data` carries engine-replica /
    slot batch parallelism, `model` tensor parallelism (DESIGN.md §15).
    Works on CPU meshes for CI; fails with the XLA_FLAGS recipe when the
    process has fewer devices than the shape needs (the flag must be set
    before jax initializes, so it cannot be applied retroactively here)."""
    n_data, n_model = parse_mesh_shape(shape)
    need = n_data * n_model
    have = len(jax.devices())
    if have < need:
        raise RuntimeError(
            f"mesh shape {(n_data, n_model)} needs {need} devices, found "
            f"{have}; on CPU launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} (must be set "
            f"before jax initializes)")
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
