"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — `pod` carries
data parallelism across the slower inter-pod links (one gradient all-reduce
per step, optionally int8-compressed), `model` stays intra-pod on ICI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for CPU tests (requires XLA_FLAGS host device override)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
