"""Synthetic document corpora with exact ground truth (DESIGN.md §8.4).

Three corpora styled after the paper's datasets (Table 1):
  - wiki : 200 docs, multi-domain (players/teams/cities/owners + movie and
           company distractor domains), ~1.2k tokens/doc, joinable tables.
  - legal: 100 long single-domain case reports, ~6k tokens/doc (LCR-style).
  - swde : 200 short attribute-dense pages (universities + laptops).

Each attribute has paired sentence *templates* (rendering) and a *pattern*
(extraction oracle); values are planted in exactly one sentence per document
and recorded as spans, so retrieval quality — not parsing luck — drives
accuracy, mirroring the paper's controlled variable.

Tables map a queried logical table to the *whole collection*: the
document-level index (not table metadata) must discover which documents are
relevant — this is precisely the paper's two-level-index setting.
"""
from __future__ import annotations

import random
import re
from dataclasses import dataclass, field
from typing import Any, Callable

from .tokens import count_tokens


@dataclass
class AttrSpec:
    name: str
    kind: str                    # 'int' | 'float' | 'str'
    desc: str
    templates: list[str]         # each with one {} slot for the value
    pattern: str                 # regex with one capture group
    sampler: Callable[[random.Random], Any] = None

    def parse(self, text: str):
        m = re.search(self.pattern, text)
        if not m:
            return None
        raw = m.group(1)
        if self.kind == "int":
            return int(raw)
        if self.kind == "float":
            return float(raw)
        return raw.strip()


@dataclass
class Document:
    doc_id: str
    domain: str
    text: str
    truth: dict = field(default_factory=dict)   # attr -> value
    spans: dict = field(default_factory=dict)   # attr -> sentence containing it
    tokens: int = 0
    # live-corpus manifest identity (repro.live, DESIGN.md §17): version
    # bumps per mutation, sha is the blake2b-128 content hash of `text`.
    # Static corpora keep version 0 / sha "" until wrapped in a LiveCorpus.
    version: int = 0
    sha: str = ""
    # retriever protocol expects .table = owning domain
    @property
    def table(self):
        return self.domain


@dataclass
class Corpus:
    name: str
    docs: dict                    # doc_id -> Document
    tables: dict                  # logical table -> [doc_ids] (candidate pool)
    attr_specs: dict              # table -> {attr: AttrSpec}
    domain_of_table: dict         # logical table -> truth domain

    def attr_description(self, table: str, attr: str) -> str:
        spec = self.attr_specs.get(table, {}).get(attr)
        return spec.desc if spec else attr

    def spec(self, domain: str, attr: str) -> AttrSpec | None:
        for t, d in self.domain_of_table.items():
            if d == domain and attr in self.attr_specs.get(t, {}):
                return self.attr_specs[t][attr]
        return None

    def truth_rows(self, table: str) -> dict:
        """doc_id -> truth dict for docs belonging to the table's domain."""
        dom = self.domain_of_table[table]
        return {d: doc.truth for d, doc in self.docs.items() if doc.domain == dom}

    def subset(self, doc_ids) -> "Corpus":
        """Restrict to `doc_ids` (CI-sized workloads). Every table keeps
        the full restricted pool as candidates — like the generators, table
        membership stays something the index must discover, not a given."""
        ids = [d for d in doc_ids if d in self.docs]
        return Corpus(f"{self.name}-subset", {d: self.docs[d] for d in ids},
                      {t: list(ids) for t in self.tables}, self.attr_specs,
                      self.domain_of_table)


# --------------------------------------------------------------- helpers ---

FIRST = ["James", "Maria", "Wei", "Aisha", "Carlos", "Elena", "Tom", "Priya",
         "Jamal", "Sofia", "Liam", "Nina", "Omar", "Grace", "Hugo", "Ivy",
         "Ken", "Lara", "Marco", "Noor", "Pablo", "Rosa", "Sven", "Tara"]
LAST = ["Walker", "Chen", "Garcia", "Okafor", "Silva", "Novak", "Kim", "Patel",
        "Johnson", "Mbeki", "Larsen", "Ortiz", "Tanaka", "Weber", "Diaz",
        "Kovac", "Brown", "Rossi", "Ahmed", "Nilsson"]
CITY_NAMES = ["Austin", "Riverton", "Lakemont", "Harborview", "Stonefield",
              "Brookside", "Fairhaven", "Mapleton", "Crestwood", "Seaport",
              "Northgate", "Eastvale", "Westbrook", "Southridge", "Pinehurst",
              "Oakland Hills", "Silver Falls", "Granite Bay", "Sunfield", "Moss Point"]
MASCOTS = ["Falcons", "Tigers", "Comets", "Raptors", "Wolves", "Hornets",
           "Pioneers", "Storm", "Titans", "Mariners", "Blazers", "Cyclones"]
STATES = ["Texas", "Ohio", "Nevada", "Oregon", "Georgia", "Maine", "Utah",
          "Kansas", "Iowa", "Vermont"]
COUNTRIES = ["American", "Spanish", "Nigerian", "Brazilian", "Croatian",
             "Japanese", "German", "Canadian", "French", "Australian"]
POSITIONS = ["point guard", "shooting guard", "small forward", "power forward", "center"]
CRIMES = ["fraud", "burglary", "assault", "embezzlement", "arson", "smuggling"]
COURTS = ["District Court of Riverton", "Lakemont Court of Appeals",
          "Harborview Superior Court", "Stonefield Circuit Court",
          "Fairhaven High Court", "Northgate Criminal Court"]

FILLER = {
    "sports": [
        "The season drew record attendance across the league.",
        "Analysts praised the coaching staff for disciplined rotations.",
        "Local media covered the preseason workouts extensively.",
        "Ticket demand surged ahead of the conference finals.",
        "The franchise invested heavily in its development program.",
        "Broadcast ratings climbed steadily through the playoffs.",
        "A new practice facility opened to the public last spring.",
        "Supporters organized community events throughout the year.",
    ],
    "finance": [
        "Portfolio allocations shifted toward fixed income last quarter.",
        "The holding company restructured its venture arm.",
        "Dividend policy remained unchanged despite market turbulence.",
        "Philanthropic pledges were announced at the annual gala.",
        "Advisors highlighted exposure to emerging markets.",
        "The family office expanded its real estate positions.",
        "Regulatory filings disclosed several new board seats.",
    ],
    "civic": [
        "The council approved a new transit corridor in spring.",
        "Municipal bonds funded the riverfront restoration project.",
        "Residents gathered for the annual harvest festival downtown.",
        "Zoning reforms opened several districts to mixed use.",
        "The public library extended weekend opening hours.",
        "Road maintenance crews completed the bridge resurfacing.",
    ],
    "cinema": [
        "Principal photography wrapped after a demanding schedule.",
        "Critics praised the cinematography in festival screenings.",
        "The score was recorded with a full orchestra.",
        "Early previews generated strong word of mouth.",
        "The studio confirmed a streaming release window.",
        "Casting announcements drew considerable press attention.",
    ],
    "corporate": [
        "Quarterly guidance was revised upward on strong demand.",
        "The board approved a share buyback program.",
        "Supply chain constraints eased through the second half.",
        "A new logistics hub opened near the coast.",
        "The sustainability report outlined emission targets.",
        "Management reiterated its hiring plans for engineering.",
    ],
    "legal": [
        "The hearing proceeded without interruption before a full gallery.",
        "Counsel for the defense submitted supplementary briefs.",
        "Procedural motions occupied much of the morning session.",
        "The clerk recorded exhibits into the permanent docket.",
        "Witness testimony continued into the late afternoon.",
        "The prosecution rested after presenting forensic analysis.",
        "Jury selection had concluded earlier that week.",
        "Observers noted the unusual length of deliberations.",
        "The bailiff maintained order during the announcement.",
        "Several continuances had delayed the original schedule.",
    ],
    "web": [
        "The campus tour is offered twice daily during term.",
        "Visitors can find directions and parking details online.",
        "The newsletter highlights alumni achievements quarterly.",
        "Frequently asked questions are answered on the portal.",
        "The office responds to inquiries within two business days.",
    ],
}


def _sent_join(rng: random.Random, planted: list[str], filler_pool: list[str],
               n_filler: int) -> tuple[str, list[str]]:
    filler = [rng.choice(filler_pool) for _ in range(n_filler)]
    sents = planted + filler
    rng.shuffle(sents)
    return " ".join(sents), sents


def _render_doc(rng: random.Random, doc_id: str, domain: str,
                specs: dict, values: dict, filler_pool: list[str],
                n_filler: int, intro: str) -> Document:
    planted, spans = [], {}
    for attr, spec in specs.items():
        v = values[attr]
        t = rng.choice(spec.templates)
        sent = t.format(v)
        planted.append(sent)
        spans[attr] = sent
    body, _ = _sent_join(rng, planted, filler_pool, n_filler)
    text = f"{intro} {body}"
    d = Document(doc_id, domain, text, dict(values), spans)
    d.tokens = count_tokens(text)
    return d


# ------------------------------------------------------------ wiki corpus --


def _wiki_specs():
    players = {
        "player_name": AttrSpec("player_name", "str", "Full name of the basketball player.",
            ["The player profiled here is {}.", "This article covers the career of {}."],
            r"(?:profiled here is|covers the career of) ([A-Z][a-z]+ [A-Z][a-zA-Z]+)"),
        "age": AttrSpec("age", "int", "Player's age in years.",
            ["He is {} years old.", "At {} years of age, he remains a regular starter."],
            r"(?:He is|At) (\d+) years (?:old|of age)"),
        "team_name": AttrSpec("team_name", "str", "Name of the team the player currently plays for.",
            ["He currently plays for the {}.", "His current club is the {}."],
            r"(?:plays for the|current club is the) ([A-Z][a-zA-Z]+(?: [A-Z][a-zA-Z]+)*)\."),
        "all_stars": AttrSpec("all_stars", "int", "Number of All-Star selections earned.",
            ["He has earned {} All-Star selections.", "His resume includes {} All-Star selections."],
            r"(\d+) All-Star selections"),
        "ppg": AttrSpec("ppg", "float", "Career scoring average in points per game.",
            ["He averages {} points per game.", "His scoring average stands at {} points per game."],
            r"(\d+\.\d) points per game"),
        "position": AttrSpec("position", "str", "Playing position on the court.",
            ["His listed position is {}.", "Scouts describe his position as {}."],
            r"position (?:is|as) (point guard|shooting guard|small forward|power forward|center)"),
        "nationality": AttrSpec("nationality", "str", "Player's nationality.",
            ["He holds {} nationality.", "By nationality he is {}."],
            r"(?:holds|he is) ([A-Z][a-z]+)(?: nationality)?\."),
    }
    teams = {
        "team_name": AttrSpec("team_name", "str", "Official name of the basketball team.",
            ["This page describes the franchise known as the {}.",
             "The franchise documented here is the {}."],
            r"(?:known as the|documented here is the) ([A-Z][a-zA-Z]+(?: [A-Z][a-zA-Z]+)*)\."),
        "championships": AttrSpec("championships", "int", "Number of championships the team has won.",
            ["The club has captured {} championships.", "Its trophy cabinet holds {} championships."],
            r"(\d+) championships"),
        "location": AttrSpec("location", "str", "Home city where the team is based.",
            ["The team is based in the city of {}.", "Home games are hosted in the city of {}."],
            r"(?:based in|hosted in) the city of ([A-Z][a-zA-Z]+(?: [A-Z][a-zA-Z]+)*)\."),
        "owner_name": AttrSpec("owner_name", "str", "Name of the team's principal owner.",
            ["The principal owner of the club is {}.", "Ownership rests with {}."],
            r"(?:principal owner of the club is|Ownership rests with) ([A-Z][a-z]+ [A-Z][a-zA-Z]+)"),
        "founded": AttrSpec("founded", "int", "Year the team was founded.",
            ["The organization was founded in {}.", "Established in {}, the club has deep roots."],
            r"(?:founded in|Established in) (\d{4})"),
        "arena_capacity": AttrSpec("arena_capacity", "int", "Seating capacity of the team's arena.",
            ["Its arena seats {} spectators.", "The home arena accommodates {} spectators."],
            r"(?:seats|accommodates) (\d+) spectators"),
    }
    cities = {
        "city_name": AttrSpec("city_name", "str", "Name of the city.",
            ["This entry concerns the municipality of {}.", "The city chronicled here is {}."],
            r"(?:municipality of|chronicled here is) ([A-Z][a-zA-Z]+(?: [A-Z][a-zA-Z]+)*)\."),
        "population": AttrSpec("population", "int", "Resident population of the city.",
            ["The resident population totals {}.", "Census figures put the population at {}."],
            r"population (?:totals|at) (\d+)"),
        "state": AttrSpec("state", "str", "State in which the city lies.",
            ["It lies within the state of {}.", "Administratively it belongs to the state of {}."],
            r"state of ([A-Z][a-z]+)"),
        "founded_year": AttrSpec("founded_year", "int", "Year of incorporation of the city.",
            ["The settlement was incorporated in {}.", "Incorporation dates to {}."],
            r"(?:incorporated in|Incorporation dates to) (\d{4})"),
    }
    owners = {
        "owner_name": AttrSpec("owner_name", "str", "Full name of the business figure.",
            ["This biography belongs to {}.", "The subject of this biography is {}."],
            r"(?:biography belongs to|biography is) ([A-Z][a-z]+ [A-Z][a-zA-Z]+)"),
        "net_worth": AttrSpec("net_worth", "float", "Estimated net worth in billions of dollars.",
            ["Estimates place the net worth near {} billion dollars.",
             "Financial outlets report a net worth of {} billion dollars."],
            r"net worth (?:near|of) (\d+\.\d) billion"),
        # NOTE: first template intentionally shared with players.age — real
        # corpora overlap lexically across domains; this is what makes the
        # document-level index earn its keep (segment-only pays for it).
        "owner_age": AttrSpec("owner_age", "int", "Age of the business figure.",
            ["He is {} years old.", "Now {} years old, the investor stays active."],
            r"(?:He is|Now) (\d+) years old"),
        "industry": AttrSpec("industry", "str", "Primary industry of the owner's fortune.",
            ["The fortune originates from the {} industry.",
             "Most holdings concentrate in the {} industry."],
            r"(?:from|in) the ([a-z]+) industry"),
    }
    movies = {
        "title": AttrSpec("title", "str", "Movie title.",
            ["The film reviewed here is {}.", "This synopsis covers the film {}."],
            r"film (?:reviewed here is|covers the film)? ?([A-Z][a-zA-Z ]+)\."),
        "box_office": AttrSpec("box_office", "int", "Worldwide box office gross in millions.",
            ["Worldwide grosses reached {} million.", "It earned {} million at the box office."],
            r"(\d+) million"),
        "director_name": AttrSpec("director_name", "str", "Name of the film's director.",
            ["Direction was handled by {}.", "It was directed by {}."],
            r"(?:handled by|directed by) ([A-Z][a-z]+ [A-Z][a-zA-Z]+)"),
    }
    companies = {
        "company_name": AttrSpec("company_name", "str", "Registered company name.",
            ["The corporation profiled is {}.", "This report examines {}."],
            r"(?:corporation profiled is|report examines) ([A-Z][a-zA-Z]+(?: [A-Z][a-zA-Z]+)*)\."),
        "revenue": AttrSpec("revenue", "float", "Annual revenue in billions of dollars.",
            ["Annual revenue reached {} billion dollars.", "It reported revenue of {} billion dollars."],
            r"revenue (?:reached|of) (\d+\.\d) billion"),
        "employees": AttrSpec("employees", "int", "Number of employees.",
            ["The workforce numbers {} employees.", "It employs {} employees worldwide."],
            r"(\d+) employees"),
    }
    return {"players": players, "teams": teams, "cities": cities,
            "owners": owners, "movies": movies, "companies": companies}


def make_wiki_corpus(seed: int = 0) -> Corpus:
    rng = random.Random(seed)
    specs = _wiki_specs()
    docs: dict = {}

    def uniq_names(n, maker):
        out = []
        seen = set()
        while len(out) < n:
            v = maker()
            if v not in seen:
                seen.add(v)
                out.append(v)
        return out

    city_vals = uniq_names(20, lambda: rng.choice(CITY_NAMES))
    team_vals = uniq_names(24, lambda: f"{rng.choice(CITY_NAMES).split()[0]} {rng.choice(MASCOTS)}")
    owner_vals = uniq_names(20, lambda: f"{rng.choice(FIRST)} {rng.choice(LAST)}")
    player_vals = uniq_names(60, lambda: f"{rng.choice(FIRST)} {rng.choice(LAST)}")

    def add(domain, i, values, intro, n_filler=10):
        doc_id = f"wiki/{domain}/{i:03d}"
        pool = {"players": "sports", "teams": "sports", "cities": "civic",
                "owners": "finance", "movies": "cinema", "companies": "corporate"}[domain]
        docs[doc_id] = _render_doc(rng, doc_id, domain, specs_map[domain],
                                   values, FILLER[pool], n_filler, intro)

    specs_map = specs
    for i, cname in enumerate(city_vals):
        add("cities", i, {
            "city_name": cname,
            "population": rng.randrange(40_000, 2_000_000, 1000),
            "state": rng.choice(STATES),
            "founded_year": rng.randint(1790, 1920),
        }, "An overview of a mid-sized municipality follows.")
    for i, tname in enumerate(team_vals):
        add("teams", i, {
            "team_name": tname,
            "championships": rng.randint(0, 18),
            "location": rng.choice(city_vals),
            "owner_name": rng.choice(owner_vals),
            "founded": rng.randint(1946, 2002),
            "arena_capacity": rng.randrange(15_000, 22_000, 100),
        }, "A franchise history page follows.")
    for i, oname in enumerate(owner_vals):
        add("owners", i, {
            "owner_name": oname,
            "net_worth": round(rng.uniform(1.0, 40.0), 1),
            "owner_age": rng.randint(38, 88),
            "industry": rng.choice(["software", "energy", "media", "finance", "retail"]),
        }, "A biography of a prominent business figure follows.")
    for i, pname in enumerate(player_vals):
        add("players", i, {
            "player_name": pname,
            "age": rng.randint(19, 42),
            "team_name": rng.choice(team_vals),
            "all_stars": rng.randint(0, 15),
            "ppg": round(rng.uniform(2.0, 32.0), 1),
            "position": rng.choice(POSITIONS),
            "nationality": rng.choice(COUNTRIES),
        }, "A profile of a professional athlete follows.")
    for i in range(38):
        add("movies", i, {
            "title": " ".join(w.title() for w in rng.sample(
                ["silent", "river", "echo", "crimson", "harvest", "orbit",
                 "glass", "ember", "northern", "voyage"], 2)),
            "box_office": rng.randrange(20, 900),
            "director_name": f"{rng.choice(FIRST)} {rng.choice(LAST)}",
        }, "A film synopsis follows.")
    for i in range(38):
        add("companies", i, {
            "company_name": f"{rng.choice(CITY_NAMES).split()[0]} {rng.choice(['Dynamics', 'Systems', 'Holdings', 'Labs', 'Group'])}",
            "revenue": round(rng.uniform(0.5, 90.0), 1),
            "employees": rng.randrange(200, 150_000, 100),
        }, "A corporate overview follows.")

    all_ids = sorted(docs)
    tables = {t: list(all_ids) for t in specs}
    return Corpus("wiki", docs, tables, specs, {t: t for t in specs})


# ----------------------------------------------------------- legal corpus --


def _legal_specs():
    return {"cases": {
        "case_number": AttrSpec("case_number", "str", "Docket number of the case.",
            ["The matter is registered under docket {}.", "Filed under docket {}, the case drew attention."],
            r"docket ([A-Z]{2}-\d{4}-\d{3})"),
        "court": AttrSpec("court", "str", "Court where the case was heard.",
            ["Proceedings took place at the {}.", "The matter was heard at the {}."],
            r"(?:took place at|heard at) the ([A-Z][a-zA-Z ]+Court(?: of [A-Z][a-z]+)?)"),
        "judge": AttrSpec("judge", "str", "Name of the presiding judge.",
            ["Presiding over the bench was Judge {}.", "The honorable Judge {} presided."],
            r"Judge ([A-Z][a-z]+ [A-Z][a-zA-Z]+)"),
        "year": AttrSpec("year", "int", "Year the judgment was delivered.",
            ["Judgment was delivered in {}.", "The final ruling came down in {}."],
            r"(?:delivered in|came down in) (\d{4})"),
        "charges": AttrSpec("charges", "int", "Number of charges brought against the defendant.",
            ["The indictment listed {} charges.", "Prosecutors filed {} charges in total."],
            r"(\d+) charges"),
        "sentence_years": AttrSpec("sentence_years", "int", "Custodial sentence length in years.",
            ["The court imposed a sentence of {} years.", "A custodial term of {} years was handed down."],
            r"(?:sentence of|custodial term of) (\d+) years"),
        "crime_type": AttrSpec("crime_type", "str", "Primary category of the offence.",
            ["The principal offence was classified as {}.", "Charges centered on allegations of {}."],
            r"(?:classified as|allegations of) (fraud|burglary|assault|embezzlement|arson|smuggling)"),
        "appeal": AttrSpec("appeal", "str", "Whether an appeal was lodged (yes/no).",
            ["An appeal was lodged: {}.", "Appeal status recorded as {}."],
            r"(?:appeal was lodged: |Appeal status recorded as )(yes|no)"),
        "defendant": AttrSpec("defendant", "str", "Name of the defendant.",
            ["The defendant named in the indictment is {}.", "Proceedings were brought against {}."],
            r"(?:indictment is|brought against) ([A-Z][a-z]+ [A-Z][a-zA-Z]+)"),
        "fine_amount": AttrSpec("fine_amount", "int", "Monetary fine in thousands of dollars.",
            ["A fine of {} thousand dollars accompanied the sentence.",
             "The court additionally levied {} thousand dollars."],
            r"(?:fine of|levied) (\d+) thousand dollars"),
    }}


def make_legal_corpus(seed: int = 1) -> Corpus:
    rng = random.Random(seed)
    specs = _legal_specs()
    docs = {}
    for i in range(100):
        doc_id = f"legal/cases/{i:03d}"
        values = {
            "case_number": f"{rng.choice(['CR', 'CV', 'AP'])}-{rng.randint(2004, 2024)}-{rng.randint(100, 999)}",
            "court": rng.choice(COURTS),
            "judge": f"{rng.choice(FIRST)} {rng.choice(LAST)}",
            "year": rng.randint(2004, 2024),
            "charges": rng.randint(1, 12),
            "sentence_years": rng.randint(0, 30),
            "crime_type": rng.choice(CRIMES),
            "appeal": rng.choice(["yes", "no"]),
            "defendant": f"{rng.choice(FIRST)} {rng.choice(LAST)}",
            "fine_amount": rng.randrange(5, 900, 5),
        }
        # ~6k tokens: large filler volume (long-document regime of LCR)
        docs[doc_id] = _render_doc(rng, doc_id, "cases", specs["cases"], values,
                                   FILLER["legal"], n_filler=320,
                                   intro="In the matter of the State versus the named defendant, the record follows.")
    all_ids = sorted(docs)
    return Corpus("legal", docs, {"cases": all_ids}, specs, {"cases": "cases"})


# ------------------------------------------------------------ swde corpus --


def _swde_specs():
    universities = {
        "university_name": AttrSpec("university_name", "str", "Name of the university.",
            ["Welcome to the admissions page of {}.", "This page is maintained by {}."],
            r"(?:admissions page of|maintained by) ([A-Z][a-zA-Z ]+University)"),
        "city": AttrSpec("city", "str", "City of the main campus.",
            ["The main campus sits in {}.", "Our campus address is in {}."],
            r"(?:campus sits in|address is in) ([A-Z][a-zA-Z ]+)\."),
        "enrollment": AttrSpec("enrollment", "int", "Total enrolled students.",
            ["Current enrollment stands at {} students.", "We serve {} students each year."],
            r"(\d+) students"),
        "founded": AttrSpec("founded", "int", "Founding year.",
            ["Founded in {}, the institution has a long history.", "Our story began in {}."],
            r"(?:Founded in|began in) (\d{4})"),
        "tuition": AttrSpec("tuition", "int", "Annual tuition in dollars.",
            ["Annual tuition is {} dollars.", "Tuition for the year totals {} dollars."],
            r"(?:tuition is|totals) (\d+) dollars"),
        "acceptance_rate": AttrSpec("acceptance_rate", "float", "Acceptance rate percentage.",
            ["The acceptance rate is {} percent.", "Roughly {} percent of applicants are admitted."],
            r"(\d+\.\d) percent"),
        "ranking": AttrSpec("ranking", "int", "National ranking position.",
            ["It holds national ranking number {}.", "Rankings place it at number {} nationally."],
            r"(?:ranking number|at number) (\d+)"),
        "mascot": AttrSpec("mascot", "str", "Athletics mascot.",
            ["Athletics teams compete as the {}.", "Students cheer for the {}."],
            r"(?:compete as the|cheer for the) ([A-Z][a-zA-Z]+)\."),
    }
    laptops = {
        "model_name": AttrSpec("model_name", "str", "Product model name.",
            ["Product listing for the {}.", "You are viewing the {}."],
            r"(?:listing for the|viewing the) ([A-Z][a-zA-Z]+ [A-Z0-9][a-zA-Z0-9]+)"),
        "price": AttrSpec("price", "int", "Retail price in dollars.",
            ["The retail price is {} dollars.", "Yours today for {} dollars."],
            r"(?:price is|for) (\d+) dollars"),
        "ram_gb": AttrSpec("ram_gb", "int", "Installed memory in gigabytes.",
            ["It ships with {} gigabytes of memory.", "Memory capacity: {} gigabytes."],
            r"(\d+) gigabytes"),
        "storage_tb": AttrSpec("storage_tb", "int", "Storage in terabytes.",
            ["Storage options start at {} terabytes.", "It includes {} terabytes of storage."],
            r"(\d+) terabytes"),
        "screen_inches": AttrSpec("screen_inches", "float", "Screen size in inches.",
            ["The display measures {} inches.", "A {} inch panel dominates the design."],
            r"(\d+\.\d) inch"),
        "weight_kg": AttrSpec("weight_kg", "float", "Weight in kilograms.",
            ["It weighs {} kilograms.", "Total weight comes to {} kilograms."],
            r"(\d+\.\d) kilograms"),
        "battery_hours": AttrSpec("battery_hours", "int", "Battery life in hours.",
            ["Battery life reaches {} hours.", "Expect up to {} hours of battery."],
            r"(\d+) hours"),
        "brand": AttrSpec("brand", "str", "Manufacturer brand.",
            ["It is manufactured by {}.", "A flagship machine from {}."],
            r"(?:manufactured by|machine from) ([A-Z][a-zA-Z]+)\."),
    }
    return {"universities": universities, "laptops": laptops}


def make_swde_corpus(seed: int = 2) -> Corpus:
    rng = random.Random(seed)
    specs = _swde_specs()
    docs = {}
    for i in range(100):
        doc_id = f"swde/universities/{i:03d}"
        values = {
            "university_name": f"{rng.choice(CITY_NAMES).split()[0]} {rng.choice(['State ', 'Tech ', ''])}University",
            "city": rng.choice(CITY_NAMES),
            "enrollment": rng.randrange(1_000, 60_000, 100),
            "founded": rng.randint(1800, 1990),
            "tuition": rng.randrange(8_000, 65_000, 500),
            "acceptance_rate": round(rng.uniform(4.0, 95.0), 1),
            "ranking": rng.randint(1, 300),
            "mascot": rng.choice(MASCOTS),
        }
        docs[doc_id] = _render_doc(rng, doc_id, "universities", specs["universities"],
                                   values, FILLER["web"], n_filler=4,
                                   intro="University admissions overview page.")
    for i in range(100):
        doc_id = f"swde/laptops/{i:03d}"
        values = {
            "model_name": f"{rng.choice(['Nova', 'Zen', 'Aero', 'Volt', 'Pixeler'])} {rng.choice(['X', 'Pro', 'Air', 'Ultra'])}{rng.randint(1, 9)}",
            "price": rng.randrange(400, 4000, 50),
            "ram_gb": rng.choice([8, 16, 32, 64]),
            "storage_tb": rng.choice([1, 2, 4]),
            "screen_inches": rng.choice([13.3, 14.0, 15.6, 16.2, 17.3]),
            "weight_kg": round(rng.uniform(0.9, 3.5), 1),
            "battery_hours": rng.randint(6, 24),
            "brand": rng.choice(["Lenark", "Dellux", "Asix", "Framewerk", "Macron"]),
        }
        docs[doc_id] = _render_doc(rng, doc_id, "laptops", specs["laptops"],
                                   values, FILLER["web"], n_filler=4,
                                   intro="Online electronics store product page.")
    all_ids = sorted(docs)
    tables = {t: list(all_ids) for t in specs}
    return Corpus("swde", docs, tables, specs, {t: t for t in specs})


CORPORA = {"wiki": make_wiki_corpus, "legal": make_legal_corpus, "swde": make_swde_corpus}
