"""LM training data pipeline: byte-level tokenizer + deterministic,
checkpointable batch iterator over a document corpus.

The cursor (epoch, offset, rng key) is part of the training checkpoint so a
restarted job consumes exactly the batches it would have (bit-exact resume).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

BOS, EOS, PAD = 256, 257, 258
VOCAB = 260  # byte values + specials (models with larger vocabs just ignore the tail)


def encode(text: str) -> list[int]:
    return list(text.encode("utf-8", errors="replace"))


def decode(ids) -> str:
    return bytes(i for i in ids if 0 <= i < 256).decode("utf-8", errors="replace")


def corpus_token_stream(corpus) -> np.ndarray:
    parts = []
    for doc_id in sorted(corpus.docs):
        parts.append([BOS] + encode(corpus.docs[doc_id].text) + [EOS])
    flat = [t for p in parts for t in p]
    return np.asarray(flat, np.int32)


@dataclass
class DataState:
    offset: int = 0
    epoch: int = 0


class LMBatches:
    """Sequential batcher: (tokens, labels) of shape (B, S)."""

    def __init__(self, stream: np.ndarray, batch: int, seq: int):
        self.stream = stream
        self.batch = batch
        self.seq = seq
        self.state = DataState()

    def next(self) -> dict:
        need = self.batch * (self.seq + 1)
        n = len(self.stream)
        out = np.empty((need,), np.int32)
        off = self.state.offset
        got = 0
        while got < need:
            take = min(need - got, n - off)
            out[got:got + take] = self.stream[off:off + take]
            got += take
            off += take
            if off >= n:
                off = 0
                self.state.epoch += 1
        self.state.offset = off
        x = out.reshape(self.batch, self.seq + 1)
        return {"tokens": x[:, :-1].copy(), "labels": x[:, 1:].copy()}

    def snapshot(self) -> dict:
        return {"offset": self.state.offset, "epoch": self.state.epoch}

    def restore(self, snap: dict):
        self.state = DataState(snap["offset"], snap["epoch"])
