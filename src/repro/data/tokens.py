"""Deterministic whitespace/punctuation tokenizer + token counting.

All cost accounting (paper metric: tokens/doc) flows through `count_tokens`
so QUEST, baselines and the serving cost model agree on the unit.
"""
from __future__ import annotations

import re

_TOKEN_RE = re.compile(r"[A-Za-z]+|\d+|[^\sA-Za-z\d]")


def tokenize(text: str) -> list[str]:
    return _TOKEN_RE.findall(text)


def count_tokens(text: str) -> int:
    return len(_TOKEN_RE.findall(text))


_WORD_RE = re.compile(r"[A-Za-z]+|\d+")


def words(text: str) -> list[str]:
    return [w.lower() for w in _WORD_RE.findall(text)]


_SENT_RE = re.compile(r"(?<=[.!?])\s+")


def split_sentences(text: str) -> list[str]:
    parts = [s.strip() for s in _SENT_RE.split(text)]
    return [s for s in parts if s]
