"""CascadeExtractor: difficulty-aware two-tier extraction (DESIGN.md §18).

QUEST minimizes *which segments* reach the LLM; the cascade adds the next
cost axis — *which model*. A small zoo model (the same second-engine
plumbing the draft-model drafter of §14 uses, promoted to a first-class
extractor) serves the easy per-(doc, attr) extractions; the target model
serves the hard ones and every extraction the verifier bounces.

Routing: `core.difficulty.DifficultyEstimator` scores each (doc, attr)
from sampling-phase agreement stats, segment retrieval margins, and
context length; scores at or below its threshold go to the small tier.
A (doc, attr) the verifier ever escalated is memoized (`tier_memo`) and
routed straight to the target from then on — it never pays the small
model twice. Under a live corpus the memo and the difficulty estimates
drop with the mutated document (InvalidationCascade, §17/§18).

Verification: the small tier's answer goes through the same §8.1 parse
(decoded text, then the oracle-fallback context parse). A structurally
invalid result — no parseable value from either — escalates to the
target model in the same `extract_batch` round. Because the §8.1 parse
is deterministic in (doc, attr, segments), an accepted small-tier value
is the value the target path would have produced, so the cascade's row
parity is exact on this container, and with trained checkpoints the
verifier bar tightens to decoded-parse agreement at unchanged plumbing.

Modes (`cascade=`): "on" (route by difficulty), "off" (byte-identical to
a plain ServedExtractor on the target engine — the small engine is never
touched), "verify_all" (degenerate-routing parity check: everything
routes small and the verifier escalates everything, so rows must be
byte-identical to target-only while the small tier's cost is pure waste).

Accounting: small-tier requests/prompt/decode tokens land in dedicated
`CascadeServedStats` columns (the inherited columns stay target-tier
only); `target_tokens_saved` counts the prompt+decode tokens of accepted
small-tier extractions — target-model work that never happened. The
scheduler forwards round deltas to `CostLedger.record_cascade`, keeping
the logical token columns cascade-invariant like every other serving
optimization.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.difficulty import DifficultyEstimator
from repro.data import lm_data
from repro.data.tokens import count_tokens
from repro.obs import as_tracer

from .served import ServedExtractor, ServedStats

CASCADE_MODES = ("on", "off", "verify_all")


@dataclass
class CascadeServedStats(ServedStats):
    # the inherited request/token columns count the *target* tier only;
    # the small tier reports apart so per-tier economics stay legible
    small_requests: int = 0
    small_prompt_tokens: int = 0
    small_generated_tokens: int = 0
    routed_small: int = 0          # routing decisions -> small tier
    routed_target: int = 0         # routing decisions -> target tier
    memo_target_routes: int = 0    # routed target because the memo said so
    escalations: int = 0           # verifier bounces (small -> target)
    accepted_small: int = 0        # small-tier values that stood
    target_tokens_saved: int = 0   # target prompt+decode tokens avoided


class CascadeExtractor(ServedExtractor):
    """ServedExtractor with a small-model fast tier. Same `extract_batch`
    / `extract_full_doc_batch` / `escalate_batch` contract, same scheduler
    protocol (`accepts_owners`); sampling sweeps and full-document
    escalations always run on the target engine (they are the evidence
    the difficulty estimates and output-critical retries rest on)."""

    def __init__(self, corpus, engine, small_engine=None, *,
                 cascade: str = "on", difficulty: DifficultyEstimator = None,
                 retriever=None, **kwargs):
        """`engine` is the target tier, `small_engine` the cheap tier (a
        ServingEngine over a smaller zoo config; None degrades to
        `cascade="off"`). `difficulty` is the routing estimator — built
        over `retriever` when omitted, so margins flow without extra
        wiring. Remaining kwargs are ServedExtractor's."""
        super().__init__(corpus, engine, **kwargs)
        if cascade not in CASCADE_MODES:
            raise ValueError(f"unknown cascade mode {cascade!r} "
                             f"(known: {CASCADE_MODES})")
        self.small_engine = small_engine
        self.cascade = cascade if small_engine is not None else "off"
        self.difficulty = (difficulty if difficulty is not None
                           else DifficultyEstimator(retriever))
        self.tier_memo: set = set()   # (doc_id, attr) escalated once already
        self.stats = CascadeServedStats()

    # ------------------------------------------------------------ routing --

    def _route(self, doc_id, attr: str, seg_tokens: int) -> str:
        if self.cascade == "verify_all":
            self.stats.routed_small += 1
            return "small"
        if (doc_id, attr) in self.tier_memo:
            self.stats.memo_target_routes += 1
            self.stats.routed_target += 1
            return "target"
        table = self.corpus.docs[doc_id].table
        tier = self.difficulty.route(doc_id, attr, table, seg_tokens)
        if tier == "small":
            self.stats.routed_small += 1
        else:
            self.stats.routed_target += 1
        return tier

    # ------------------------------------------------------ small serving --

    def _make_small_request(self, prefix_text, tail_text, owner=None,
                            content_docs=()):
        """Target-shaped request re-homed to the small tier: built by the
        parent (identical prompt bytes — the escalation path must replay
        the exact prompt on the target), then its counts move to the
        small-tier stat columns."""
        req = self._make_request(prefix_text, tail_text, owner=owner,
                                 content_docs=content_docs)
        self.stats.requests -= 1
        self.stats.prompt_tokens -= len(req.prompt)
        self.stats.small_requests += 1
        self.stats.small_prompt_tokens += len(req.prompt)
        return req

    def _run_small_round(self, reqs: list) -> dict:
        """One continuous-batching round on the small engine — the same
        drain loop as `_run_round`, with decode tokens landing in the
        small-tier column and engine-side prefix/spec deltas folded into
        the shared counters (a prefix hit is a saving whichever tier
        takes it)."""
        outs = {}
        es = self.small_engine.stats
        # spans land on the *target* engine's tracer: one trace per system
        tracer = as_tracer(getattr(self.engine, "tracer", None))
        hits0, saved0 = es["prefix_hits"], es["prefix_saved_tokens"]
        spec0 = (es["draft_tokens"], es["accepted_tokens"],
                 es["decode_steps_saved"])
        with tracer.span("cascade.small_round", kind="cascade",
                         reqs=len(reqs)):
            window = self.small_engine.queue_depth or len(reqs)
            for i in range(0, len(reqs), max(window, 1)):
                chunk = reqs[i:i + max(window, 1)]
                self.small_engine.submit_many(chunk)
                done = self.small_engine.run()
                self.stats.batches += 1
                self.stats.max_batch = max(self.stats.max_batch, len(chunk))
                for req in chunk:
                    if req.rid not in done:
                        failed = self.small_engine.failed.get(req.rid)
                        raise RuntimeError(
                            f"small-tier request {req.rid} failed: "
                            f"{failed.error if failed else 'not in finished set'}")
                    out = done[req.rid].out
                    self.stats.small_generated_tokens += len(out)
                    outs[req.rid] = lm_data.decode(out)
            self._note_round_deltas(es, hits0, saved0, spec0)
        return outs

    # ----------------------------------------------------------- protocol --

    def extract_batch(self, items: list, owners: list = None):
        """Cascaded batch round: route every item, run the small tier's
        round, verify, escalate rejects into the target tier's round of
        the *same* call — so one scheduler round still resolves every
        item, whatever mix of tiers it took."""
        if self.cascade == "off":
            return super().extract_batch(items, owners)
        results: list = [None] * len(items)
        small, target = [], []      # (item index, doc, attr, text, tokens)
        for i, (doc_id, attr, segments) in enumerate(items):
            text = " ".join(segments)
            if not text:
                results[i] = (None, 0)
                continue
            entry = (i, doc_id, attr, text, count_tokens(text))
            tier = self._route(doc_id, attr, entry[4])
            (small if tier == "small" else target).append(entry)
        tracer = as_tracer(getattr(self.engine, "tracer", None))
        tracer.instant("cascade.route", kind="cascade",
                       small=len(small), target=len(target))

        reqs, meta = [], []
        for i, doc_id, attr, text, tokens in small:
            req = self._make_small_request(
                self._prompt_prefix(doc_id, attr), f"{text} Answer:",
                owner=owners[i] if owners else None, content_docs=(doc_id,))
            reqs.append(req)
            meta.append((i, doc_id, attr, text, tokens, req))
        outs = self._run_small_round(reqs) if reqs else {}
        for i, doc_id, attr, text, tokens, req in meta:
            value = self._parse(doc_id, attr, outs[req.rid], text)
            if value is not None and self.cascade != "verify_all":
                self.stats.accepted_small += 1
                self.stats.target_tokens_saved += \
                    len(req.prompt) + self.max_new
                results[i] = (value, tokens)
            else:
                self.stats.escalations += 1
                self.tier_memo.add((doc_id, attr))
                target.append((i, doc_id, attr, text, tokens))
                if tracer.enabled(2):
                    tracer.instant("cascade.escalate", kind="cascade",
                                   level=2, doc=str(doc_id), attr=attr)

        reqs, meta = [], []
        for i, doc_id, attr, text, tokens in target:
            req = self._make_request(
                self._prompt_prefix(doc_id, attr), f"{text} Answer:",
                owner=owners[i] if owners else None, content_docs=(doc_id,))
            reqs.append(req)
            meta.append((i, doc_id, attr, text, tokens, req.rid))
        if reqs:
            outs = self._run_round(reqs)
            for i, doc_id, attr, text, tokens, rid in meta:
                results[i] = (self._parse(doc_id, attr, outs[rid], text),
                              tokens)
        return results
