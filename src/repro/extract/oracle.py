"""Oracle LLM extractor with a calibrated context-length noise model.

Used for the paper-table experiments: extraction correctness is a controlled
function of (a) whether the retrieved segments actually contain the value
(retrieval quality — QUEST's variable under test) and (b) context length
(longer prompts -> higher error rate, reproducing the paper's observation
that full-document feeding misleads the LLM on long docs, e.g. Lotus' F1
collapse on LCR). Token accounting is exact.

Error model, per (doc, attr) deterministic:
  present value : miss/corrupt with p = P_MISS + P_CONFUSE * max(0, T - T0)/SCALE
  absent value  : hallucinate with p = P_HALL * min(1, T / SCALE)
where T = prompt tokens.
"""
from __future__ import annotations

import hashlib
import random

from repro.data.tokens import count_tokens

P_MISS = 0.02
P_CONFUSE = 0.18
P_HALL = 0.10
T0 = 600
SCALE = 4000.0


def _doc_rng(doc_id, attr: str, salt: str = "") -> random.Random:
    h = hashlib.blake2b(f"{doc_id}|{attr}|{salt}".encode(), digest_size=8).digest()
    return random.Random(int.from_bytes(h, "little"))


class OracleExtractor:
    # accepts the scheduler's owners= protocol extension (a no-op here:
    # the oracle has no admission tier to route tenants into) so oracle
    # and served paths run under identical scheduler call shapes
    accepts_owners = True

    def __init__(self, corpus, *, noisy: bool = True):
        self.corpus = corpus
        self.noisy = noisy

    # -- helpers ------------------------------------------------------------

    def _spec_for(self, attr: str):
        for table, attrs in self.corpus.attr_specs.items():
            if attr in attrs:
                return attrs[attr]
        return None

    def _fabricate(self, attr: str, rng: random.Random):
        spec = self._spec_for(attr)
        if spec is None:
            return None
        if spec.kind == "int":
            return rng.randint(1, 40)
        if spec.kind == "float":
            return round(rng.uniform(1.0, 40.0), 1)
        return rng.choice(["Example Value", "Unknown Entity", "Riverton Комета"])[:20]

    def _error_rates(self, tokens: int):
        p_err = P_MISS + P_CONFUSE * max(0, tokens - T0) / SCALE
        p_hall = P_HALL * min(1.0, tokens / SCALE)
        return min(p_err, 0.5), min(p_hall, 0.3)

    # -- protocol -----------------------------------------------------------

    def extract(self, doc_id, attr: str, segments: list[str]):
        """Returns (value_or_None, input_tokens)."""
        text = " ".join(segments)
        tokens = count_tokens(text)
        doc = self.corpus.docs[doc_id]
        spec = self.corpus.spec(doc.domain, attr) or self._spec_for(attr)
        value = spec.parse(text) if (spec and text) else None
        if not self.noisy:
            return value, tokens
        rng = _doc_rng(doc_id, attr)
        p_err, p_hall = self._error_rates(tokens)
        if value is not None:
            if rng.random() < p_err:
                value = None if rng.random() < 0.7 else self._fabricate(attr, rng)
        else:
            if text and rng.random() < p_hall:
                value = self._fabricate(attr, rng)
        return value, tokens

    def extract_batch(self, items: list, owners: list = None):
        """Batched protocol: items = [(doc_id, attr, segments)], returns
        [(value, input_tokens)]. The oracle is deterministic per (doc, attr),
        so batching cannot change values or accounting — the property the
        batched-execution equivalence tests lean on."""
        return [self.extract(doc_id, attr, segments)
                for doc_id, attr, segments in items]

    def extract_full_doc_batch(self, items: list, owners: list = None):
        """items = [(doc_id, attrs)] -> [(values, segs_by_attr, tokens)]."""
        return [self.extract_full_doc(doc_id, attrs) for doc_id, attrs in items]

    def extract_full_doc(self, doc_id, attrs: list[str]):
        """Sampling-phase call: whole document in, values + source segments
        out. Returns (values dict, segments-by-attr dict, input_tokens)."""
        doc = self.corpus.docs[doc_id]
        tokens = doc.tokens or count_tokens(doc.text)
        values, segs = {}, {}
        for attr in attrs:
            spec = self.corpus.spec(doc.domain, attr)
            v = spec.parse(doc.text) if spec else None
            if self.noisy:
                rng = _doc_rng(doc_id, attr, salt="full")
                p_err, p_hall = self._error_rates(tokens)
                if v is not None and rng.random() < p_err:
                    v = None
                elif v is None and rng.random() < p_hall * 0.5:
                    v = self._fabricate(attr, rng)
            values[attr] = v
            if v is not None and attr in doc.spans:
                segs[attr] = [doc.spans[attr]]
        return values, segs, tokens
