"""ServedExtractor: QUEST's extraction operator driven by the *real* JAX
serving engine.

The retrieved segments become a real prompt; prefill/decode run through
`repro.serving.ServingEngine` (continuous batching, KV caches, the whole
substrate), and the ledger charges the engine's true token counts. Since no
pretrained checkpoint ships in this container, answer *parsing* falls back
to the corpus pattern oracle when the model's decoded text doesn't parse —
cost/latency are real, accuracy is oracle-backed; with a trained checkpoint
(`examples/train_extractor.py`) the decoded text itself is used. This split
is documented in DESIGN.md §8.1.

`extract_batch` is the cross-document fast path (DESIGN.md §9): N prompts
are submitted together and drained by a *single* `engine.run()`, so the
engine's slots stay full and prefill/decode interleave across documents —
the serial `extract` path drains the engine once per extraction instead.

Prompts are ordered shared-part-first (DESIGN.md §10): the static task
template + attribute name + description come before the per-document
evidence, and `Request.shared_len` marks that boundary, so an engine with
the prefix KV cache enabled prefills the template once per attribute and
only the evidence tail per document. The byte-level tokenizer makes the
boundary exact (`encode(a + b) == encode(a) + encode(b)`).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.data import lm_data
from repro.data.tokens import count_tokens
from repro.obs import as_tracer
from repro.serving.engine import Request, ServingEngine

MAX_PROMPT_TOKENS = 220


@dataclass
class ServedStats:
    requests: int = 0
    prompt_tokens: int = 0
    generated_tokens: int = 0
    batches: int = 0          # extract_batch rounds (one engine.run() each)
    max_batch: int = 0
    prefix_hits: int = 0               # engine prefix-cache hits for our reqs
    saved_prefill_tokens: int = 0      # prefill tokens skipped via those hits
    draft_tokens: int = 0              # speculative decode (DESIGN.md §14):
    accepted_tokens: int = 0           # drafted/accepted tokens and decode
    decode_steps_saved: int = 0        # steps saved for our requests


class ServedExtractor:
    # opt-in scheduler protocol extension (core/scheduler.py): batch calls
    # may carry `owners=` (per-item child ledgers) so requests inherit the
    # owning query's tenant/priority for admission control
    accepts_owners = True

    def __init__(self, corpus, engine: ServingEngine, *, max_new: int = 12,
                 oracle_fallback: bool = True, frontend=None,
                 doc_prefix_escalation: bool = False):
        """frontend: optional `serving.frontend.ServingFrontend` fronting
        `engine`. When set, every extraction round routes through its
        admission queue (per-tenant fair share, page-headroom backpressure)
        instead of submitting straight to the engine — rows stay
        byte-identical, scheduling policy changes.

        doc_prefix_escalation: lay full-document escalation prompts
        document-first (the document text is the shareable prefix, the
        attribute question the tail), so several attrs escalated on the
        same document share its prefill KV. Those entries embed document
        text, so a live-corpus mutation of the doc invalidates them
        (DESIGN.md §17) — which is exactly why the default template-first
        layout keeps its prefix entries mutation-immune."""
        self.corpus = corpus
        self.engine = engine
        self.frontend = frontend
        self.max_new = max_new
        self.oracle_fallback = oracle_fallback
        self.doc_prefix_escalation = doc_prefix_escalation
        self.stats = ServedStats()
        self._rid = 0

    # ------------------------------------------------------------ serving --

    def _prompt_prefix(self, doc_id, attr: str) -> str:
        """Shareable prompt head: identical for every document of an
        attribute, so it prefix-caches across the whole corpus sweep."""
        table = self.corpus.docs[doc_id].table
        desc = self.corpus.attr_description(table, attr)
        return (f"Task: report the value of one attribute from document "
                f"evidence. Attribute: {attr} ({desc}). "
                f"Answer with the value only. Evidence: ")

    @staticmethod
    def _owner_identity(owner) -> tuple:
        """(tenant, priority) a request inherits from its owning query's
        child ledger (core/ledger.py tags tenant ledgers and their query
        children); session-direct work runs as the default tenant."""
        tenant = getattr(owner, "tenant", "") or "default"
        return tenant, 0

    def _make_request(self, prefix_text: str, tail_text: str, owner=None,
                      content_docs=(), content_in_prefix=False) -> Request:
        """Build a request from (shareable prefix, per-request tail); the
        tail is truncated to the token budget, never the prefix boundary.
        `content_docs` records which documents' text the prompt embeds and
        `content_in_prefix` where it starts (prefix vs tail) — the engine
        tags prefix-cache entries with it for live-corpus invalidation."""
        cap = 4 * MAX_PROMPT_TOKENS
        prefix = lm_data.encode(prefix_text)[:cap]
        toks = prefix + lm_data.encode(tail_text)[:cap - len(prefix)]
        self._rid += 1
        self.stats.requests += 1
        self.stats.prompt_tokens += len(toks)
        tenant, priority = self._owner_identity(owner)
        return Request(rid=self._rid, prompt=toks or [lm_data.BOS],
                       max_new=self.max_new, eos_id=lm_data.EOS,
                       shared_len=min(len(prefix), max(len(toks) - 1, 0)),
                       tenant=tenant, priority=priority,
                       content_docs=tuple(content_docs),
                       content_start=(0 if content_in_prefix
                                      else len(prefix)) if content_docs
                                     else None)

    def _run_round_frontend(self, reqs: list) -> dict:
        """Admission-tier round: requests queue under their tenants' fair
        share and the frontend pumps the engine until they resolve. A shed
        or failed extraction raises visibly — the session layer never
        mistakes backpressure for an empty answer."""
        tickets = [self.frontend.submit(req=req, tenant=req.tenant,
                                        priority=req.priority)
                   for req in reqs]
        self.frontend.wait_all(tickets)
        outs = {}
        for t in tickets:
            if t.status != "done":
                raise RuntimeError(
                    f"extraction request {t.rid} {t.status}"
                    f"{f' ({t.shed_reason})' if t.shed_reason else ''}: "
                    f"{t.req.error or 'no result'}")
            self.stats.generated_tokens += len(t.req.out)
            outs[t.rid] = lm_data.decode(t.req.out)
        self.stats.batches += 1
        self.stats.max_batch = max(self.stats.max_batch, len(reqs))
        return outs

    def _run_round(self, reqs: list) -> dict:
        """Submit N requests, drain with one continuous-batching run per
        admission window (the engine's queue_depth, when set, bounds how
        many requests may be queued at once). With a frontend attached the
        window is its admission queue instead."""
        outs = {}
        es = self.engine.stats
        tracer = as_tracer(getattr(self.engine, "tracer", None))
        hits0, saved0 = es["prefix_hits"], es["prefix_saved_tokens"]
        spec0 = (es["draft_tokens"], es["accepted_tokens"],
                 es["decode_steps_saved"])
        with tracer.span("extract.round", kind="extract", reqs=len(reqs),
                         frontend=self.frontend is not None):
            if self.frontend is not None:
                outs = self._run_round_frontend(reqs)
                self._note_round_deltas(es, hits0, saved0, spec0)
                return outs
            window = self.engine.queue_depth or len(reqs)
            for i in range(0, len(reqs), max(window, 1)):
                chunk = reqs[i:i + max(window, 1)]
                self.engine.submit_many(chunk)
                done = self.engine.run()
                self.stats.batches += 1
                self.stats.max_batch = max(self.stats.max_batch, len(chunk))
                for req in chunk:
                    if req.rid not in done:            # retry cap exceeded
                        failed = self.engine.failed.get(req.rid)
                        raise RuntimeError(
                            f"extraction request {req.rid} failed: "
                            f"{failed.error if failed else 'not in finished set'}")
                    out = done[req.rid].out
                    self.stats.generated_tokens += len(out)
                    outs[req.rid] = lm_data.decode(out)
            self._note_round_deltas(es, hits0, saved0, spec0)
            return outs

    def _note_round_deltas(self, es, hits0, saved0, spec0):
        self.stats.prefix_hits += es["prefix_hits"] - hits0
        self.stats.saved_prefill_tokens += es["prefix_saved_tokens"] - saved0
        self.stats.draft_tokens += es["draft_tokens"] - spec0[0]
        self.stats.accepted_tokens += es["accepted_tokens"] - spec0[1]
        self.stats.decode_steps_saved += es["decode_steps_saved"] - spec0[2]

    def _generate(self, prefix_text: str, tail_text: str) -> str:
        req = self._make_request(prefix_text, tail_text)
        return self._run_round([req])[req.rid]

    # ------------------------------------------------------------ parsing --

    def _spec(self, doc_id, attr):
        doc = self.corpus.docs[doc_id]
        spec = self.corpus.spec(doc.domain, attr)
        if spec is None:
            for attrs in self.corpus.attr_specs.values():
                if attr in attrs:
                    return attrs[attr]
        return spec

    def _parse(self, doc_id, attr: str, answer: str, context: str):
        spec = self._spec(doc_id, attr)
        value = spec.parse(answer) if spec else None
        if value is None and self.oracle_fallback and spec is not None:
            value = spec.parse(context)         # DESIGN.md §8.1 split
        return value

    # ----------------------------------------------------------- protocol --

    def extract(self, doc_id, attr: str, segments: list):
        return self.extract_batch([(doc_id, attr, segments)])[0]

    def extract_batch(self, items: list, owners: list = None):
        """items = [(doc_id, attr, segments)] -> [(value, input_tokens)].
        One continuous-batching round for the whole batch. `owners`
        (optional, parallel to items) carries each item's owning child
        ledger; its tenant/priority ride on the request for admission
        control."""
        results: list = [None] * len(items)
        reqs, meta = [], []
        for i, (doc_id, attr, segments) in enumerate(items):
            text = " ".join(segments)
            if not text:
                results[i] = (None, 0)
                continue
            req = self._make_request(self._prompt_prefix(doc_id, attr),
                                     f"{text} Answer:",
                                     owner=owners[i] if owners else None,
                                     content_docs=(doc_id,))
            reqs.append(req)
            meta.append((i, doc_id, attr, text, count_tokens(text), req.rid))
        if reqs:
            outs = self._run_round(reqs)
            for i, doc_id, attr, text, tokens, rid in meta:
                results[i] = (self._parse(doc_id, attr, outs[rid], text), tokens)
        return results

    def escalate_batch(self, items: list, owners: list = None):
        """Full-document escalation rounds (session `_resolve_escalations`
        dispatches here). Default layout delegates to `extract_batch`
        (template-first, prefix entries mutation-immune); with
        `doc_prefix_escalation` on, prompts go document-first so the N
        attrs escalated on one document share its prefill KV — those
        entries are doc-tagged and fall to `invalidate_docs` when the
        document mutates."""
        if not self.doc_prefix_escalation:
            return self.extract_batch(items, owners)
        results: list = [None] * len(items)
        reqs, meta = [], []
        for i, (doc_id, attr, segments) in enumerate(items):
            text = " ".join(segments)
            if not text:
                results[i] = (None, 0)
                continue
            doc = self.corpus.docs[doc_id]
            table = doc.table
            desc = self.corpus.attr_description(table, attr)
            req = self._make_request(
                f"Document evidence: {text} ",
                f"Task: report the value of one attribute. "
                f"Attribute: {attr} ({desc}). Answer:",
                owner=owners[i] if owners else None,
                content_docs=(doc_id,), content_in_prefix=True)
            reqs.append(req)
            meta.append((i, doc_id, attr, text, count_tokens(text), req.rid))
        if reqs:
            outs = self._run_round(reqs)
            for i, doc_id, attr, text, tokens, rid in meta:
                results[i] = (self._parse(doc_id, attr, outs[rid], text), tokens)
        return results

    def _full_doc_values(self, doc_id, attrs: list):
        doc = self.corpus.docs[doc_id]
        tokens = doc.tokens or count_tokens(doc.text)
        values, segs = {}, {}
        for attr in attrs:
            spec = self.corpus.spec(doc.domain, attr)
            v = spec.parse(doc.text) if spec else None
            values[attr] = v
            if v is not None and attr in doc.spans:
                segs[attr] = [doc.spans[attr]]
        return values, segs, tokens

    def extract_full_doc(self, doc_id, attrs: list):
        return self.extract_full_doc_batch([(doc_id, attrs)])[0]

    def extract_full_doc_batch(self, items: list, owners: list = None):
        """Sampling phase, batched: one real engine round represents the
        full-document analysis prompts of the whole chunk (shared attrs
        template first, document text last — same prefix-reuse shape)."""
        results, reqs = [], []
        for i, (doc_id, attrs) in enumerate(items):
            results.append(self._full_doc_values(doc_id, attrs))
            doc = self.corpus.docs[doc_id]
            reqs.append(self._make_request(
                f"Task: extract {', '.join(attrs)}. Document: ",
                doc.text[:800], owner=owners[i] if owners else None,
                content_docs=(doc_id,)))
        if reqs:
            self._run_round(reqs)
        return results
