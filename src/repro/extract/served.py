"""ServedExtractor: QUEST's extraction operator driven by the *real* JAX
serving engine.

The retrieved segments become a real prompt; prefill/decode run through
`repro.serving.ServingEngine` (continuous batching, KV caches, the whole
substrate), and the ledger charges the engine's true token counts. Since no
pretrained checkpoint ships in this container, answer *parsing* falls back
to the corpus pattern oracle when the model's decoded text doesn't parse —
cost/latency are real, accuracy is oracle-backed; with a trained checkpoint
(`examples/train_extractor.py`) the decoded text itself is used. This split
is documented in DESIGN.md §8.1.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.data import lm_data
from repro.data.tokens import count_tokens
from repro.serving.engine import Request, ServingEngine

MAX_PROMPT_TOKENS = 220


@dataclass
class ServedStats:
    requests: int = 0
    prompt_tokens: int = 0
    generated_tokens: int = 0


class ServedExtractor:
    def __init__(self, corpus, engine: ServingEngine, *, max_new: int = 12,
                 oracle_fallback: bool = True):
        self.corpus = corpus
        self.engine = engine
        self.max_new = max_new
        self.oracle_fallback = oracle_fallback
        self.stats = ServedStats()
        self._rid = 0

    def _generate(self, prompt_text: str) -> str:
        toks = lm_data.encode(prompt_text)[: 4 * MAX_PROMPT_TOKENS]
        self._rid += 1
        req = Request(rid=self._rid, prompt=toks or [lm_data.BOS],
                      max_new=self.max_new, eos_id=lm_data.EOS)
        self.engine.submit(req)
        done = self.engine.run()
        out = done[self._rid].out
        self.stats.requests += 1
        self.stats.prompt_tokens += len(toks)
        self.stats.generated_tokens += len(out)
        return lm_data.decode(out)

    def _spec(self, doc_id, attr):
        doc = self.corpus.docs[doc_id]
        spec = self.corpus.spec(doc.domain, attr)
        if spec is None:
            for attrs in self.corpus.attr_specs.values():
                if attr in attrs:
                    return attrs[attr]
        return spec

    def extract(self, doc_id, attr: str, segments: list):
        text = " ".join(segments)
        tokens = count_tokens(text)
        if not text:
            return None, 0
        answer = self._generate(f"Extract {attr}. Context: {text} Answer:")
        spec = self._spec(doc_id, attr)
        value = spec.parse(answer) if spec else None
        if value is None and self.oracle_fallback and spec is not None:
            value = spec.parse(text)
        return value, tokens

    def extract_full_doc(self, doc_id, attrs: list):
        doc = self.corpus.docs[doc_id]
        tokens = doc.tokens or count_tokens(doc.text)
        values, segs = {}, {}
        for attr in attrs:
            spec = self.corpus.spec(doc.domain, attr)
            v = spec.parse(doc.text) if spec else None
            values[attr] = v
            if v is not None and attr in doc.spans:
                segs[attr] = [doc.spans[attr]]
        # one real engine call represents the full-document analysis prompt
        self._generate(f"Extract {', '.join(attrs)}. Document: {doc.text[:800]}")
        return values, segs, tokens
