from .oracle import OracleExtractor

__all__ = ["OracleExtractor"]
