from .cascade import CascadeExtractor, CascadeServedStats
from .oracle import OracleExtractor
from .served import ServedExtractor, ServedStats

__all__ = ["OracleExtractor", "ServedExtractor", "ServedStats",
           "CascadeExtractor", "CascadeServedStats"]
