"""Exact invalidation cascade (DESIGN.md §17): one corpus mutation fans out
to every caching layer that might hold state derived from the mutated
document, and *only* that state.

Layers touched, in order:

  * session attr-value cache + escalation memo — entries keyed
    `(doc_id, attr)` for the mutated doc drop (`Session.drop_doc_state`);
    every other document's cached values survive (they are byte-identical
    to fresh extraction, so keeping them is row-invisible). Under a
    cascade extractor (DESIGN.md §18) the same call drops the doc's
    memoized difficulty estimates and tier-escalation memo entries —
    stale routing evidence; the doc gets a fresh shot at the small tier.
  * sampling investments — under the default `sample_policy="exact"`,
    *every* table's `TableSample` drops on any mutation (rank-stratified
    sampling depends on the candidate distance ranking, which any
    ingest/update/delete can reshuffle), together with the retriever's
    derived per-table thresholds/evidence (`reset_table_state`) — the next
    query re-samples exactly like a fresh session, which is what makes
    interleaved runs byte-match the rebuilt oracle. `"sampled_only"`
    trades that guarantee for cheapness: only samples that actually
    contain the mutated doc drop, and the retriever merely absorbs the
    doc's evidence churn (`absorb_doc_churn`).
  * served prefix caches — entries whose prompt embeds the mutated
    document's text release (`PrefixCache.invalidate_docs`), returning
    their pages to the engine's PageAllocator; template-only entries are
    untagged and survive.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.executor import TableSample


@dataclass
class CascadeStats:
    mutations: int = 0
    cache_entries_dropped: int = 0
    escalations_dropped: int = 0
    samples_dropped: int = 0
    samples_retained: int = 0
    evidence_dropped: int = 0
    prefix_entries_dropped: int = 0
    # model cascade (DESIGN.md §18): a mutated doc's memoized difficulty
    # scores and tier-escalation memo entries are stale routing evidence
    difficulty_dropped: int = 0
    tier_memo_dropped: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


class InvalidationCascade:
    """Subscribes to a LiveCorpus and routes each mutation through the
    session's caching layers. `sample_policy`: "exact" (parity-grade, the
    default) or "sampled_only" (drop only directly-stale samples)."""

    def __init__(self, live_corpus, session, *, sample_policy: str = "exact",
                 prefix_caches=()):
        if sample_policy not in ("exact", "sampled_only"):
            raise ValueError(f"unknown sample_policy {sample_policy!r}")
        self.live = live_corpus
        self.session = session
        self.sample_policy = sample_policy
        self.prefix_caches = list(prefix_caches)
        self.stats = CascadeStats()
        live_corpus.subscribe(self.on_mutation)

    def register_prefix_cache(self, prefix_cache) -> None:
        if prefix_cache is not None and prefix_cache not in self.prefix_caches:
            self.prefix_caches.append(prefix_cache)

    # ------------------------------------------------------------ cascade --

    def _tables_with_state(self) -> set:
        ret = self.session.retriever
        tables = set(self.session._samples)
        tables.update(t for t, _a in getattr(ret, "_attr_state", {}))
        tables.update(getattr(ret, "_tau", {}))
        return tables

    def on_mutation(self, record, old_doc, new_doc) -> None:
        s = self.stats
        s.mutations += 1
        doc_id = record.doc_id
        with self.session.tracer.span("live.invalidate", kind="live",
                                      op=record.op, doc=str(doc_id)):
            self._cascade(doc_id)

    def _cascade(self, doc_id) -> None:
        s = self.stats
        dropped = self.session.drop_doc_state(doc_id)
        s.cache_entries_dropped += dropped["cache_entries"]
        s.escalations_dropped += dropped["escalations"]
        s.difficulty_dropped += dropped.get("difficulty_estimates", 0)
        s.tier_memo_dropped += dropped.get("tier_memo", 0)
        ret = self.session.retriever
        for table in sorted(self._tables_with_state()):
            if self.sample_policy == "exact":
                stale = True
            else:
                sample = self.session._samples.get(table)
                stale = (isinstance(sample, TableSample)
                         and doc_id in sample.sampled)
            if stale:
                if self.session.invalidate_table_sample(table):
                    s.samples_dropped += 1
                if hasattr(ret, "reset_table_state"):
                    ret.reset_table_state(table)
            else:
                s.samples_retained += 1
        if self.sample_policy != "exact" and hasattr(ret, "absorb_doc_churn"):
            s.evidence_dropped += ret.absorb_doc_churn(doc_id)
        for pc in self.prefix_caches:
            s.prefix_entries_dropped += pc.invalidate_docs([doc_id])
