"""LiveCorpus: first-class ingest/update/delete over a `data.Corpus`
(DESIGN.md §17).

Mutations are applied *in place* on the wrapped corpus — every component
holding a reference (retriever, extractor, session) observes the new state
the moment a mutation lands — and every mutation appends a `MutationRecord`
to the versioned log, bumps the document's `(version, sha)` manifest entry,
and notifies subscribed listeners in subscription order. The listener
protocol is what the incremental index (`live.index.LiveRetriever`) and the
invalidation cascade (`live.invalidate.InvalidationCascade`) hang off.

Ground truth stays consistent under edits: unless the caller passes explicit
`truth=`/`spans=`, `update()` re-derives both from the new text via the
corpus attr specs (pattern parse + carrier-sentence search) — exactly what a
generator would have planted — so a rebuilt-from-scratch corpus at any
mutation point is byte-equivalent to the live one (the parity oracle).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.data.corpus import Corpus, Document
from repro.data.tokens import count_tokens, split_sentences

from .log import MutationLog, MutationRecord, sha_text


def _utf8_len(s: str) -> int:
    return len(s.encode("utf-8"))


def edit_span_bytes(old: str, new: str) -> int:
    """Size of the localized edit between two texts: strip the common
    prefix/suffix, count the differing middle of the *new* text (an edit
    that only deletes still counts 0 new bytes but bumps mutations)."""
    lo = min(len(old), len(new))
    i = 0
    while i < lo and old[i] == new[i]:
        i += 1
    j = 0
    while j < lo - i and old[len(old) - 1 - j] == new[len(new) - 1 - j]:
        j += 1
    return _utf8_len(new[i:len(new) - j])


def render_edit(corpus, doc_id, attr: str, new_value) -> str:
    """Edited full text of `doc_id` with `attr`'s value replaced by
    `new_value` in its carrier sentence — the canonical localized edit the
    tests and benchmark drive `update()` with."""
    doc = corpus.docs[doc_id]
    spec = corpus.spec(doc.domain, attr)
    old_sent = doc.spans.get(attr)
    if spec is None or old_sent is None:
        raise KeyError(f"{doc_id} has no editable span for {attr!r}")
    m = re.search(spec.pattern, old_sent)
    if m is None:
        raise ValueError(f"span for {attr!r} no longer matches its pattern")
    new_sent = old_sent[:m.start(1)] + str(new_value) + old_sent[m.end(1):]
    return doc.text.replace(old_sent, new_sent, 1)


@dataclass
class LiveCorpusStats:
    mutations: int = 0
    ingests: int = 0
    updates: int = 0
    deletes: int = 0
    edited_bytes: int = 0      # localized-diff bytes across updates
    ingested_bytes: int = 0
    deleted_bytes: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


class LiveCorpus:
    """Mutable view over a `Corpus`. All reads delegate to the wrapped
    corpus, so a LiveCorpus can stand in anywhere a Corpus is expected."""

    def __init__(self, corpus: Corpus):
        self.corpus = corpus
        self.log = MutationLog()
        self.stats = LiveCorpusStats()
        self._listeners: list = []
        # seed manifest: version 0 entries for the initial snapshot, so
        # replay digests cover the starting state too
        for doc_id, doc in corpus.docs.items():
            doc.sha = doc.sha or sha_text(doc.text)
            self.log.manifest[doc_id] = (doc.version, doc.sha)

    # ------------------------------------------------------- corpus facade --

    @property
    def name(self):
        return self.corpus.name

    @property
    def docs(self):
        return self.corpus.docs

    @property
    def tables(self):
        return self.corpus.tables

    @property
    def attr_specs(self):
        return self.corpus.attr_specs

    @property
    def domain_of_table(self):
        return self.corpus.domain_of_table

    def attr_description(self, table: str, attr: str) -> str:
        return self.corpus.attr_description(table, attr)

    def spec(self, domain: str, attr: str):
        return self.corpus.spec(domain, attr)

    def truth_rows(self, table: str) -> dict:
        return self.corpus.truth_rows(table)

    @property
    def seq(self) -> int:
        """Current mutation-log sequence (0 = untouched seed snapshot)."""
        return self.log.seq

    def subscribe(self, listener) -> None:
        """listener(record, old_doc, new_doc) — called after each mutation
        has been applied, in subscription order (the incremental index
        subscribes before the invalidation cascade)."""
        self._listeners.append(listener)

    def snapshot(self) -> Corpus:
        """Deep-enough copy of the current state for the rebuild-from-
        scratch parity oracle: later live mutations never leak into it."""
        docs = {d: Document(doc.doc_id, doc.domain, doc.text,
                            dict(doc.truth), dict(doc.spans), doc.tokens,
                            version=doc.version, sha=doc.sha)
                for d, doc in self.corpus.docs.items()}
        return Corpus(self.corpus.name, docs,
                      {t: list(ids) for t, ids in self.corpus.tables.items()},
                      self.corpus.attr_specs, self.corpus.domain_of_table)

    # ----------------------------------------------------------- mutations --

    def _domain_specs(self, domain: str) -> dict:
        out: dict = {}
        for t, d in self.corpus.domain_of_table.items():
            if d == domain:
                out.update(self.corpus.attr_specs.get(t, {}))
        return out

    def _derive_truth_spans(self, domain: str, text: str):
        """Re-derive (truth, spans) from text the way the generators plant
        them: value = pattern parse over the full text, span = the first
        sentence the pattern matches within."""
        truth, spans = {}, {}
        sents = split_sentences(text)
        for attr, spec in self._domain_specs(domain).items():
            truth[attr] = spec.parse(text)
            if truth[attr] is None:
                continue
            for s in sents:
                if re.search(spec.pattern, s):
                    spans[attr] = s
                    break
        return truth, spans

    def _notify(self, rec: MutationRecord, old_doc, new_doc) -> None:
        for fn in self._listeners:
            fn(rec, old_doc, new_doc)

    def ingest(self, doc_or_id, text: str = None, domain: str = None, *,
               truth: dict = None, spans: dict = None) -> MutationRecord:
        """Add a new document: `ingest(Document)` or
        `ingest(doc_id, text, domain)`. The new doc joins every table's
        candidate pool (corpus convention: table membership is discovered
        by the index, never given)."""
        if isinstance(doc_or_id, Document):
            doc = doc_or_id
            doc_id, text, domain = doc.doc_id, doc.text, doc.domain
            truth = truth if truth is not None else (doc.truth or None)
            spans = spans if spans is not None else (doc.spans or None)
        else:
            doc_id = doc_or_id
        if doc_id in self.corpus.docs:
            raise KeyError(f"{doc_id!r} already exists (use update)")
        if truth is None or spans is None:
            d_truth, d_spans = self._derive_truth_spans(domain, text)
            truth = d_truth if truth is None else truth
            spans = d_spans if spans is None else spans
        doc = Document(doc_id, domain, text, dict(truth), dict(spans),
                       count_tokens(text), version=1, sha=sha_text(text))
        self.corpus.docs[doc_id] = doc
        for pool in self.corpus.tables.values():
            if doc_id not in pool:
                pool.append(doc_id)
        self.stats.mutations += 1
        self.stats.ingests += 1
        self.stats.ingested_bytes += _utf8_len(text)
        self.stats.edited_bytes += _utf8_len(text)
        rec = self.log.append("ingest", doc_id, 1, doc.sha,
                              n_bytes=_utf8_len(text), domain=domain,
                              text=text)
        self._notify(rec, None, doc)
        return rec

    def update(self, doc_id, text: str, *, truth: dict = None,
               spans: dict = None) -> MutationRecord:
        """Replace a document's text; version bumps, sha/tokens/truth/spans
        follow the new content."""
        old = self.corpus.docs.get(doc_id)
        if old is None:
            raise KeyError(f"{doc_id!r} not in corpus (use ingest)")
        if truth is None or spans is None:
            d_truth, d_spans = self._derive_truth_spans(old.domain, text)
            truth = d_truth if truth is None else truth
            spans = d_spans if spans is None else spans
        old_doc = Document(old.doc_id, old.domain, old.text, dict(old.truth),
                           dict(old.spans), old.tokens, version=old.version,
                           sha=old.sha)
        edit = edit_span_bytes(old.text, text)
        old.text = text
        old.truth = dict(truth)
        old.spans = dict(spans)
        old.tokens = count_tokens(text)
        old.version += 1
        old.sha = sha_text(text)
        self.stats.mutations += 1
        self.stats.updates += 1
        self.stats.edited_bytes += edit
        rec = self.log.append("update", doc_id, old.version, old.sha,
                              n_bytes=_utf8_len(text), domain=old.domain,
                              text=text)
        self._notify(rec, old_doc, old)
        return rec

    def delete(self, doc_id) -> MutationRecord:
        """Remove a document from the corpus and every candidate pool."""
        old = self.corpus.docs.pop(doc_id, None)
        if old is None:
            raise KeyError(f"{doc_id!r} not in corpus")
        for pool in self.corpus.tables.values():
            if doc_id in pool:
                pool.remove(doc_id)
        self.stats.mutations += 1
        self.stats.deletes += 1
        self.stats.deleted_bytes += _utf8_len(old.text)
        rec = self.log.append("delete", doc_id, old.version, "",
                              n_bytes=0, domain=old.domain)
        self._notify(rec, old, None)
        return rec
