"""LiveSession: corpus mutations interleaved with in-flight queries
(DESIGN.md §17).

Snapshot semantics — a query's rows always reflect exactly one corpus
state, never a torn mix:

  * `ingest/update/delete` on the session queue the mutation; it applies
    immediately when it can, otherwise at the top of the next `_step`.
  * a mutation may not apply while any in-flight query has already
    emitted rows — those queries keep running to completion on the
    pre-mutation snapshot (the mutation defers until they drain).
  * in-flight queries that have *not* emitted rows restart: their
    coroutine is closed, sampling reservations roll back, and a fresh
    `QueryRun` is built with the same seed on the same handle/ledger —
    so they execute entirely on the post-mutation snapshot (restart cost
    is honestly charged to the same query ledger). Restarts happen
    *before* the mutation lands, so teardown never observes a half-
    mutated corpus.
  * the `InvalidationCascade` fires as part of applying the mutation
    (listener order: incremental index first, cascade second), so by the
    time restarted queries resume, every stale cache/sample/prefix entry
    is gone.
"""
from __future__ import annotations

from repro.core.session import Session

from .invalidate import InvalidationCascade


class LiveSession(Session):
    """Session over a LiveCorpus-backed retriever/extractor. Mutations go
    through the session (`session.update(...)` etc.) so they serialize
    correctly against in-flight queries; each returns its MutationRecord,
    or None when deferred behind row-emitting queries (it applies — in
    order — once they drain)."""

    def __init__(self, live_corpus, retriever, extractor, *,
                 sample_policy: str = "exact", **kwargs):
        super().__init__(retriever, extractor, **kwargs)
        self.live = live_corpus
        prefix_caches = []
        engine = getattr(extractor, "engine", None)
        pc = getattr(engine, "prefix_cache", None) if engine is not None else None
        if pc is not None:
            prefix_caches.append(pc)
        self.cascade = InvalidationCascade(live_corpus, self,
                                          sample_policy=sample_policy,
                                          prefix_caches=prefix_caches)
        self._pending_mutations: list = []
        self.live_stats = {"mutations_applied": 0, "mutations_deferred": 0,
                           "query_restarts": 0}

    # ---------------------------------------------------------- mutations --

    def ingest(self, *args, **kwargs):
        return self._enqueue("ingest", args, kwargs)

    def update(self, *args, **kwargs):
        return self._enqueue("update", args, kwargs)

    def delete(self, *args, **kwargs):
        return self._enqueue("delete", args, kwargs)

    def _enqueue(self, op, args, kwargs):
        self._pending_mutations.append((op, args, kwargs))
        recs = self._apply_pending()
        return recs[-1] if recs else None

    def _apply_pending(self):
        """Apply queued mutations if no in-flight query has emitted rows;
        restart the (row-less) in-flight queries first so none observes a
        half-mutated corpus. Returns the applied MutationRecords, or None
        when deferred."""
        if not self._pending_mutations:
            return None
        if any(h._rows for h in self._active):
            self.live_stats["mutations_deferred"] += 1
            self.tracer.instant("live.mutation_deferred", kind="live",
                                pending=len(self._pending_mutations))
            return None
        for h in self._active:
            h.gen.close()
            self._release(h)
            h.acquired.clear()
            h._make_run()
            self.live_stats["query_restarts"] += 1
            self.tracer.instant("live.query_restart", kind="live", qid=h.qid)
        recs = []
        pending, self._pending_mutations = self._pending_mutations, []
        for op, args, kwargs in pending:
            with self.tracer.span("live.mutation", kind="live", op=op):
                recs.append(getattr(self.live, op)(*args, **kwargs))
            self.live_stats["mutations_applied"] += 1
        return recs

    # -------------------------------------------------------------- hooks --

    def _step(self) -> bool:
        self._apply_pending()
        return super()._step()

    def _publish_sample(self, h, sample) -> None:
        # stamp the sampling investment with the corpus version it was
        # taken at: exact invalidation checks staleness by seq, and the
        # bench asserts no row ever came from a stale-stamped sample
        sample.version = self.live.seq
        super()._publish_sample(h, sample)
