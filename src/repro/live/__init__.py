"""Live corpus subsystem (DESIGN.md §17): streaming ingestion, incremental
indexing, and exact invalidation over the static QUEST pipeline."""
from .corpus import (LiveCorpus, LiveCorpusStats, edit_span_bytes,
                     render_edit)
from .index import CachedEmbedder, LiveRetriever, clone_embedder
from .invalidate import CascadeStats, InvalidationCascade
from .log import MutationLog, MutationRecord, sha_text
from .session import LiveSession

__all__ = [
    "LiveCorpus", "LiveCorpusStats", "edit_span_bytes", "render_edit",
    "CachedEmbedder", "LiveRetriever", "clone_embedder",
    "CascadeStats", "InvalidationCascade",
    "MutationLog", "MutationRecord", "sha_text",
    "LiveSession",
]
