"""Incremental index maintenance over a LiveCorpus (DESIGN.md §17).

`LiveRetriever` is a `TwoLevelRetriever` that subscribes to a LiveCorpus
and absorbs each mutation in place:

  * stability-driven re-segmentation — the mutated document re-segments,
    but embeddings go through a `CachedEmbedder` keyed by content hash, so
    only the sentences/segments whose *text actually changed* hit the
    embedder; everything untouched reuses its cached vector. The
    `reembedded_bytes / edited_bytes` ratio is the subsystem's acceptance
    metric (bench_live_corpus).
  * index maintenance — the doc-level index drops the old summary row and
    adds the new one (tombstones + bounded compaction in ExactIndex,
    per-list re-clustering in IVFIndex — never a global rebuild); the
    per-doc segment index rebuilds for the one mutated document only.
  * idf freeze — the embedder fits once, at construction, over the seed
    corpus sentences, and never refits on mutation. `rebuild_reference()`
    hands out a static `TwoLevelRetriever` over the current snapshot with
    a *clone* of that frozen embedder (`refit_idf=False`), which makes the
    rebuilt-from-scratch index byte-comparable to the live one — the
    parity oracle every live test and benchmark checks against.
"""
from __future__ import annotations

import numpy as np

from repro.index.embedder import HashedEmbedder
from repro.index.retriever import TwoLevelRetriever
from repro.index.segmenter import key_sentences, segment_document
from repro.data.tokens import split_sentences

from .log import sha_text


class CachedEmbedder:
    """Content-hash memo in front of an embedder. `segment_document` embeds
    per-sentence and `_build` embeds per-segment, so after a localized edit
    every unchanged sentence/segment resolves from the memo — the embedder
    only sees the bytes the edit actually touched."""

    def __init__(self, base: HashedEmbedder | None = None):
        self.base = base or HashedEmbedder()
        self._memo: dict = {}          # sha(text) -> vector
        self.reembedded_bytes = 0
        self.reused_bytes = 0
        self.reembedded_texts = 0
        self.reused_texts = 0

    @property
    def dim(self) -> int:
        return self.base.dim

    def reset_counters(self) -> None:
        self.reembedded_bytes = 0
        self.reused_bytes = 0
        self.reembedded_texts = 0
        self.reused_texts = 0

    def fit(self, texts):
        self._memo.clear()             # idf changed: every vector is stale
        self.base.fit(texts)
        return self

    def embed(self, texts) -> np.ndarray:
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        keys = [sha_text(t) for t in texts]
        miss = [(i, t) for i, (k, t) in enumerate(zip(keys, texts))
                if k not in self._memo]
        if miss:
            fresh = self.base.embed([t for _i, t in miss])
            for (i, t), v in zip(miss, fresh):
                self._memo[keys[i]] = v
                self.reembedded_bytes += len(t.encode("utf-8"))
                self.reembedded_texts += 1
        hit = len(texts) - len(miss)
        if hit:
            missed = {i for i, _t in miss}
            for i, t in enumerate(texts):
                if i not in missed:
                    self.reused_bytes += len(t.encode("utf-8"))
            self.reused_texts += hit
        return np.stack([self._memo[k] for k in keys])

    def embed_one(self, text: str) -> np.ndarray:
        return self.embed([text])[0]


def clone_embedder(src) -> HashedEmbedder:
    """Fresh HashedEmbedder sharing `src`'s projection and a *copy* of its
    idf — embeds byte-identically to `src` without aliasing mutable
    state (the clone can be refit without touching the original)."""
    base = src.base if isinstance(src, CachedEmbedder) else src
    clone = HashedEmbedder(dim=base.dim)
    clone._proj = base._proj           # immutable device array: share
    clone._idf = base._idf.copy()
    return clone


class LiveRetriever(TwoLevelRetriever):
    """TwoLevelRetriever wired to a LiveCorpus. Construction fits the
    embedder once over the seed corpus *sentences* (not post-segmentation
    segments: segmentation itself consumes embeddings, so the fit must
    precede it for re-segmentation under the frozen idf to reproduce the
    seed segmentation of unchanged text), then subscribes `apply` so every
    mutation maintains the indexes incrementally."""

    def __init__(self, live_corpus, embedder: HashedEmbedder | None = None,
                 **kwargs):
        self.live = live_corpus
        cached = CachedEmbedder(embedder)
        sents = [s for doc in live_corpus.docs.values()
                 for s in split_sentences(doc.text)]
        cached.fit(sents)
        kwargs.pop("refit_idf", None)
        super().__init__(live_corpus, cached, refit_idf=False, **kwargs)
        live_corpus.subscribe(self.apply)

    # ------------------------------------------------- incremental apply --

    def apply(self, record, old_doc, new_doc) -> None:
        """Absorb one mutation: delete drops the doc's rows, ingest/update
        re-segment the one document and swap its index rows in place."""
        doc_id = record.doc_id
        if record.op == "delete":
            self.doc_segments.pop(doc_id, None)
            self.seg_index.pop(doc_id, None)
            if doc_id in self._doc_emb:
                self.doc_index.remove([doc_id])
                del self._doc_emb[doc_id]
        else:
            segs = segment_document(doc_id, new_doc.text, self.embedder)
            self.doc_segments[doc_id] = segs
            embs = self.embedder.embed([s.text for s in segs])
            self.seg_index[doc_id] = self._make_index(
                embs, list(range(len(segs))))
            e = self.embedder.embed_one(key_sentences(new_doc.text))
            if doc_id in self._doc_emb:
                self.doc_index.remove([doc_id])
            self.doc_index.add(e[None], [doc_id])
            self._doc_emb[doc_id] = e
        self._version += 1             # segment cache keys include version

    # ------------------------------------------------------ parity oracle --

    def rebuild_reference(self, corpus=None) -> TwoLevelRetriever:
        """Static TwoLevelRetriever rebuilt from scratch over the current
        snapshot (or `corpus`), under a clone of the frozen embedder —
        the byte-parity oracle for the incremental indexes."""
        corpus = corpus if corpus is not None else self.live.snapshot()
        return TwoLevelRetriever(
            corpus, clone_embedder(self.embedder), mode=self.mode,
            evidence_k=self.evidence_k, tau_init=self.tau_init,
            gamma_init=self.gamma_init, rag_k=self.rag_k,
            threshold_slack=self.slack,
            per_evidence_radius=self.per_evidence_radius,
            cluster_radius_floor=self.cluster_radius_floor,
            approx_threshold=self.approx_threshold,
            ivf_n_lists=self.ivf_n_lists, ivf_nprobe=self.ivf_nprobe,
            refit_idf=False)
