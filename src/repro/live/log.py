"""Versioned mutation log + content-hash manifest (DESIGN.md §17).

Every live-corpus mutation appends one `MutationRecord` carrying the
document's new `(version, sha)` and the payload needed to replay it, so a
dynamic run is an audit trail: `MutationLog.replay(corpus)` re-applies the
stream against a fresh snapshot and must land on the same manifest digest.
The manifest (`doc_id -> (version, sha)`) is the ground truth every cache
layer stamps against — an entry keyed to a stale `(doc_id, version)` is
invalid by construction, no content comparison needed.

The sha is over document *text* (blake2b-128): unchanged text hashes
identically across mutations, which is exactly the key the incremental
index uses to keep embeddings for untouched segments/sentences.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field
from typing import Optional


def sha_text(text: str) -> str:
    """Content hash of a document/segment/sentence text (blake2b-128)."""
    return hashlib.blake2b(text.encode("utf-8"), digest_size=16).hexdigest()


@dataclass
class MutationRecord:
    seq: int                       # monotone log sequence number (from 1)
    op: str                        # 'ingest' | 'update' | 'delete'
    doc_id: str
    version: int                   # doc version after the op (delete: last)
    sha: str                       # content hash after the op (delete: "")
    n_bytes: int = 0               # len of the new text ("" for delete)
    domain: str = ""               # ingest payload
    text: Optional[str] = None     # ingest/update payload (replayability)
    truth: Optional[dict] = None   # explicit truth override, when given
    spans: Optional[dict] = None   # explicit span override, when given

    def to_json(self) -> str:
        return json.dumps(asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, line: str) -> "MutationRecord":
        return cls(**json.loads(line))


@dataclass
class MutationLog:
    """Append-only record stream + the manifest it induces."""

    records: list = field(default_factory=list)
    manifest: dict = field(default_factory=dict)   # doc_id -> (version, sha)

    @property
    def seq(self) -> int:
        return self.records[-1].seq if self.records else 0

    def __len__(self) -> int:
        return len(self.records)

    def append(self, op: str, doc_id: str, version: int, sha: str, *,
               n_bytes: int = 0, domain: str = "", text: Optional[str] = None,
               truth: Optional[dict] = None,
               spans: Optional[dict] = None) -> MutationRecord:
        rec = MutationRecord(self.seq + 1, op, doc_id, version, sha,
                             n_bytes=n_bytes, domain=domain, text=text,
                             truth=truth, spans=spans)
        self.records.append(rec)
        if op == "delete":
            self.manifest.pop(doc_id, None)
        else:
            self.manifest[doc_id] = (version, sha)
        return rec

    def digest(self) -> str:
        """Chained hash over the record stream — two logs with the same
        digest describe byte-identical mutation histories."""
        h = hashlib.blake2b(digest_size=16)
        for rec in self.records:
            h.update(rec.to_json().encode("utf-8"))
        return h.hexdigest()

    def manifest_digest(self) -> str:
        """Hash of the *current* manifest only (order-independent): two
        corpora with equal manifest digests hold identical doc contents."""
        h = hashlib.blake2b(digest_size=16)
        for doc_id in sorted(self.manifest):
            v, s = self.manifest[doc_id]
            h.update(f"{doc_id}:{v}:{s}\n".encode("utf-8"))
        return h.hexdigest()

    # ----------------------------------------------------- serialization --

    def to_jsonl(self) -> str:
        return "\n".join(rec.to_json() for rec in self.records)

    @classmethod
    def from_jsonl(cls, blob: str) -> "MutationLog":
        log = cls()
        for line in blob.splitlines():
            if not line.strip():
                continue
            rec = MutationRecord.from_json(line)
            log.records.append(rec)
            if rec.op == "delete":
                log.manifest.pop(rec.doc_id, None)
            else:
                log.manifest[rec.doc_id] = (rec.version, rec.sha)
        return log

    def replay(self, live_corpus) -> None:
        """Re-apply the recorded stream against `live_corpus` (a fresh
        `LiveCorpus` over the same seed snapshot). The caller can then
        compare `manifest_digest()` — audit-log replayability."""
        for rec in self.records:
            if rec.op == "ingest":
                live_corpus.ingest(rec.doc_id, rec.text, rec.domain,
                                   truth=rec.truth, spans=rec.spans)
            elif rec.op == "update":
                live_corpus.update(rec.doc_id, rec.text,
                                   truth=rec.truth, spans=rec.spans)
            else:
                live_corpus.delete(rec.doc_id)
