"""Shared-prefix KV cache: longest-prefix-match store over prompt tokens
(DESIGN.md §10). Invariant: a hit changes prefill work, never decoded
output — results are byte-identical with the cache on or off.

QUEST plans issue hundreds of extraction calls whose prompts share a long
template prefix (instruction + attribute description + evidence header) and
differ only in the per-document tail (`extract/served.py` orders prompts
that way on purpose). Each stored entry maps a token prefix to the B=1
decode-cache snapshot obtained by prefilling *exactly* that prefix
(`models.cache_ops.prefix_snapshot`): attention KV sliced to the prefix,
SSM/conv state taken at the prefix boundary — so a hit is state-correct for
every model family, not just attention.

Entries live at explicit boundaries (`Request.shared_len`), so the store is
a radix-style trie whose every path is a single compressed edge:
`match(prompt)` returns the deepest stored node whose token path is a
*proper* prefix of the prompt (proper, because at least one suffix token
must be prefilled to produce the first-output logits). Lookup scans the
(small, LRU-bounded) entry table and compares token runs — O(entries ×
prefix) integer comparisons, cheap next to a single prefill step.

Eviction is LRU over both knobs: `max_entries` and, when set, `max_bytes`
of snapshot storage (`cache_ops.cache_nbytes`).

One instance may be shared by several engine replicas (`serving/
replicas.py`, DESIGN.md §15): entries are immutable after insert, hits only
touch LRU order, and in the paged layout entry pages are ref-counted in the
replicas' *shared* PageAllocator — so an eviction triggered by one replica
can never free a page another replica's live slot still references, and a
prefix prefilled by one replica splices O(1) into every other. Sharing is
single-threaded by construction: `ReplicaGroup` interleaves replica steps
on one host thread (the async tier of ROADMAP item 2 adds locking, not new
semantics).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.models.cache_ops import cache_nbytes


@dataclass
class PrefixEntry:
    tokens: tuple                 # the prefix token path
    cache: dict                   # trimmed B=1 snapshot (see cache_ops); in
    #                               the paged layout, the pure-state part only
    nbytes: int
    hits: int = 0
    # Paged layout (DESIGN.md §12): the prefix KV lives in the engine's page
    # pool, referenced rather than copied. `pages` are the completely-filled
    # pages (shared by reference with every slot that hits), `tail_page` the
    # partially-filled boundary page (copy-on-write on hit). `release` drops
    # the entry's page references; the store calls it exactly once when the
    # entry is evicted or cleared.
    pages: tuple = ()
    tail_page: Optional[int] = None
    release: Optional[Callable[[], None]] = None
    # Live-corpus provenance (DESIGN.md §17): doc_ids whose text is embedded
    # in this prefix. A mutation to any of them invalidates the entry via
    # `invalidate_docs`; template-only prefixes carry () and survive.
    doc_ids: tuple = ()

    def _drop(self) -> None:
        if self.release is not None:
            rel, self.release = self.release, None
            rel()


@dataclass
class PrefixCacheStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    saved_tokens: int = 0         # prefill tokens skipped via hits
    invalidated_entries: int = 0  # dropped by live-corpus doc invalidation

    def snapshot(self) -> dict:
        return dict(self.__dict__)


class PrefixCache:
    def __init__(self, *, max_entries: int = 32,
                 max_bytes: Optional[int] = None):
        self.max_entries = max(1, int(max_entries))
        self.max_bytes = max_bytes
        self.stats = PrefixCacheStats()
        self._entries: "OrderedDict[tuple, PrefixEntry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    # ------------------------------------------------------------ lookup --

    def match(self, prompt: list) -> Optional[PrefixEntry]:
        """Deepest entry whose path is a proper prefix of `prompt`."""
        best = None
        n = len(prompt)
        for key, entry in self._entries.items():
            k = len(key)
            if k < n and (best is None or k > len(best.tokens)) \
                    and tuple(prompt[:k]) == key:
                best = entry
        if best is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(best.tokens)       # LRU touch
        best.hits += 1
        self.stats.hits += 1
        self.stats.saved_tokens += len(best.tokens)
        return best

    # ------------------------------------------------------------ insert --

    def insert(self, prefix: list, snapshot: dict, *, pages=(),
               tail_page: Optional[int] = None, nbytes: Optional[int] = None,
               release: Optional[Callable[[], None]] = None,
               doc_ids=()) -> PrefixEntry:
        key = tuple(prefix)
        if key in self._entries:                     # refresh, don't duplicate
            if release is not None:                  # drop the redundant copy
                release()
            self._entries.move_to_end(key)
            return self._entries[key]
        entry = PrefixEntry(
            tokens=key, cache=snapshot,
            nbytes=cache_nbytes(snapshot) if nbytes is None else int(nbytes),
            pages=tuple(pages), tail_page=tail_page, release=release,
            doc_ids=tuple(doc_ids))
        self._entries[key] = entry
        self.stats.inserts += 1
        self._evict()
        return entry

    def invalidate_docs(self, doc_ids) -> int:
        """Drop every entry whose prefix embeds one of `doc_ids` (live-
        corpus mutation, DESIGN.md §17). Page references release through
        the entries' `release` callbacks exactly as on eviction, so paged
        entries return their pages to the allocator. Returns entries
        dropped."""
        targets = set(doc_ids)
        stale = [k for k, e in self._entries.items()
                 if targets.intersection(e.doc_ids)]
        for k in stale:
            self._entries.pop(k)._drop()
        self.stats.invalidated_entries += len(stale)
        return len(stale)

    def _evict(self) -> None:
        while len(self._entries) > self.max_entries or (
                self.max_bytes is not None and self.nbytes > self.max_bytes
                and len(self._entries) > 1):
            _, entry = self._entries.popitem(last=False)
            entry._drop()
            self.stats.evictions += 1

    def pop_lru(self) -> Optional[PrefixEntry]:
        """Force-evict the least-recently-used entry (page-pool pressure);
        returns it (references already released) or None when empty."""
        if not self._entries:
            return None
        _, entry = self._entries.popitem(last=False)
        entry._drop()
        self.stats.evictions += 1
        return entry

    def clear(self) -> None:
        for entry in self._entries.values():
            entry._drop()
        self._entries.clear()
