"""Serving cost model per architecture (DESIGN.md §3 arch-applicability).

QUEST's optimizer prices an extraction by tokens; deploying it on a real
fleet needs tokens -> seconds/Joules per architecture. This module derives
first-order per-token costs from the ModelConfig (prefill FLOPs/token,
decode state bytes/token) and the roofline hardware constants, giving the
QUEST cost model its hardware-aware exchange rate (used by
benchmarks/common.derived_latency_s and reported per arch below).

SSM archs have O(1) decode state instead of a KV cache — exactly the
"cost-model constants change, technique unchanged" note of DESIGN.md.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip


@dataclass(frozen=True)
class ServingCosts:
    arch: str
    prefill_flops_per_token: float
    decode_flops_per_token: float
    kv_bytes_per_token: float        # cache growth per generated/ctx token
    state_bytes: float               # O(1) recurrent state (SSM), per seq
    prefill_tokens_per_s_chip: float
    decode_ms_per_token_chip: float  # memory-bound decode estimate @ ctx

    def extraction_seconds(self, prompt_tokens: int, output_tokens: int,
                           chips: int = 1) -> float:
        t_pre = prompt_tokens / (self.prefill_tokens_per_s_chip * chips)
        t_dec = output_tokens * self.decode_ms_per_token_chip / 1e3 / chips
        return t_pre + t_dec


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // max(cfg.attn_every, 1)
    elif cfg.family == "encdec":
        n_attn = cfg.num_layers
    else:
        n_attn = cfg.num_layers
    if cfg.use_mla:
        return n_attn * (cfg.kv_lora_rank + cfg.qk_rope_dim) * dtype_bytes
    return n_attn * 2 * nkv * hd * dtype_bytes


def recurrent_state_bytes(cfg: ModelConfig) -> float:
    if not cfg.mamba_version:
        return 0.0
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    conv_dim = di + (2 * N if cfg.mamba_version == 2 else 0)
    return cfg.num_layers * (di * N * 4 + (K - 1) * conv_dim * 2)


def serving_costs(cfg: ModelConfig, *, context: int = 4096,
                  mfu: float = 0.4) -> ServingCosts:
    """First-order costs at a given decode context length."""
    n_active = cfg.param_count(active_only=True)
    pre_flops = 2.0 * n_active
    dec_flops = 2.0 * n_active
    kv_tok = kv_bytes_per_token(cfg)
    state = recurrent_state_bytes(cfg)
    # decode: read all weights + the context's cache once per token
    weight_bytes = n_active * 2
    dec_bytes = weight_bytes + kv_tok * context + state
    return ServingCosts(
        arch=cfg.name,
        prefill_flops_per_token=pre_flops,
        decode_flops_per_token=dec_flops,
        kv_bytes_per_token=kv_tok,
        state_bytes=state,
        prefill_tokens_per_s_chip=mfu * PEAK_FLOPS / pre_flops,
        decode_ms_per_token_chip=1e3 * dec_bytes / HBM_BW,
    )


def cost_table(context: int = 4096) -> list[ServingCosts]:
    from repro.configs import ARCH_IDS, get_config
    return [serving_costs(get_config(a), context=context) for a in ARCH_IDS]
