"""Serving cost model per architecture (DESIGN.md §3 arch-applicability).

QUEST's optimizer prices an extraction by tokens; deploying it on a real
fleet needs tokens -> seconds/Joules per architecture. This module derives
first-order per-token costs from the ModelConfig (prefill FLOPs/token,
decode state bytes/token) and the roofline hardware constants, giving the
QUEST cost model its hardware-aware exchange rate (used by
benchmarks/common.derived_latency_s and reported per arch below).

SSM archs have O(1) decode state instead of a KV cache — exactly the
"cost-model constants change, technique unchanged" note of DESIGN.md.

The second half of this module is the *measured* side the async serving
tier needs (DESIGN.md §16): `LatencySeries` (bounded-reservoir percentile
estimates over whatever unit the caller samples in — wall seconds or the
frontend's deterministic pump ticks) and `TenantStats` (per-tenant queue
depth, admission/shed/cancel accounting, pool pages held, speculative
acceptance, and p50/p99 of queueing + completion latency). The frontend
maintains one `TenantStats` per tenant continuously; benchmarks snapshot
them as gateable counters.
"""
from __future__ import annotations

from bisect import insort
from dataclasses import dataclass, field

from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip


class LatencySeries:
    """Streaming latency percentiles over a bounded window.

    Keeps the most recent `window` samples (FIFO) in sorted order, so
    `percentile` is exact over the window — deterministic for the tick-based
    benches, O(log w) insert, bounded memory for long-running frontends."""

    def __init__(self, window: int = 4096):
        self.window = max(1, int(window))
        self._fifo: list = []        # arrival order (for eviction)
        self._sorted: list = []      # value order (for percentiles)
        self.count = 0               # total samples ever observed
        self.total = 0.0

    def add(self, value) -> None:
        self.count += 1
        self.total += value
        self._fifo.append(value)
        insort(self._sorted, value)
        if len(self._fifo) > self.window:
            old = self._fifo.pop(0)
            self._sorted.remove(old)

    def percentile(self, p: float):
        """Exact percentile over the retained window (nearest-rank);
        None with no samples."""
        if not self._sorted:
            return None
        rank = max(0, min(len(self._sorted) - 1,
                          int(round((p / 100.0) * (len(self._sorted) - 1)))))
        return self._sorted[rank]

    @property
    def mean(self):
        return self.total / self.count if self.count else None

    def snapshot(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "p50": self.percentile(50), "p99": self.percentile(99)}


@dataclass
class TenantStats:
    """Continuous per-tenant serving statistics (DESIGN.md §16). Counters
    are maintained by `serving/frontend.py` as requests move through the
    admission state machine; latency series sample in the frontend's time
    unit (pump ticks under the virtual clock, wall seconds otherwise)."""
    tenant: str
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    failed: int = 0
    shed: int = 0                  # backpressure: rejected with typed result
    cancelled: int = 0
    timeouts: int = 0
    queue_depth: int = 0           # currently waiting for admission
    queue_depth_peak: int = 0
    in_flight: int = 0             # admitted, not yet resolved
    pool_pages_held: int = 0       # estimated pages admitted-but-unfinished
    draft_tokens: int = 0          # speculative economy, summed at resolve
    accepted_tokens: int = 0
    queue_wait: LatencySeries = field(default_factory=LatencySeries)
    latency: LatencySeries = field(default_factory=LatencySeries)  # submit->done

    def note_queued(self) -> None:
        self.submitted += 1
        self.queue_depth += 1
        self.queue_depth_peak = max(self.queue_depth_peak, self.queue_depth)

    def acceptance_rate(self):
        return (self.accepted_tokens / self.draft_tokens
                if self.draft_tokens else None)

    def snapshot(self) -> dict:
        out = {k: getattr(self, k) for k in
               ("tenant", "submitted", "admitted", "completed", "failed",
                "shed", "cancelled", "timeouts", "queue_depth",
                "queue_depth_peak", "in_flight", "pool_pages_held",
                "draft_tokens", "accepted_tokens")}
        out["queue_wait"] = self.queue_wait.snapshot()
        out["latency"] = self.latency.snapshot()
        return out


@dataclass(frozen=True)
class ServingCosts:
    arch: str
    prefill_flops_per_token: float
    decode_flops_per_token: float
    kv_bytes_per_token: float        # cache growth per generated/ctx token
    state_bytes: float               # O(1) recurrent state (SSM), per seq
    prefill_tokens_per_s_chip: float
    decode_ms_per_token_chip: float  # memory-bound decode estimate @ ctx

    def extraction_seconds(self, prompt_tokens: int, output_tokens: int,
                           chips: int = 1) -> float:
        t_pre = prompt_tokens / (self.prefill_tokens_per_s_chip * chips)
        t_dec = output_tokens * self.decode_ms_per_token_chip / 1e3 / chips
        return t_pre + t_dec


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // max(cfg.attn_every, 1)
    elif cfg.family == "encdec":
        n_attn = cfg.num_layers
    else:
        n_attn = cfg.num_layers
    if cfg.use_mla:
        return n_attn * (cfg.kv_lora_rank + cfg.qk_rope_dim) * dtype_bytes
    return n_attn * 2 * nkv * hd * dtype_bytes


def recurrent_state_bytes(cfg: ModelConfig) -> float:
    if not cfg.mamba_version:
        return 0.0
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    conv_dim = di + (2 * N if cfg.mamba_version == 2 else 0)
    return cfg.num_layers * (di * N * 4 + (K - 1) * conv_dim * 2)


def serving_costs(cfg: ModelConfig, *, context: int = 4096,
                  mfu: float = 0.4) -> ServingCosts:
    """First-order costs at a given decode context length."""
    n_active = cfg.param_count(active_only=True)
    pre_flops = 2.0 * n_active
    dec_flops = 2.0 * n_active
    kv_tok = kv_bytes_per_token(cfg)
    state = recurrent_state_bytes(cfg)
    # decode: read all weights + the context's cache once per token
    weight_bytes = n_active * 2
    dec_bytes = weight_bytes + kv_tok * context + state
    return ServingCosts(
        arch=cfg.name,
        prefill_flops_per_token=pre_flops,
        decode_flops_per_token=dec_flops,
        kv_bytes_per_token=kv_tok,
        state_bytes=state,
        prefill_tokens_per_s_chip=mfu * PEAK_FLOPS / pre_flops,
        decode_ms_per_token_chip=1e3 * dec_bytes / HBM_BW,
    )


def cost_table(context: int = 4096) -> list[ServingCosts]:
    from repro.configs import ARCH_IDS, get_config
    return [serving_costs(get_config(a), context=context) for a in ARCH_IDS]
