"""Admission control and SLO-aware scheduling for the serving tier
(DESIGN.md §16).

The engine's `step()` is a mechanism; *policy* — who gets the next free
slot, what happens when the paged-KV pool is full, when a request has
waited too long — lives here. `ServingFrontend` fronts one `ServingEngine`
or `ReplicaGroup` with:

  admission queue   per-tenant FIFOs under weighted fair queuing (virtual
                    time: a dispatched request advances its tenant's
                    finish tag by cost/weight, cost = prompt + max_new
                    tokens), with strict priority classes on top — the
                    highest-priority backlogged head always dispatches
                    first, ties broken by fair-share vtime. Strict
                    priority can starve lower classes under sustained
                    overload by design; within one class the WFQ bound
                    applies (tests/test_serve_frontend.py pins both).
  backpressure      `PagePoolExhausted` NEVER escapes to callers. The
                    dispatch loop gates on estimated page headroom while
                    the engine is busy (work stays queued — "defer");
                    anything that slips through is absorbed by the
                    engine's `defer_admission` path or caught here and
                    counted. Requests that could *never* run (prompt over
                    max_len, page demand over the whole pool) and, with
                    `max_queue` set, requests past the bound are *shed*:
                    a typed terminal Ticket status, not an exception.
  cancellation      `cancel()` / per-ticket deadlines (deterministic pump
                    ticks or wall seconds) release every held resource —
                    queue entry, slot, paged-KV refs — wherever the
                    request is in its lifecycle (engine.cancel does the
                    engine-side cleanup; the leak regression test holds
                    pool free-count to baseline).
  observability     one `TenantStats` per tenant (serving/costs.py):
                    queue depth, admission/shed/timeout counters, pages
                    held, speculative acceptance, p50/p99 of queue wait
                    and submit→done latency — sampled in pump ticks, so
                    benches gate them deterministically.

One `pump()` is one scheduling round: expire deadlines → dispatch under
the fair-share order and page headroom → one engine step (prefill budget
`max_prefill_chunks` interleaves admission prefill with live decode,
bounding time-to-first-token) → harvest resolved requests. Drive it
synchronously (`pump_until_idle`, deterministic — what the tests and the
load bench do) or from the background pump thread (`start()`/`stop()`,
tickets resolve through `Ticket.wait`).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from repro.models.cache_ops import PagePoolExhausted
from repro.data import lm_data
from repro.obs import MetricsRegistry, StatsDict, as_tracer
from repro.obs.metrics import FRONTEND_STATS

from .costs import TenantStats
from .engine import Request

# ticket lifecycle: QUEUED -> ADMITTED -> one terminal state
QUEUED = "queued"
ADMITTED = "admitted"
DONE = "done"
FAILED = "failed"
SHED = "shed"            # backpressure: typed rejection, never an exception
CANCELLED = "cancelled"
TIMEOUT = "timeout"
TERMINAL = frozenset({DONE, FAILED, SHED, CANCELLED, TIMEOUT})

# typed shed reasons
SHED_QUEUE_FULL = "queue_full"   # admission queue past max_queue
SHED_TOO_LARGE = "too_large"     # could never run on this engine


@dataclass
class Ticket:
    """A request's handle through the admission tier. Terminal status is
    always one of TERMINAL; `req.out` holds the decoded tokens for DONE."""
    req: Request
    tenant: str
    priority: int
    status: str = QUEUED
    shed_reason: Optional[str] = None
    submitted_tick: int = 0
    admitted_tick: Optional[int] = None
    resolved_tick: Optional[int] = None
    deadline_tick: Optional[int] = None     # pump-tick deadline (deterministic)
    deadline_s: Optional[float] = None      # wall-clock deadline
    pages_est: int = 0
    _resolved: threading.Event = field(default_factory=threading.Event,
                                       repr=False)

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def done(self) -> bool:
        return self.status in TERMINAL

    @property
    def out(self) -> list:
        return list(self.req.out)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the ticket resolves (background-pump mode)."""
        return self._resolved.wait(timeout)


class ServingFrontend:
    def __init__(self, engine, *, tenant_weights: Optional[dict] = None,
                 default_weight: float = 1.0,
                 max_queue: Optional[int] = None,
                 max_prefill_chunks: Optional[int] = None,
                 clock: str = "ticks", tracer=None, metrics=None):
        """engine: a ServingEngine or ReplicaGroup (duck-typed on the
        non-blocking step API: step/poll/cancel/free_slots/estimate_pages/
        pool_free_pages). The frontend owns admission — the engine's own
        `queue_depth` bound should be left None.
        tenant_weights: fair-share weight per tenant (missing tenants get
        `default_weight`); a tenant with weight 2 drains twice the token
        cost per unit virtual time of a weight-1 tenant.
        max_queue: total queued-ticket bound; past it submissions shed
        with SHED_QUEUE_FULL (None = queue without bound).
        max_prefill_chunks: per-pump prefill budget handed to
        `engine.step` — bounds how much admission prefill a round may do
        before the decode phase runs (None = drain inserts every round).
        clock: "ticks" samples latencies in pump ticks (deterministic,
        what benches gate); "wall" samples in seconds."""
        self.engine = engine
        self.weights = dict(tenant_weights or {})
        self.default_weight = float(default_weight)
        self.max_queue = max_queue
        self.max_prefill_chunks = max_prefill_chunks
        if clock not in ("ticks", "wall"):
            raise ValueError(f"clock must be 'ticks' or 'wall', got {clock!r}")
        self.clock = clock
        self.tick = 0
        self.tenants: dict = {}          # tenant -> TenantStats
        self._pending: dict = {}         # tenant -> deque[Ticket]
        self._order: list = []           # tenant arrival order (tie-break)
        self._vtime: dict = {}           # tenant -> WFQ finish tag
        self._vnow = 0.0                 # virtual time of the last dispatch
        self._inflight: dict = {}        # rid -> Ticket (admitted, unresolved)
        self._tickets: dict = {}         # rid -> Ticket (all, for poll())
        self._next_rid = 0
        # observability (DESIGN.md §19): frontend counters live in a typed
        # registry behind the legacy dict surface; `metrics_text()` serves
        # the Prometheus exposition. Pass the engine's registry as
        # `metrics` for one combined exposition (names don't collide).
        self.tracer = as_tracer(tracer)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.stats = StatsDict(self.metrics, "frontend", FRONTEND_STATS)
        self._queue_delay = self.metrics.histogram("frontend.queue_delay")
        # max page demand a request may ever pose: the whole pool when empty
        self._pool_total = engine.pool_free_pages()
        self._lock = threading.RLock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ----------------------------------------------------------- helpers --

    def _engines(self):
        return self.engine.engines if hasattr(self.engine, "engines") \
            else [self.engine]

    def _busy(self) -> bool:
        return any(e.active or e._inserting for e in self._engines())

    def _capacity(self) -> int:
        """Slots the engine could start filling right now (free slots minus
        already-dispatched-but-unadmitted requests)."""
        cap = sum(e.free_slots - len(e.queue) for e in self._engines())
        if hasattr(self.engine, "engines"):
            cap -= len(self.engine.queue)
        return cap

    def _now(self):
        return self.tick if self.clock == "ticks" else time.time()

    def _tenant(self, tenant: str) -> TenantStats:
        if tenant not in self.tenants:
            self.tenants[tenant] = TenantStats(tenant=tenant)
            self._pending[tenant] = deque()
            self._order.append(tenant)
            self._vtime[tenant] = self._vnow
        return self.tenants[tenant]

    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._pending.values())

    @property
    def in_flight(self) -> int:
        return len(self._inflight)

    def has_work(self) -> bool:
        return bool(self.queued or self._inflight)

    # ------------------------------------------------------------ intake --

    def submit(self, prompt=None, *, req: Optional[Request] = None,
               tenant: str = "default", priority: int = 0,
               max_new: int = 16, eos_id: int = lm_data.EOS,
               shared_len: int = 0, deadline_ticks: Optional[int] = None,
               deadline_s: Optional[float] = None) -> Ticket:
        """Queue one request under `tenant`. Always returns a Ticket: a
        request that cannot be accepted resolves immediately with a typed
        SHED status instead of raising."""
        with self._lock:
            if req is None:
                req = Request(rid=self._next_rid, prompt=list(prompt),
                              max_new=max_new, eos_id=eos_id,
                              shared_len=shared_len)
            self._next_rid = max(self._next_rid, req.rid) + 1
            req.tenant, req.priority = tenant, priority
            t = Ticket(req=req, tenant=tenant, priority=priority,
                       submitted_tick=self.tick)
            if deadline_ticks is not None:
                t.deadline_tick = self.tick + int(deadline_ticks)
            if deadline_s is not None:
                t.deadline_s = time.time() + float(deadline_s)
            self._tickets[req.rid] = t
            ts = self._tenant(tenant)
            ts.note_queued()
            self.stats["submitted"] += 1
            eng0 = self._engines()[0]
            t.pages_est = self.engine.estimate_pages(len(req.prompt),
                                                     req.max_new)
            if eng0._extra + len(req.prompt) > eng0.max_len or \
                    (self._pool_total is not None and
                     t.pages_est > self._pool_total):
                self._resolve(t, SHED, reason=SHED_TOO_LARGE)
                return t
            if self.max_queue is not None and self.queued >= self.max_queue:
                self._resolve(t, SHED, reason=SHED_QUEUE_FULL)
                return t
            # WFQ: a tenant going from idle to backlogged catches its
            # finish tag up to the current virtual time (no credit hoarding)
            if not self._pending[tenant]:
                self._vtime[tenant] = max(self._vtime[tenant], self._vnow)
            self._pending[tenant].append(t)
            self.stats["queue_depth_peak"] = max(
                self.stats["queue_depth_peak"], self.queued)
            return t

    def submit_many(self, prompts=None, *, reqs=None, tenant: str = "default",
                    **kw) -> list:
        """All-or-nothing admission accounting: with `max_queue` set,
        either the whole batch queues or the whole batch sheds with
        SHED_QUEUE_FULL — a batch is never left half-enqueued."""
        with self._lock:
            items = list(reqs) if reqs is not None else list(prompts)
            n = len(items)
            if self.max_queue is not None and self.queued + n > self.max_queue:
                out = []
                for it in items:
                    t = self.submit(
                        **({"req": it} if isinstance(it, Request)
                           else {"prompt": it}), tenant=tenant, **kw)
                    if t.status == QUEUED:      # the bound cut in mid-batch
                        self._unqueue(t)
                        self._resolve(t, SHED, reason=SHED_QUEUE_FULL)
                    elif t.status == SHED and t.shed_reason != SHED_QUEUE_FULL:
                        pass                    # keep the more specific reason
                    else:
                        t.status, t.shed_reason = SHED, SHED_QUEUE_FULL
                        t.resolved_tick = self.tick
                    out.append(t)
                return out
            return [self.submit(
                **({"req": it} if isinstance(it, Request)
                   else {"prompt": it}), tenant=tenant, **kw)
                for it in items]

    # ------------------------------------------------------- lifecycle ----

    def _unqueue(self, t: Ticket) -> bool:
        q = self._pending.get(t.tenant)
        if q is not None and t in q:
            q.remove(t)
            return True
        return False

    def _resolve(self, t: Ticket, status: str, reason: Optional[str] = None):
        was_admitted = t.status == ADMITTED
        t.status, t.shed_reason = status, reason
        t.resolved_tick = self.tick
        ts = self.tenants[t.tenant]
        if not was_admitted:
            ts.queue_depth = max(0, ts.queue_depth - 1)
        else:
            ts.in_flight -= 1
            ts.pool_pages_held -= t.pages_est
            ts.draft_tokens += t.req.draft_tokens
            ts.accepted_tokens += t.req.accepted_tokens
            self._inflight.pop(t.rid, None)
        key = {DONE: "completed", FAILED: "failed", SHED: "shed",
               CANCELLED: "cancelled", TIMEOUT: "timeouts"}[status]
        self.stats[key] += 1
        if status == SHED:
            self.tracer.instant("frontend.shed", kind="frontend",
                                rid=t.rid, tenant=t.tenant, reason=reason)
        setattr(ts, key, getattr(ts, key) + 1)
        if status == DONE:
            ts.latency.add(self._now() - (t.submitted_tick if
                                          self.clock == "ticks"
                                          else t.req.submitted_s))
        t._resolved.set()

    def cancel(self, ticket: Ticket) -> bool:
        """Cancel a ticket anywhere in its lifecycle, releasing held
        resources. False when it already resolved (cancel lost the race)."""
        with self._lock:
            if ticket.done:
                return False
            if ticket.status == QUEUED:
                self._unqueue(ticket)
                self._resolve(ticket, CANCELLED)
                return True
            self.engine.cancel(ticket.rid)
            self._resolve(ticket, CANCELLED)
            return True

    def poll(self, rid: int) -> Optional[Ticket]:
        with self._lock:
            return self._tickets.get(rid)

    def _expire(self):
        now_s = time.time()
        for t in list(self._inflight.values()):
            if (t.deadline_tick is not None and self.tick >= t.deadline_tick) \
                    or (t.deadline_s is not None and now_s >= t.deadline_s):
                self.engine.cancel(t.rid)
                self._resolve(t, TIMEOUT)
        for q in self._pending.values():
            for t in list(q):
                if (t.deadline_tick is not None and
                        self.tick >= t.deadline_tick) or \
                        (t.deadline_s is not None and now_s >= t.deadline_s):
                    q.remove(t)
                    self._resolve(t, TIMEOUT)

    # ------------------------------------------------------- scheduling ---

    def _weight(self, tenant: str) -> float:
        return float(self.weights.get(tenant, self.default_weight))

    def _peek_next(self) -> Optional[Ticket]:
        """Strict priority first, then min WFQ finish tag, then tenant
        arrival order — deterministic under equal weights/timing."""
        best_key, best = None, None
        for i, tenant in enumerate(self._order):
            q = self._pending[tenant]
            if not q:
                continue
            head = q[0]
            key = (-head.priority, self._vtime[tenant], i)
            if best_key is None or key < best_key:
                best_key, best = key, head
        return best

    def _dispatch_one(self, t: Ticket):
        self._pending[t.tenant].popleft()
        cost = len(t.req.prompt) + t.req.max_new
        self._vnow = self._vtime[t.tenant]
        self._vtime[t.tenant] += cost / self._weight(t.tenant)
        t.status, t.admitted_tick = ADMITTED, self.tick
        t.req.submitted_s = time.time()
        self.engine.queue.append(t.req)   # frontend owns the admission bound
        ts = self.tenants[t.tenant]
        ts.queue_depth -= 1
        ts.admitted += 1
        ts.in_flight += 1
        ts.pool_pages_held += t.pages_est
        wait = self._now() - (t.submitted_tick if self.clock == "ticks"
                              else t.req.submitted_s)
        ts.queue_wait.add(wait)
        self._queue_delay.observe(wait)
        self._inflight[t.rid] = t
        self.stats["admitted"] += 1
        if self.tracer.enabled(2):
            self.tracer.instant("frontend.admit", kind="frontend", level=2,
                                rid=t.rid, tenant=t.tenant, wait=wait)

    # ------------------------------------------------------------- pump ---

    def pump(self) -> bool:
        """One scheduling round; returns whether work remains. Safe to call
        when idle (a no-op round)."""
        with self._lock:
            self.tick += 1
            self.stats["pumps"] += 1
            with self.tracer.span("frontend.pump", kind="frontend", level=2,
                                  tick=self.tick):
                return self._pump_locked()

    def _pump_locked(self) -> bool:
        self._expire()
        cap = self._capacity()
        headroom = self.engine.pool_free_pages()
        busy = self._busy()
        while cap > 0:
            t = self._peek_next()
            if t is None:
                break
            if headroom is not None and busy and t.pages_est > headroom:
                # keep it queued: live work will release pages — this
                # is the "defer" arm of the backpressure state machine
                self.stats["deferred"] += 1
                break
            self._dispatch_one(t)
            cap -= 1
            if headroom is not None:
                headroom -= t.pages_est
                busy = True      # an idle engine is busy once fed
        if self._busy() or any(e.queue for e in self._engines()) or \
                (hasattr(self.engine, "engines") and self.engine.queue):
            try:
                self.engine.step(
                    max_prefill_chunks=self.max_prefill_chunks,
                    defer_admission=True)
            except PagePoolExhausted:
                # the engine requeued the request at its queue head
                # (hardening contract) — absorb, count, retry next pump
                self.stats["pool_exhausted_absorbed"] += 1
        for rid, t in list(self._inflight.items()):
            req = self.engine.poll(rid)
            if req is None:
                continue
            if req.done:
                self._resolve(t, DONE)
            elif req.error == "cancelled":
                self._resolve(t, CANCELLED)
            else:
                self._resolve(t, FAILED)
        return self.has_work()

    def pump_until_idle(self, max_pumps: int = 100_000):
        """Synchronous drain (deterministic; what tests and benches use).
        Raises RuntimeError rather than spinning forever."""
        for _ in range(max_pumps):
            if not self.pump():
                return
        if self.has_work():
            raise RuntimeError(
                f"frontend still has work after {max_pumps} pumps "
                f"({self.queued} queued, {self.in_flight} in flight)")

    def wait_all(self, tickets, max_pumps: int = 100_000) -> list:
        """Pump until every ticket resolves; returns them (thread mode:
        just waits)."""
        if self._thread is not None:
            for t in tickets:
                t.wait()
            return list(tickets)
        for _ in range(max_pumps):
            if all(t.done for t in tickets):
                return list(tickets)
            self.pump()
        raise RuntimeError(f"tickets unresolved after {max_pumps} pumps")

    # ------------------------------------------------------ pump thread ---

    def start(self, interval_s: float = 0.0):
        """Run the pump on a background thread; `submit`/`cancel` stay
        safe from other threads and tickets resolve via `Ticket.wait`."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.pump():
                    time.sleep(max(interval_s, 1e-3))   # idle: don't spin
                elif interval_s:
                    time.sleep(interval_s)
        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="serving-frontend-pump")
        self._thread.start()

    def stop(self):
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    # ------------------------------------------------------ observability --

    def tenant_snapshot(self) -> dict:
        return {name: ts.snapshot() for name, ts in self.tenants.items()}

    def metrics_text(self) -> str:
        """Prometheus text exposition: the typed registry (frontend counters,
        queue-delay histogram, plus engine/session instruments when a shared
        registry was passed in) followed by per-tenant gauge lines rendered
        from `tenant_snapshot()` with a `tenant` label."""
        lines = [self.metrics.exposition().rstrip("\n")]
        per_tenant = ("queue_depth", "in_flight", "admitted", "completed",
                      "shed", "timeouts", "cancelled", "pool_pages_held")
        lines.append("# TYPE repro_frontend_tenant gauge")
        for tenant in sorted(self.tenants):
            snap = self.tenants[tenant].snapshot()
            for key in per_tenant:
                lines.append(
                    f'repro_frontend_tenant{{tenant="{tenant}",'
                    f'stat="{key}"}} {snap[key]}')
        return "\n".join(lines) + "\n"
