"""Data-parallel engine replicas behind one shared admission queue
(DESIGN.md §15).

Throughput past one engine comes from *replicas*: N `ServingEngine`s, each
with its own slots, decode cache, and jitted phases (and, when `mesh=` is
set, its own TP/FSDP-sharded execution), fed from a single shared queue.
`ReplicaGroup` is the engine-state split ROADMAP items 2 and 4 also need:

  per-replica — slots, decode cache, page *tables*, drafters, stats;
  shared      — the admission queue, the prefix cache, and (paged layout)
                the KV page pool, so a prefix prefilled by one replica is
                an O(1) page-id splice for every other.

Scheduling is least-loaded continuous batching: each group step spreads the
shared queue over the replicas (most-free-slots first, so partial batches
parallelize instead of piling onto replica 0), then advances every replica
that has work by one `ServingEngine.step()`. In a deployment the replicas
run concurrently (one process/device-set each); the in-process group
interleaves them on one host thread, which keeps rows byte-identical to a
single engine serving the same workload — the parity bar
tests/test_sharded_serving.py holds the group to.

Stats aggregate by *summation* across replicas (peaks — `max_live`,
`kv_bytes_peak` — take the max), updated in place on one long-lived dict so
callers holding `group.stats` (e.g. `ServedExtractor._run_round`'s
delta-accounting) read coherent totals, exactly as they would off a single
engine. Replica-sum equals single-engine totals for the per-token counters
on an identical workload (regression-tested); last-writer-wins merging of
replica stats dicts is the bug class the aggregation tests pin down.

`ReplicaGroup` is interface-compatible with `ServingEngine` where the
extraction layer touches it (`submit`/`submit_many`/`run`/`stats`/
`queue_depth`/`failed`/`finished`), so it drops into `ServedExtractor`
unchanged and `CostLedger` charges aggregate back through the normal path.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Optional, Union

import jax

from repro.models.cache_ops import PageAllocator, PagePoolExhausted
from repro.models.config import ModelConfig

from .engine import RunTruncated, ServingEngine
from .prefix_cache import PrefixCache

# stats aggregated as max over replicas; every other counter sums
PEAK_KEYS = ("max_live", "kv_bytes_peak")


def aggregate_stats(stat_dicts, into: Optional[dict] = None) -> dict:
    """Sum counters (max for PEAK_KEYS) across per-replica stats dicts.
    With `into`, the aggregate is written into that dict in place (cleared
    first) so long-lived references observe the update."""
    agg: dict = {}
    for stats in stat_dicts:
        for k, v in stats.items():
            if k in PEAK_KEYS:
                agg[k] = max(agg.get(k, 0), v)
            else:
                agg[k] = agg.get(k, 0) + v
    if into is None:
        return agg
    into.clear()
    into.update(agg)
    return into


class ReplicaGroup:
    def __init__(self, cfg: ModelConfig, params, *, replicas: int = 2,
                 slots: int = 4, max_len: int = 256,
                 queue_depth: Optional[int] = None,
                 prefix_cache: Union[bool, PrefixCache, None] = False,
                 kv_layout: str = "paged", page_size: int = 16,
                 num_pages: Optional[int] = None, mesh=None,
                 share_kv_pool: bool = True, **engine_kwargs):
        """replicas: number of data-parallel engines behind the queue.
        queue_depth: admission bound on the *shared* queue (replica queues
        stay unbounded; the group only feeds them up to free slots).
        share_kv_pool: paged layout — one PageAllocator across replicas
        (prefix pages splice cross-replica); False gives each replica its
        own pool (no cross-replica prefix sharing in the paged layout).
        num_pages: shared-pool capacity (default: every replica's default
        allotment); per-replica capacity when share_kv_pool=False.
        Remaining kwargs (spec_decode, chunk_size, ...) pass through to
        every `ServingEngine`."""
        if replicas < 1:
            raise ValueError(f"need at least one replica, got {replicas}")
        self.replicas = replicas
        self.queue: deque = deque()
        self.queue_depth = queue_depth
        self.stats: dict = {}
        self._own = {"runs": 0, "truncations": 0, "cancelled": 0}
        self._cancelled: dict = {}   # rid -> Request (cancelled off the shared queue)
        if isinstance(prefix_cache, PrefixCache):
            self.prefix_cache: Optional[PrefixCache] = prefix_cache
        else:
            self.prefix_cache = PrefixCache() if prefix_cache else None
        shared_alloc = None
        if kv_layout == "paged" and share_kv_pool and replicas > 1:
            pages_per_slot = max_len // max(1, int(page_size))
            if num_pages is None:
                num_pages = replicas * (slots + 4) * pages_per_slot + 1
            shared_alloc = PageAllocator(cfg, num_pages, page_size)
            if mesh is not None:
                shared_alloc.shard_pools(mesh)
        if mesh is not None:
            # shard once; each engine's device_put of already-sharded
            # params is then a no-op instead of R host->device transfers
            from repro.distributed.sharding import param_shardings
            params = jax.device_put(params,
                                    param_shardings(cfg, params, mesh))
        self.engines = [
            ServingEngine(
                cfg, params, slots=slots, max_len=max_len, queue_depth=None,
                prefix_cache=(self.prefix_cache if self.prefix_cache
                              is not None else False),
                kv_layout=kv_layout, page_size=page_size,
                num_pages=num_pages, mesh=mesh, page_allocator=shared_alloc,
                **engine_kwargs)
            for _ in range(replicas)]
        self._sync_stats()

    # ------------------------------------------------------------ intake --

    def submit(self, req):
        if self.queue_depth is not None and len(self.queue) >= self.queue_depth:
            raise RuntimeError(
                f"serving queue full ({len(self.queue)} >= {self.queue_depth})")
        req.submitted_s = time.time()
        self.queue.append(req)

    def submit_many(self, reqs):
        """All-or-nothing admission, mirroring `ServingEngine.submit_many`."""
        reqs = list(reqs)
        if self.queue_depth is not None and \
                len(self.queue) + len(reqs) > self.queue_depth:
            raise RuntimeError(
                f"serving queue full ({len(self.queue)} + {len(reqs)} > "
                f"{self.queue_depth})")
        for req in reqs:
            self.submit(req)

    # --------------------------------------------------------- aggregation --

    def _sync_stats(self) -> dict:
        aggregate_stats([e.stats for e in self.engines], into=self.stats)
        for k, v in self._own.items():
            # group-level run/truncation accounting: the group drives
            # engine.step() directly, so engines' own counters stay zero
            self.stats[k] = self.stats.get(k, 0) + v
        return self.stats

    @property
    def finished(self) -> dict:
        out: dict = {}
        for e in self.engines:
            out.update(e.finished)
        return out

    @property
    def failed(self) -> dict:
        out: dict = {}
        for e in self.engines:
            out.update(e.failed)
        return out

    @property
    def cancelled(self) -> dict:
        out: dict = dict(self._cancelled)
        for e in self.engines:
            out.update(e.cancelled)
        return out

    @property
    def active_requests(self) -> int:
        return sum(len(e.active) + len(e.queue) + len(e._inserting)
                   for e in self.engines)

    @property
    def free_slots(self) -> int:
        return sum(e.free_slots for e in self.engines)

    def pool_free_pages(self) -> Optional[int]:
        """Free pages in the (shared or per-replica) KV pool — the most
        constrained replica when pools are private. None off-paged."""
        vals = [e.alloc.free_pages for e in self.engines
                if e.paged and e.alloc.pools]
        return min(vals) if vals else None

    def estimate_pages(self, prompt_len: int, max_new: int) -> int:
        return self.engines[0].estimate_pages(prompt_len, max_new)

    # --------------------------------------------------------------- run ---

    def _dispatch(self):
        """Least-loaded dispatch: hand shared-queue requests one at a time
        to the replica with the most free slots (ties to the lowest index),
        so a partial batch spreads across replicas instead of serializing
        behind replica 0 — that spread IS the dp2 throughput win the bench
        gates. Stats stay sum-identical to a single engine: replicas step
        sequentially after dispatch, so whichever replica steps first with a
        prefix group's request pays the one boundary prefill and inserts the
        snapshot into the shared cache; every later admission hits. The
        boundary is paid once and each request pays its own suffix, exactly
        the single-engine totals."""
        while self.queue:
            best, cap = None, 0
            for eng in self.engines:
                free = eng.free_slots - len(eng.queue)
                if free > cap:
                    best, cap = eng, free
            if best is None:
                break
            best.queue.append(self.queue.popleft())

    def _work_remains(self) -> bool:
        return bool(self.queue) or \
            any(e.queue or e.active or e._inserting for e in self.engines)

    def step(self, *, max_prefill_chunks=None,
             defer_admission: bool = False) -> bool:
        """One group round: least-loaded dispatch off the shared queue,
        then one `ServingEngine.step()` on every replica with work — the
        non-blocking unit `serving/frontend.py` pumps. Both knobs pass
        through to each replica (the prefill budget is per replica: they
        model independent devices, so budgets don't share). Returns whether
        work remains; stats are re-aggregated so long-lived references
        observe the round."""
        self._dispatch()
        for eng in self.engines:
            if eng.queue or eng.active or eng._inserting:
                eng.step(max_prefill_chunks=max_prefill_chunks,
                         defer_admission=defer_admission)
        self._sync_stats()
        return self._work_remains()

    def poll(self, rid: int):
        """Non-blocking result check across the group (None = in flight)."""
        if rid in self._cancelled:
            return self._cancelled[rid]
        for eng in self.engines:
            req = eng.poll(rid)
            if req is not None:
                return req
        return None

    def cancel(self, rid: int) -> bool:
        """Cancel wherever the request lives: the shared queue, or any
        replica's queue/insert/active slot (resources released there)."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                req.error = "cancelled"
                req.finished_s = time.time()
                self._cancelled[req.rid] = req
                self._own["cancelled"] += 1
                self._sync_stats()
                return True
        for eng in self.engines:
            if eng.cancel(rid):
                self._sync_stats()
                return True
        return False

    def run(self, max_steps: int = 10_000, *, strict: bool = True):
        """Drain the shared queue across all replicas. Semantics mirror
        `ServingEngine.run`: `max_steps` bounds *group* steps (one
        interleaved round over every replica), truncation is counted and,
        under `strict`, raised as `RunTruncated`."""
        self._own["runs"] += 1
        try:
            while self._work_remains() and max_steps > 0:
                max_steps -= 1
                self._dispatch()
                for eng in self.engines:
                    if eng.queue or eng.active:
                        eng.step()
        except PagePoolExhausted:
            self._sync_stats()
            raise
        self._sync_stats()
        if self._work_remains():
            self._own["truncations"] += 1
            self._sync_stats()
            if strict:
                raise RunTruncated(
                    f"run() truncated at max_steps with "
                    f"{self.active_requests} requests on replicas and "
                    f"{len(self.queue)} queued", self.finished)
        return self.finished
