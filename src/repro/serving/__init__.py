"""Serving substrate (DESIGN.md §7, §10, §12, §14–§16): everything
between an extraction prompt and its decoded tokens.

Inputs are token-level `Request`s (prompt ids, decode budget, optional
shared-prefix boundary and tenant tag); outputs are greedy decoded
token ids plus per-engine stats. The layer's contract, enforced across
every module here, is that serving optimizations are invisible in
results: decoded output is byte-identical with batching, prefix reuse,
paged vs slab KV layouts, speculative decoding, replica/mesh placement,
and admission scheduling on or off — savings surface only in the stats
and the cost ledger's separately-reported columns.

  engine.py        slot-based continuous-batching engine, both KV
                   layouts, chunked prefill, the speculative decode loop
  prefix_cache.py  shared-prefix KV store (longest-prefix match, LRU,
                   doc-tagged invalidation)
  spec_decode.py   drafters: prompt-lookup n-grams, draft-model
  replicas.py      data-parallel engines behind one shared queue
  frontend.py      admission control, SLO scheduling, typed shedding
  costs.py         per-architecture tokens -> seconds/Joules model
"""
