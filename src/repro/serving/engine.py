"""Batched serving engine with continuous batching (slot-based)
(DESIGN.md §7). Inputs are token-level `Request`s; outputs are greedy
decoded ids, byte-identical across every layout/optimization below.

Two KV layouts (DESIGN.md §10/§12):

`kv_layout="paged"` (default) — vLLM-style block layout. Length-indexed KV
lives in a fixed pool of `page_size`-token pages (`models.cache_ops.
PageAllocator`); each slot is a page table, and the decode/prefill model
code runs over views gathered through it. Prompts prefill in fixed-size
chunks (`chunk_size` tokens per jitted `prefill_chunk` call, remainder
chunk exact — jit signatures stay bounded) instead of token-at-a-time
decode steps. A request whose prompt extends a cached prefix splices the
prefix's page ids into its table — O(1) in KV bytes, ref-counted, with
copy-on-write on the partially-filled boundary page — and chunk-prefills
only the unshared suffix. Pure-state buffers (SSM conv/ssm state, enc-dec
cross KV) are not length-indexed: they stay in the per-slot state cache and
prefix entries carry the exact boundary state, so paging is correct for all
six model families, not just attention.

`kv_layout="slab"` — the PR 2 layout kept for comparison: per-slot
contiguous KV, prefix hits copy a materialized snapshot into the slot
(`expand_snapshot`/`write_slot`) and the unshared suffix prefills one token
at a time through the decode step. Full prefills bucket their jit
signatures: prompts are right-padded to the next `chunk_size` multiple and
`prefill(..., length=n)` keeps the state exact at the true length.

Shared-prefix semantics are layout-invariant: decoded outputs are identical
with the cache on or off and across layouts (tests/test_paged_kv.py);
savings are reported separately (`stats["prefix_saved_tokens"]`).

Speculative decoding (DESIGN.md §14): with `spec_decode=` on, decode runs
as draft/verify rounds — a drafter (prompt-lookup n-grams or a small draft
model, `serving/spec_decode.py`) proposes up to `spec_k` tokens per live
slot, one batched `verify_chunk` forward scores every slot's pending token
plus drafts at per-row positions, and the longest greedy-agreeing prefix
plus one bonus token is emitted. Rejected suffixes roll back exactly:
paged KV is scrubbed and speculative page refs released
(`cache_ops.truncate_pages` / `release_trailing_pages`), SSM/conv state is
restored from per-position checkpoints. Greedy output is byte-identical to
plain decode for every drafter and family (tests/test_spec_decode.py);
the economy is reported via `stats["draft_tokens"]` /
`stats["accepted_tokens"]` / `stats["decode_steps_saved"]`.

Mesh-aware serving (DESIGN.md §15): with `mesh=` set (a (data, model) mesh
from `launch/mesh.py`, CPU meshes supported for CI), the engine runs every
phase multi-device: params are laid out with the FSDP+TP rules of
`distributed/sharding.py`, the decode cache shards its slot axis over
`data` and heads/features over `model`, and the paged KV pool shards pages
replicated / heads over `model` (page tables stay host-local integers).
The jitted phases — chunked prefill, paged decode, and spec-decode verify —
thread the mesh's activation-constraint hook through the model and pin
their cache/pool outputs to explicit PartitionSpecs, so the layout is
stable across steps. Decoded rows are byte-identical to the single-device
engine (tests/test_sharded_serving.py). Data-parallel *replica* scaling on
top of one engine lives in `serving/replicas.py`.

Fault tolerance: `drain_slot` evicts a request (e.g. on a simulated worker
failure) and requeues it; the scheduler resubmits from the prompt. Retries
are bounded by `Request.max_retries` — beyond it the request fails visibly
into `engine.failed` instead of looping forever. `run()` raises
`RunTruncated` (strict default) when `max_steps` is exhausted with work
still queued/active, so callers can never mistake partial results for
complete ones.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.distributed.sharding import (cache_specs, make_constrain,
                                        param_shardings, pool_specs,
                                        to_shardings)
from repro.models import (decode_step, encode_cross_kv, init_decode_cache,
                          prefill, prefill_chunk, verify_chunk)
from repro.models.cache_ops import (PAGE_SINK, PageAllocator,
                                    PagePoolExhausted, cache_nbytes,
                                    expand_snapshot, gather_page_views,
                                    prefix_snapshot, release_trailing_pages,
                                    scatter_chunk_pages,
                                    scatter_chunk_pages_rows,
                                    scatter_token_pages, truncate_pages,
                                    write_slot)
from repro.models.config import ModelConfig
from repro.data import lm_data
from repro.obs import MetricsRegistry, StatsDict, as_tracer
from repro.obs.metrics import ENGINE_STATS
from .prefix_cache import PrefixCache
from .spec_decode import DraftModelDrafter, PromptLookupDrafter


@dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    eos_id: int = lm_data.EOS
    shared_len: int = 0      # prompt[:shared_len] is shareable across requests
    max_retries: int = 3     # drain_slot evictions tolerated before failing
    tenant: str = ""         # admission-control identity (serving/frontend.py)
    priority: int = 0        # admission priority class (higher first)
    out: list = field(default_factory=list)
    done: bool = False
    submitted_s: float = 0.0
    finished_s: float = 0.0
    retries: int = 0
    error: Optional[str] = None
    # per-request speculative-decode economy (per-tenant acceptance rates)
    draft_tokens: int = 0
    accepted_tokens: int = 0
    # live-corpus provenance (DESIGN.md §17): doc_ids whose text the prompt
    # embeds, and the token offset where that content starts. A prefix-cache
    # entry is tagged with content_docs only when its boundary reaches past
    # content_start — template-only prefixes stay invalidation-immune.
    content_docs: tuple = ()
    content_start: Optional[int] = None


class RunTruncated(RuntimeError):
    """`run()` exhausted max_steps with requests still queued/active."""

    def __init__(self, msg: str, finished: dict):
        super().__init__(msg)
        self.finished = finished


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@jax.jit
def _restore_ckpt_rows(ssm, conv, ck_ssm, ck_conv, keeps, mask):
    """Batched SSM/conv rollback: for every row with mask[b], replace the
    state with the per-position checkpoint at keeps[b] kept tokens — one
    vectorized dispatch per verify round instead of two scatters per slot.
    ssm (L, B, ...); conv (L, B, K-1, ...); ck_ssm (L, B, C, ...);
    ck_conv (L, B, K-1+C, ...)."""
    km1 = conv.shape[2]

    def pick_ssm(row, k):                        # (L, C, ...) -> (L, ...)
        return jax.lax.dynamic_index_in_dim(row, k - 1, axis=1,
                                            keepdims=False)

    def pick_conv(row, k):                       # (L, K-1+C, ...) -> window
        return jax.lax.dynamic_slice_in_dim(row, k, km1, axis=1)

    new_ssm = jax.vmap(pick_ssm, in_axes=(1, 0), out_axes=1)(ck_ssm, keeps)
    new_conv = jax.vmap(pick_conv, in_axes=(1, 0), out_axes=1)(ck_conv, keeps)
    ms = mask.reshape((1, -1) + (1,) * (ssm.ndim - 2))
    mc = mask.reshape((1, -1) + (1,) * (conv.ndim - 2))
    return (jnp.where(ms, new_ssm.astype(ssm.dtype), ssm),
            jnp.where(mc, new_conv.astype(conv.dtype), conv))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 queue_depth: Optional[int] = None,
                 prefix_cache: Union[bool, PrefixCache, None] = False,
                 prefix_min_len: int = 8,
                 kv_layout: str = "paged", page_size: int = 16,
                 num_pages: Optional[int] = None, chunk_size: int = 32,
                 spec_decode="off", spec_k: int = 4, spec_ngram: int = 3,
                 draft_model: Optional[tuple] = None, mesh=None,
                 page_allocator: Optional[PageAllocator] = None,
                 compilation_cache_dir: Optional[str] = None,
                 tracer=None, metrics=None):
        """queue_depth: optional admission-control bound on queued requests;
        ServedExtractor splits its batch rounds into windows of this size
        (None = unbounded).
        prefix_cache: shared-prefix KV reuse — False/None off, True for a
        default `PrefixCache()`, or a configured instance.
        prefix_min_len: shortest prefix worth snapshotting/splicing.
        kv_layout: "paged" (block/page-table KV + chunked prefill) or
        "slab" (per-slot contiguous KV, PR 2's layout).
        page_size: tokens per KV page (paged layout; must divide max_len).
        num_pages: pool capacity (default (slots+4) tables' worth + sink).
        chunk_size: prompt tokens per chunked-prefill call; also the
        bucket granularity for slab-mode prefill jit signatures.
        spec_decode: speculative decoding (DESIGN.md §14) — "off" (plain
        one-token decode steps), "prompt_lookup" (n-gram drafting over the
        request's own context), "draft" (a second small model, see
        `draft_model`), or a custom drafter instance. Greedy output is
        byte-identical across all settings.
        spec_k: draft tokens per verify round (each round emits 1..k+1).
        spec_ngram: longest n-gram the prompt-lookup drafter matches.
        draft_model: (ModelConfig, params) of the draft model, required for
        spec_decode="draft" (dense/moe family, same vocab).
        mesh: optional (data, model) jax Mesh (see `launch/mesh.py`) — run
        the engine multi-device with FSDP+TP-sharded params, sharded decode
        cache / paged KV pool, and mesh-constrained jitted phases (DESIGN.md
        §15). Rows stay byte-identical to the single-device engine.
        page_allocator: an existing PageAllocator to use instead of
        constructing one — `serving/replicas.py` shares a pool (and with it
        the prefix-cache page references) across engine replicas.
        compilation_cache_dir: enable jax's persistent compilation cache at
        this directory before any engine phase is jitted (launch/
        compile_cache.py) — repeated runs skip re-jit."""
        if compilation_cache_dir is not None:
            from repro.launch.compile_cache import enable_compilation_cache
            enable_compilation_cache(compilation_cache_dir)
        self.cfg = cfg
        self.mesh = mesh
        if mesh is not None:
            # FSDP+TP parameter layout; a no-op when `params` already
            # carries these shardings (replica groups pre-shard once)
            params = jax.device_put(params, param_shardings(cfg, params, mesh))
            self._constrain = make_constrain(mesh, slots)      # batched phases
            self._constrain1 = make_constrain(mesh, 1)         # B=1 prefill
        else:
            self._constrain = self._constrain1 = None
        self._cache_pspecs = self._pool_pspecs = None
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.queue_depth = queue_depth
        if isinstance(prefix_cache, PrefixCache):   # may be empty, i.e. falsy
            self.prefix_cache: Optional[PrefixCache] = prefix_cache
        else:
            self.prefix_cache = PrefixCache() if prefix_cache else None
        self.prefix_min_len = max(1, int(prefix_min_len))
        if kv_layout not in ("paged", "slab"):
            raise ValueError(f"kv_layout must be 'paged' or 'slab', got {kv_layout!r}")
        self.kv_layout = kv_layout
        self.paged = kv_layout == "paged"
        self.page_size = max(1, int(page_size))
        self.chunk_size = max(1, int(chunk_size))
        # vlm: image tokens occupy the first cache positions of every prompt
        self._extra = cfg.n_image_tokens if cfg.family == "vlm" else 0
        self.queue: deque = deque()
        self.active: dict = {}          # slot -> Request
        self.finished: dict = {}
        self.failed: dict = {}          # rid -> Request (retry cap exceeded)
        self.cancelled: dict = {}       # rid -> Request (cancel() resolved)
        self._inserting: dict = {}      # slot -> (Request, insert coroutine)
        self.spec_k = max(1, int(spec_k))
        if isinstance(spec_decode, str):
            if spec_decode not in ("off", "prompt_lookup", "draft"):
                raise ValueError(
                    f"spec_decode must be 'off', 'prompt_lookup', 'draft' or "
                    f"a drafter instance, got {spec_decode!r}")
            if spec_decode == "prompt_lookup":
                self.drafter = PromptLookupDrafter(ngram=spec_ngram)
            elif spec_decode == "draft":
                if draft_model is None:
                    raise ValueError(
                        "spec_decode='draft' requires draft_model=(cfg, params)")
                dcfg, dparams = draft_model
                if dcfg.vocab_size != cfg.vocab_size:
                    raise ValueError(
                        f"draft vocab {dcfg.vocab_size} != target vocab "
                        f"{cfg.vocab_size}")
                self.drafter = DraftModelDrafter(dcfg, dparams, slots=slots,
                                                 max_len=max_len, mesh=mesh)
            else:
                self.drafter = None
        else:
            # custom drafter instance (tests); falsy (None/False) reads as
            # off, mirroring the prefix_cache parameter's bool convention
            self.drafter = spec_decode or None
            if self.drafter is not None and \
                    not hasattr(self.drafter, "draft_round"):
                raise ValueError(
                    f"spec_decode instance must implement the drafter "
                    f"protocol (draft_round/on_insert/on_free), got "
                    f"{spec_decode!r}")
        self.spec = self.drafter is not None
        # observability (DESIGN.md §19): engine counters live in a typed
        # MetricsRegistry behind the same dict read/write surface as the
        # old plain dict — an undeclared key is now a hard schema error.
        # One registry per engine (shared instruments would double-count
        # under replica aggregation); `tracer` spans the engine phases.
        self.tracer = as_tracer(tracer)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.stats = StatsDict(self.metrics, "engine", ENGINE_STATS)

        self.cache = init_decode_cache(cfg, slots, max_len)
        self.cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self._live = np.zeros((slots,), bool)
        self._tokens = jnp.zeros((slots, 1), jnp.int32)

        def _dec(params, tokens, cache):
            # full-batch decode gets the batched constrain hook + sticky
            # cache specs; B=1 sub-cache suffix prefill (slab) the B=1 hook
            full = tokens.shape[0] == self.slots
            logits, new = decode_step(
                cfg, params, tokens, cache,
                constrain=self._constrain if full else self._constrain1)
            if full:
                new = self._with_specs(new, self._cache_pspecs)
            return logits, new
        self._decode = jax.jit(_dec)
        self._prefill_cache = {}

        def _vslab(params, toks, cache):
            logits, new, ckpts = verify_chunk(cfg, params, {"tokens": toks},
                                              cache, constrain=self._constrain)
            return logits, self._with_specs(new, self._cache_pspecs), ckpts
        self._verify_slab = jax.jit(_vslab)
        self._verify_fns: dict = {}

        if self.paged:
            assert max_len % self.page_size == 0, (
                f"max_len={max_len} must be a multiple of page_size={page_size}")
            self.pages_per_slot = max_len // self.page_size
            if page_allocator is not None:
                assert page_allocator.page_size == self.page_size, (
                    f"shared allocator page_size={page_allocator.page_size} "
                    f"!= engine page_size={self.page_size}")
                self.alloc = page_allocator   # shared pool: replica groups
            else:
                if num_pages is None:
                    num_pages = (slots + 4) * self.pages_per_slot + 1
                self.alloc = PageAllocator(cfg, num_pages, self.page_size)
                if mesh is not None:
                    self.alloc.shard_pools(mesh)
            for k in self.alloc.pools:   # length-indexed KV lives in the pool
                del self.cache[k]
            self.slot_pages: list = [[] for _ in range(slots)]
            self._pos_h = np.zeros((slots,), np.int64)   # host mirror of pos
            self._chunk_fns: dict = {}
            self._paged_decode = jax.jit(self._make_paged_decode())
            self._cross_kv = None                         # encdec, computed once

        if mesh is not None:
            # sticky layouts for the state that persists across steps: the
            # jitted phases re-pin their cache/pool outputs to these specs
            self._cache_pspecs = cache_specs(cfg, self.cache, mesh, slots)
            self.cache = jax.device_put(
                self.cache, to_shardings(mesh, self._cache_pspecs))
            if self.paged:
                self._pool_pspecs = pool_specs(self.alloc.pools, mesh)

    def _with_specs(self, tree: dict, pspecs) -> dict:
        """Pin a cache/pool pytree's leaves to the engine's mesh specs
        (jit-traceable `with_sharding_constraint`); identity off-mesh."""
        if self.mesh is None or pspecs is None:
            return tree
        out = dict(tree)
        for k, spec in pspecs.items():
            if k in out:
                out[k] = jax.lax.with_sharding_constraint(
                    out[k], NamedSharding(self.mesh, spec))
        return out

    # ------------------------------------------------------------ intake --

    def submit(self, req: Request):
        if self.queue_depth is not None and len(self.queue) >= self.queue_depth:
            raise RuntimeError(
                f"serving queue full ({len(self.queue)} >= {self.queue_depth})")
        req.submitted_s = time.time()
        self.queue.append(req)

    def submit_many(self, reqs):
        """All-or-nothing admission: never leaves a batch half-enqueued."""
        reqs = list(reqs)
        if self.queue_depth is not None and \
                len(self.queue) + len(reqs) > self.queue_depth:
            raise RuntimeError(
                f"serving queue full ({len(self.queue)} + {len(reqs)} > "
                f"{self.queue_depth})")
        for req in reqs:
            req.submitted_s = time.time()
            self.queue.append(req)

    # --------------------------------------------------- slab-mode prefill --

    def _bucket_len(self, n: int) -> int:
        """Next chunk_size multiple — bounds distinct prefill jit signatures
        (each distinct prompt length no longer triggers a fresh compile).
        Capped so padding never pushes text + image/frame tokens past the
        cache bound a legal prompt still fits in."""
        b = self.chunk_size
        return min(((n + b - 1) // b) * b, self.max_len - self._extra)

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            self._prefill_cache[bucket] = jax.jit(
                partial(prefill, self.cfg, max_len=self.max_len,
                        constrain=self._constrain1))
        return self._prefill_cache[bucket]

    def _prefill_sub(self, tokens: list):
        """Exact-state prefill of `tokens` into a B=1 sub-cache, padded to a
        bucketed length (one jit signature per bucket; `length` keeps the
        logits, cache position and SSM state exact at the true length).
        Returns (last-position logits, sub-cache)."""
        n = len(tokens)
        bucket = self._bucket_len(n)
        toks = jnp.asarray(list(tokens) + [0] * (bucket - n), jnp.int32)[None, :]
        batch = {"tokens": toks}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros((1, self.cfg.encoder_seq, self.cfg.d_model),
                                        jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "vlm":
            from repro.models.model import VISION_DIM
            batch["image_embeds"] = jnp.zeros((1, self.cfg.n_image_tokens, VISION_DIM),
                                              jnp.float32)
        self.stats["prefill_invocations"] += 1
        # attention-FLOPs proxy: KV positions computed against (S x S matrix)
        self.stats["prefill_ctx_positions"] += (self._extra + bucket) ** 2
        return self._prefill_fn(bucket)(self.params, batch,
                                        length=jnp.asarray(n, jnp.int32))

    def _insert_slab_co(self, slot: int, req: Request):
        """Coroutine form of the slab-layout insert: yields between prefill
        units (one bucketed prefill call, or one exact decode step per
        unshared-suffix token — the same recurrence decode uses, so SSM/conv
        state stays correct). Driven to exhaustion it computes exactly what
        the old blocking `_insert_slab` did."""
        prompt = req.prompt
        sub, prefix_len, did_work = None, 0, False
        if self.prefix_cache is not None:
            entry = self.prefix_cache.match(prompt)
            if entry is not None and len(entry.tokens) >= self.prefix_min_len:
                prefix_len = len(entry.tokens)
                sub = expand_snapshot(entry.cache, self.max_len)
                self.stats["prefix_hits"] += 1
                self.stats["prefix_saved_tokens"] += prefix_len
                self.tracer.instant("engine.prefix_hit", kind="engine",
                                    level=2, saved=prefix_len)
            else:
                # first request of a prefix group: prefill the shared prefix
                # exactly (state-correct snapshot boundary), then continue
                boundary = min(int(req.shared_len), len(prompt) - 1)
                if boundary >= self.prefix_min_len:
                    _, sub = self._prefill_sub(prompt[:boundary])
                    did_work = True
                    self.stats["prefill_tokens"] += boundary
                    self.prefix_cache.insert(
                        prompt[:boundary],
                        prefix_snapshot(sub, self._extra + boundary),
                        doc_ids=self._entry_docs(req, boundary))
                    self.stats["prefix_inserts"] += 1
                    prefix_len = boundary
        if sub is None:
            logits, sub = self._prefill_sub(prompt)
            self.stats["prefill_tokens"] += len(prompt)
        else:
            logits = None
            for t in prompt[prefix_len:]:
                if did_work:
                    yield               # cooperative point between tokens
                did_work = True
                logits, sub = self._decode(self.params,
                                           jnp.asarray([[t]], jnp.int32), sub)
                self.stats["prefill_invocations"] += 1
                # each token-step attends the full max_len KV buffer
                self.stats["prefill_ctx_positions"] += self.max_len
            self.stats["prefill_tokens"] += len(prompt) - prefix_len
        self.cache = write_slot(self.cache, sub, slot)
        return logits

    # -------------------------------------------------- paged-mode prefill --

    def _init_state_sub(self) -> dict:
        """Fresh B=1 pure-state sub-cache (pos + conv/ssm/cross buffers)."""
        sub = {}
        for k, a in self.cache.items():
            sub[k] = jnp.zeros((), jnp.int32) if k == "pos" else \
                jnp.zeros_like(a[:, :1])
        if self.cfg.family == "encdec":
            if self._cross_kv is None:
                frames = jnp.zeros((1, self.cfg.encoder_seq, self.cfg.d_model),
                                   jnp.dtype(self.cfg.dtype))
                ck, cv = encode_cross_kv(self.cfg, self.params, frames)
                self._cross_kv = (ck.astype(self.cache["ck"].dtype),
                                  cv.astype(self.cache["cv"].dtype))
            sub["ck"], sub["cv"] = self._cross_kv
        return sub

    def _make_paged_decode(self):
        cfg, ps = self.cfg, self.page_size

        def step(params, tokens, state, pools, table, write_ids):
            dense = dict(state)
            dense.update(gather_page_views(pools, table))
            logits, new = decode_step(cfg, params, tokens, dense,
                                      constrain=self._constrain)
            new_state = {k: new[k] for k in state}
            if pools:
                starts = (state["pos"] // ps) * ps
                pools = scatter_token_pages(pools, new, write_ids, starts, ps)
            return (logits, self._with_specs(new_state, self._cache_pspecs),
                    self._with_specs(pools, self._pool_pspecs))
        return step

    def _chunk_fn(self, n_ctx: int, nb: int, with_images: bool):
        key = (n_ctx, nb, with_images)
        if key not in self._chunk_fns:
            cfg, ps = self.cfg, self.page_size
            has_pool = bool(self.alloc.pools)

            def fn(params, state, pools, ctx_ids, tokens, length, write_ids, b0):
                batch = {"tokens": tokens}
                if with_images:
                    from repro.models.model import VISION_DIM
                    batch["image_embeds"] = jnp.zeros(
                        (1, cfg.n_image_tokens, VISION_DIM), jnp.float32)
                dense = dict(state)
                if has_pool:
                    dense.update(gather_page_views(pools, ctx_ids[None, :]))
                logits, new = prefill_chunk(cfg, params, batch, dense,
                                            length=length,
                                            constrain=self._constrain1)
                new_state = {k: new[k] for k in state}
                if has_pool:
                    pools = scatter_chunk_pages(pools, new, write_ids, b0, ps, nb)
                return logits, new_state, self._with_specs(pools,
                                                           self._pool_pspecs)
            self._chunk_fns[key] = jax.jit(fn)
        return self._chunk_fns[key]

    def _ensure_pages(self, n: int, acquired: list) -> list:
        """Allocate n pages, evicting LRU prefix entries under pool pressure
        (pinned entries — pages shared with live slots — free nothing and the
        loop moves on to the next victim). Newly allocated ids are appended
        to `acquired`; on hard exhaustion the caller rolls that list back."""
        while True:
            try:
                ids = self.alloc.alloc(n)
                acquired.extend(ids)
                return ids
            except PagePoolExhausted:
                if self.prefix_cache is not None and \
                        self.prefix_cache.pop_lru() is not None:
                    continue
                raise

    def _cow_page(self, src: int, acquired: list) -> int:
        """copy_page with the same evict-LRU-under-pressure behaviour as
        `_ensure_pages`. `src` must be retained by the caller so a victim
        eviction cannot free it mid-copy."""
        while True:
            try:
                dst = self.alloc.copy_page(src)
                acquired.append(dst)
                return dst
            except PagePoolExhausted:
                if self.prefix_cache is not None and \
                        self.prefix_cache.pop_lru() is not None:
                    continue
                raise

    def _chunked_prefill_co(self, slot: int, state: dict, tokens: list,
                            lpos: int, *, first: bool = True):
        """Feed `tokens` through fixed-size prefill chunks, yielding between
        chunks so the caller can interleave decode of live slots with this
        insert's prefill. Every chunk is padded to `chunk_size` and carries
        its true length traced, so one jit signature (per pow2-bucketed
        context width) serves every prompt length and offset. KV is written
        straight into the slot's pages through a context view gathered over
        the page table. `first=False` yields before the first chunk too
        (continuation of an insert that already did a prefill unit).
        Returns (last-chunk logits, state, new logical position, first)."""
        cs, ps = self.chunk_size, self.page_size
        pages = self.slot_pages[slot]
        has_pool = bool(self.alloc.pools)
        logits, i, n = None, 0, len(tokens)
        while i < n:
            if not first:
                yield               # cooperative point between chunks
            first = False
            true_clen = min(cs, n - i)
            with_images = self._extra > 0 and lpos == 0
            extra = self._extra if with_images else 0
            llen_pad = cs + extra         # positions the padded chunk touches
            if has_pool:
                nb = (llen_pad + ps - 2) // ps + 1 if ps > 1 else llen_pad
                need = -(-(lpos + llen_pad) // ps)
                n_ctx = _pow2_at_least(max(need, nb))
                b0 = min(lpos // ps, n_ctx - nb)
                ctx = [pages[b] if b < len(pages) else PAGE_SINK
                       for b in range(n_ctx)]
                wids = [pages[b] if b < len(pages) else PAGE_SINK
                        for b in range(b0, b0 + nb)]
            else:
                nb = n_ctx = b0 = 0
                ctx, wids = [], []
            chunk = list(tokens[i:i + true_clen]) + [0] * (cs - true_clen)
            fn = self._chunk_fn(n_ctx, nb, with_images)
            logits, state, self.alloc.pools = fn(
                self.params, state, self.alloc.pools,
                jnp.asarray(ctx, jnp.int32),
                jnp.asarray(chunk, jnp.int32)[None, :],
                jnp.asarray(true_clen, jnp.int32),
                jnp.asarray(wids, jnp.int32), jnp.asarray(b0, jnp.int32))
            self.stats["prefill_invocations"] += 1
            self.stats["prefill_chunks"] += 1
            self.stats["prefill_ctx_positions"] += \
                llen_pad * (n_ctx * ps if has_pool else llen_pad)
            self.tracer.instant("engine.prefill_chunk", kind="engine",
                                level=2, tokens=int(true_clen))
            i += true_clen
            lpos += true_clen + extra
        return logits, state, lpos, first

    @staticmethod
    def _entry_docs(req: Request, boundary: int) -> tuple:
        """Doc provenance for a prefix entry at `boundary` tokens: the
        request's content docs iff the boundary reaches into the content
        span — a template-only prefix embeds no document text and must
        survive that document's mutation."""
        if (req.content_docs and req.content_start is not None
                and boundary > req.content_start):
            return tuple(req.content_docs)
        return ()

    def _snapshot_prefix_paged(self, slot: int, prefix: list, state: dict,
                               req: Optional[Request] = None):
        """Store a prefix entry as *page references*: full pages shared by
        reference (ref-counted), the partially-filled boundary page copied
        once so the slot can keep writing into its own copy (CoW)."""
        lp = self._extra + len(prefix)
        pages = self.slot_pages[slot]
        full = lp // self.page_size
        entry_pages = list(pages[:full])
        self.alloc.retain(entry_pages)
        tail = None
        if lp % self.page_size and full < len(pages):
            try:
                tail = self._cow_page(pages[full], [])
            except PagePoolExhausted:
                # caching this prefix is an optimization, not a requirement:
                # under hard pool pressure skip the snapshot, keep serving
                self.alloc.release(entry_pages)
                return
            self.stats["cow_copies"] += 1
        snap = dict(state)
        nbytes = ((len(entry_pages) + (1 if tail is not None else 0))
                  * self.alloc.page_nbytes + cache_nbytes(snap))
        alloc, ids = self.alloc, entry_pages + ([tail] if tail is not None else [])
        self.prefix_cache.insert(prefix, snap, pages=entry_pages,
                                 tail_page=tail, nbytes=nbytes,
                                 release=(lambda: alloc.release(ids)),
                                 doc_ids=(self._entry_docs(req, len(prefix))
                                          if req is not None else ()))
        self.stats["prefix_inserts"] += 1

    def _insert_paged_co(self, slot: int, req: Request):
        """Coroutine form of the paged insert. Pages are acquired all at
        once *before the first yield* (all-or-nothing: PagePoolExhausted
        raises out of the first advance with every acquired ref rolled
        back), then the prompt chunk-prefills with a yield between chunks.
        From the first yield on, `slot_pages[slot]` owns every page ref, so
        cancelling the coroutine mid-insert cleans up via
        `_free_slot_pages(slot)` alone."""
        prompt = req.prompt
        plen = len(prompt)
        total = self._extra + plen
        ps = self.page_size
        # Positions ever written: prompt + every fed generated token. With
        # speculation on, verify rounds grow the table lazily (and roll a
        # rejected suffix's pages back), so insert covers the prompt only.
        cap = min(total if self.spec else total + req.max_new, self.max_len)
        blocks = -(-cap // ps) if self.alloc.pools else 0
        acquired: list = []
        state, prefix_len, pages = None, 0, []
        try:
            if self.prefix_cache is not None:
                entry = self.prefix_cache.match(prompt)
                if entry is not None and len(entry.tokens) >= self.prefix_min_len:
                    # O(1) splice: share the full pages, CoW the boundary page
                    prefix_len = len(entry.tokens)
                    pages = list(entry.pages)
                    self.alloc.retain(pages)
                    acquired.extend(pages)
                    if entry.tail_page is not None:
                        tail_src = entry.tail_page
                        self.alloc.retain([tail_src])   # survive a victim evict
                        try:
                            pages.append(self._cow_page(tail_src, acquired))
                        finally:
                            self.alloc.release([tail_src])
                        self.stats["cow_copies"] += 1
                    state = dict(entry.cache)
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_saved_tokens"] += prefix_len
                    self.tracer.instant("engine.prefix_hit", kind="engine",
                                        level=2, saved=prefix_len)
            if blocks > len(pages):
                pages = pages + self._ensure_pages(blocks - len(pages), acquired)
        except PagePoolExhausted:
            if acquired:                    # roll back the splice/CoW refs
                self.alloc.release(acquired)
            raise
        self.slot_pages[slot] = pages
        if state is None:
            state = self._init_state_sub()
            boundary = 0 if self.prefix_cache is None else \
                min(int(req.shared_len), plen - 1)
            if boundary >= self.prefix_min_len:
                _, state, lpos, first = yield from self._chunked_prefill_co(
                    slot, state, prompt[:boundary], 0)
                self._snapshot_prefix_paged(slot, prompt[:boundary], state,
                                            req=req)
                logits, state, lpos, first = yield from self._chunked_prefill_co(
                    slot, state, prompt[boundary:], lpos, first=first)
            else:
                logits, state, lpos, _ = yield from self._chunked_prefill_co(
                    slot, state, prompt, 0)
            self.stats["prefill_tokens"] += plen
        else:
            logits, state, lpos, _ = yield from self._chunked_prefill_co(
                slot, state, prompt[prefix_len:], self._extra + prefix_len)
            self.stats["prefill_tokens"] += plen - prefix_len
        self.cache = write_slot(self.cache, state, slot)
        self._pos_h[slot] = lpos
        return logits

    def _free_slot_pages(self, slot: int):
        if self.paged and self.slot_pages[slot]:
            self.alloc.release(self.slot_pages[slot])
            self.slot_pages[slot] = []

    def _page_table(self, width: int):
        """Page table truncated to the live rows' block high-water mark
        (pow2-bucketed by the caller): decode gathers — and attends — only
        the blocks actually in use instead of the full max_len slab."""
        tbl = np.full((self.slots, width), PAGE_SINK, np.int32)
        for s, pages in enumerate(self.slot_pages):
            if self._live[s]:
                tbl[s, :min(len(pages), width)] = pages[:width]
        return jnp.asarray(tbl)

    # ----------------------------------------------------------- prefill --

    def _insert_co(self, slot: int, req: Request):
        """Coroutine insert: run `req`'s (possibly chunked) prefill into
        `slot`, yielding between prefill units so `step()` can interleave
        decode of already-live slots with admission prefill — that
        interleaving is what bounds time-to-first-token for running
        requests (and p99 time-to-first-row upstream) under bursty intake.
        The slot goes live only on completion; mid-insert it is reserved
        via `self._inserting`. Driving the coroutine to exhaustion without
        observing the yields is exactly the old blocking insert."""
        prompt = req.prompt
        assert self._extra + len(prompt) <= self.max_len, (
            f"prompt ({len(prompt)} + {self._extra} image/frame tokens) "
            f"exceeds cache max_len={self.max_len}")
        co = (self._insert_paged_co if self.paged else self._insert_slab_co)
        logits = yield from co(slot, req)
        nxt = int(jnp.argmax(logits[0, -1]))
        self._tokens = self._tokens.at[slot, 0].set(nxt)
        req.out.append(nxt)
        self.active[slot] = req
        self._live[slot] = True
        if self.spec:
            self.drafter.on_insert(slot, req)
        self._note_kv_bytes()

    def _insert(self, slot: int, req: Request):
        """Blocking insert (legacy API, kept for tests/direct callers):
        drain the insert coroutine in one go."""
        for _ in self._insert_co(slot, req):
            pass

    def _note_kv_bytes(self):
        used = cache_nbytes(self.cache)
        if self.paged:
            used += self.alloc.nbytes_in_use
        elif self.prefix_cache is not None:
            used += self.prefix_cache.nbytes
        self.stats["kv_bytes_peak"] = max(self.stats["kv_bytes_peak"], used)

    # ------------------------------------------------------------- decode --

    def _finish(self, slot: int, req: Request):
        req.done = True
        req.finished_s = time.time()
        self.finished[req.rid] = req
        del self.active[slot]
        self._live[slot] = False
        self._free_slot_pages(slot)
        if self.spec:
            self.drafter.on_free(slot)

    def _step(self):
        if self.paged:
            write_ids = np.full((self.slots,), PAGE_SINK, np.int32)
            maxb = 1
            for s in range(self.slots):
                if self._live[s]:
                    maxb = max(maxb, len(self.slot_pages[s]))
                    b = int(self._pos_h[s]) // self.page_size
                    if b < len(self.slot_pages[s]):
                        write_ids[s] = self.slot_pages[s][b]
            width = min(_pow2_at_least(maxb), self.pages_per_slot)
            logits, self.cache, self.alloc.pools = self._paged_decode(
                self.params, self._tokens, self.cache, self.alloc.pools,
                self._page_table(width), jnp.asarray(write_ids))
            self._pos_h += 1
        else:
            logits, self.cache = self._decode(self.params, self._tokens, self.cache)
        self.stats["decode_steps"] += 1
        self.stats["decode_slot_steps"] += len(self.active)
        self.stats["max_live"] = max(self.stats["max_live"], len(self.active))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.out.append(tok)
            full = int(np.asarray(self.cache["pos"])[slot]) >= self.max_len - 1
            if tok == req.eos_id or len(req.out) >= req.max_new or full:
                self._finish(slot, req)
        self._tokens = jnp.asarray(nxt[:, None], jnp.int32)

    # ------------------------------------------------ speculative decode --

    def _verify_fn(self, n_ctx: int):
        """Jitted batched verify round for the paged layout: gather every
        live row's page-table context, run `verify_chunk` over all slots at
        once (per-row positions), scatter the dirtied blocks back. One jit
        signature per pow2-bucketed context width, like decode."""
        if n_ctx not in self._verify_fns:
            cfg, ps = self.cfg, self.page_size
            C = self.spec_k + 1
            nb = (C + ps - 2) // ps + 1 if ps > 1 else C
            has_pool = bool(self.alloc.pools)

            def fn(params, state, pools, ctx_tab, toks, wtabs, b0s):
                dense = dict(state)
                if has_pool:
                    dense.update(gather_page_views(pools, ctx_tab))
                logits, new, ckpts = verify_chunk(cfg, params,
                                                  {"tokens": toks}, dense,
                                                  constrain=self._constrain)
                new_state = {k: new[k] for k in state}
                if has_pool:
                    pools = scatter_chunk_pages_rows(pools, new, wtabs, b0s,
                                                     ps, nb)
                return (logits, self._with_specs(new_state, self._cache_pspecs),
                        self._with_specs(pools, self._pool_pspecs), ckpts)
            self._verify_fns[n_ctx] = (jax.jit(fn), nb)
        return self._verify_fns[n_ctx]

    def _spec_grow_pages(self, slot: int, upto: int) -> int:
        """Lazily extend a slot's page table to cover `upto` positions for
        this verify round (evicting LRU prefix entries under pressure).
        Returns the number of positions that actually fit — under hard pool
        exhaustion the round is clamped to the current allocation instead of
        failing, as long as at least the pending token fits."""
        ps = self.page_size
        pages = self.slot_pages[slot]
        need = min(-(-upto // ps), self.pages_per_slot)
        if need > len(pages):
            try:
                pages += self._ensure_pages(need - len(pages), [])
            except PagePoolExhausted:
                if len(pages) * ps <= int(self._pos_h[slot]):
                    raise               # not even the pending token fits
        return min(upto, len(pages) * ps, self.max_len)

    def _spec_clamp_drafts(self, live, pos_h, drafts):
        """Clamp each live slot's drafts to its page capacity, growing
        tables lazily. A slot whose *pending token* no longer fits (pool
        pinned by other live slots, prefix LRU drained) is evicted back to
        the queue via `drain_slot` — the engine's fail-visibly path, with
        retries bounded by `Request.max_retries` — freeing its pages so the
        other slots (and, later, the requeued request) can proceed.
        Returns the live list minus any drained slots."""
        kept = []
        for s in live:
            if self.alloc.pools:
                try:
                    fit = self._spec_grow_pages(s, int(pos_h[s]) + 1 +
                                                len(drafts[s]))
                except PagePoolExhausted:
                    self.drain_slot(s)
                    continue
                drafts[s] = drafts[s][: max(fit - int(pos_h[s]) - 1, 0)]
            kept.append(s)
        return kept

    def _spec_step(self):
        """One speculative round (replaces `_step` when `spec_decode` is
        on): draft up to k tokens per live slot, verify pending+drafts for
        every slot in ONE batched `verify_chunk` forward, emit the longest
        agreeing prefix plus the target's own next token, then roll rejected
        suffixes back — position truncation + page scrub/ref-release for
        attention KV, per-position state checkpoints for SSM/conv state —
        so the engine state is exactly what plain decode would have built."""
        C = self.spec_k + 1
        live = [s for s in range(self.slots) if self._live[s]]
        pos_h = (self._pos_h.astype(np.int64).copy() if self.paged else
                 np.asarray(self.cache["pos"]).astype(np.int64).copy())
        reqs = {s: self.active[s] for s in live}
        k_eff = {}
        for s in live:
            req, p0 = self.active[s], int(pos_h[s])
            k_eff[s] = max(0, min(self.spec_k,
                                  req.max_new - len(req.out) - 1,
                                  self.max_len - 1 - p0))
        drafts = self.drafter.draft_round(reqs, k_eff)
        for s in live:
            drafts[s] = list(drafts.get(s) or [])[: k_eff[s]]
        if self.paged:
            live = self._spec_clamp_drafts(live, pos_h, drafts)
            if not live:
                return                   # all slots drained; run() reinserts
        toks = np.zeros((self.slots, C), np.int64)
        true_c = {}
        for s in live:
            row = [self.active[s].out[-1]] + drafts[s]
            true_c[s] = len(row)
            toks[s, :len(row)] = row

        if self.paged:
            ps = self.page_size
            nb_probe = (C + ps - 2) // ps + 1 if ps > 1 else C
            need_ctx = 1
            for s in live:
                p0 = int(pos_h[s])
                need_ctx = max(need_ctx, -(-(p0 + C) // ps),
                               p0 // ps + nb_probe)
            n_ctx = _pow2_at_least(need_ctx)
            fn, nb = self._verify_fn(n_ctx)
            ctx = np.full((self.slots, n_ctx), PAGE_SINK, np.int32)
            wtabs = np.full((self.slots, nb), PAGE_SINK, np.int32)
            b0s = np.zeros((self.slots,), np.int32)
            for s in live:
                pages = self.slot_pages[s]
                ctx[s, :min(len(pages), n_ctx)] = pages[:n_ctx]
                b0 = min(int(pos_h[s]) // ps, n_ctx - nb)
                b0s[s] = b0
                for j in range(nb):
                    b = b0 + j
                    if b < len(pages):
                        wtabs[s, j] = pages[b]
            logits, new_state, self.alloc.pools, ckpts = fn(
                self.params, self.cache, self.alloc.pools,
                jnp.asarray(ctx), jnp.asarray(toks, jnp.int32),
                jnp.asarray(wtabs), jnp.asarray(b0s))
            cache = dict(self.cache)
            cache.update(new_state)
        else:
            logits, cache, ckpts = self._verify_slab(
                self.params, jnp.asarray(toks, jnp.int32), self.cache)
            cache = dict(cache)

        self.stats["decode_steps"] += 1
        self.stats["spec_rounds"] += 1
        self.stats["decode_slot_steps"] += len(live)
        self.stats["max_live"] = max(self.stats["max_live"], len(live))

        Y = np.asarray(jnp.argmax(logits, axis=-1))          # (slots, C)
        new_pos = pos_h.copy()
        nxt = np.asarray(self._tokens[:, 0]).copy()
        keeps = np.ones((self.slots,), np.int32)
        restore = np.zeros((self.slots,), bool)
        for s in live:
            req, d, p0 = self.active[s], drafts[s], int(pos_h[s])
            m = 0
            while m < len(d) and int(Y[s, m]) == d[m]:
                m += 1
            emitted = d[:m] + [int(Y[s, m])]
            done, n_app = False, 0
            for i, t in enumerate(emitted):
                req.out.append(t)
                n_app = i + 1
                if t == req.eos_id or len(req.out) >= req.max_new or \
                        p0 + i + 1 >= self.max_len - 1:
                    done = True
                    break
            keep = n_app
            self.stats["draft_tokens"] += len(d)
            # count only accepted tokens actually emitted: when EOS/max_new/
            # max_len truncates mid-prefix, the tail never reached the output
            self.stats["accepted_tokens"] += min(m, n_app)
            req.draft_tokens += len(d)
            req.accepted_tokens += min(m, n_app)
            self.stats["decode_steps_saved"] += n_app - 1
            if not done and "ssm" in ckpts:
                keeps[s] = keep                  # batched restore below
                restore[s] = True
            new_pos[s] = p0 + keep
            if done:
                self._finish(s, req)
            else:
                nxt[s] = emitted[-1]
                if self.paged and self.alloc.pools:
                    # page-truncate + ref-release the rejected suffix
                    pages = self.slot_pages[s]
                    end = min(p0 + true_c[s], len(pages) * self.page_size)
                    if p0 + keep < end:
                        self.alloc.pools = truncate_pages(
                            self.alloc.pools, pages, p0 + keep, end,
                            self.page_size)
                    self.slot_pages[s] = release_trailing_pages(
                        self.alloc, pages, -(-(p0 + keep) // self.page_size))
        if restore.any():
            # mid-sequence checkpoint restore: state exactly as after
            # sequentially decoding each row's kept tokens
            cache["ssm"], cache["conv"] = _restore_ckpt_rows(
                cache["ssm"], cache["conv"], ckpts["ssm"], ckpts["conv"],
                jnp.asarray(keeps), jnp.asarray(restore))
        cache["pos"] = jnp.asarray(new_pos, jnp.int32)
        self.cache = cache
        if self.paged:
            self._pos_h = new_pos
        self._tokens = jnp.asarray(nxt[:, None], jnp.int32)
        self._note_kv_bytes()

    def drain_slot(self, slot: int):
        """Evict + requeue (straggler/failure mitigation). Retries are
        bounded: past `req.max_retries` the request fails visibly into
        `self.failed` instead of requeueing forever."""
        if slot in self.active:
            req = self.active.pop(slot)
            self._live[slot] = False
            self._free_slot_pages(slot)
            if self.spec:
                self.drafter.on_free(slot)
            req.out.clear()
            req.retries += 1
            self.stats["evictions"] += 1
            self.tracer.instant("engine.evict", kind="engine", level=2,
                                rid=req.rid, retries=req.retries)
            if req.retries > req.max_retries:
                req.error = (f"evicted {req.retries} times "
                             f"(max_retries={req.max_retries})")
                self.failed[req.rid] = req
                self.stats["failures"] += 1
            else:
                self.queue.appendleft(req)

    # ------------------------------------------------- non-blocking API ---

    def _free_slot(self) -> Optional[int]:
        """Lowest slot that is neither live nor mid-insert, or None."""
        for s in range(self.slots):
            if not self._live[s] and s not in self._inserting:
                return s
        return None

    @property
    def free_slots(self) -> int:
        return self.slots - len(self.active) - len(self._inserting)

    def estimate_pages(self, prompt_len: int, max_new: int) -> int:
        """Pages the paged insert will demand up front for a prompt of this
        shape (0 for the slab layout / stateless families) — the admission
        headroom check `serving/frontend.py` gates on."""
        if not (self.paged and self.alloc.pools):
            return 0
        total = self._extra + prompt_len
        cap = min(total if self.spec else total + max_new, self.max_len)
        return -(-cap // self.page_size)

    def pool_free_pages(self) -> Optional[int]:
        """Free pages in the KV pool (None off-paged) — interface shared
        with `ReplicaGroup` so the frontend gates either uniformly."""
        if not (self.paged and self.alloc.pools):
            return None
        return self.alloc.free_pages

    def _advance_insert(self, slot: int, req: Request, gen, budget):
        """Drive one insert coroutine until it completes or `budget`
        prefill units are consumed (None = unbounded). Completion removes
        it from `_inserting`; pool exhaustion rolls the slot's page refs
        back and requeues the request at the queue head (the caller decides
        defer vs raise). Returns the remaining budget."""
        try:
            while budget is None or budget > 0:
                next(gen)
                if budget is not None:
                    budget -= 1
        except StopIteration:
            self._inserting.pop(slot, None)
        except PagePoolExhausted:
            self._inserting.pop(slot, None)
            self._free_slot_pages(slot)
            # keep the request visible: it is back at the queue head,
            # never silently dropped (PR 2 hardening contract)
            self.queue.appendleft(req)
            raise
        return budget

    def poll(self, rid: int) -> Optional[Request]:
        """Non-blocking result check: the resolved Request once it has
        finished, failed, or been cancelled; None while still in flight."""
        for d in (self.finished, self.failed, self.cancelled):
            if rid in d:
                return d[rid]
        return None

    def _resolve_cancelled(self, req: Request):
        req.error = "cancelled"
        req.finished_s = time.time()
        self.cancelled[req.rid] = req
        self.stats["cancelled"] += 1

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it is in the lifecycle — queued,
        mid-insert, or actively decoding — releasing every resource it
        holds (slot, paged-KV refs, drafter state). The request resolves
        into `self.cancelled` with error='cancelled'. Returns False when
        `rid` is unknown or already resolved (cancel lost the race)."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                self._resolve_cancelled(req)
                return True
        for slot, (req, gen) in list(self._inserting.items()):
            if req.rid == rid:
                gen.close()                      # abandon mid-chunk prefill
                del self._inserting[slot]
                self._free_slot_pages(slot)
                self._resolve_cancelled(req)
                return True
        for slot, req in list(self.active.items()):
            if req.rid == rid:
                del self.active[slot]
                self._live[slot] = False
                self._free_slot_pages(slot)
                if self.spec:
                    self.drafter.on_free(slot)
                req.out.clear()
                self._resolve_cancelled(req)
                return True
        return False

    # --------------------------------------------------------------- run ---

    def step(self, *, max_prefill_chunks: Optional[int] = None,
             defer_admission: bool = False) -> bool:
        """One continuous-batching round: resume in-flight chunked inserts,
        admit queued requests into free slots, then run one batched
        decode/verify phase. Returns whether work remains. `run()` is a
        loop over this; `serving/replicas.py` drives several engines'
        step() interleaved off a shared queue; `serving/frontend.py` pumps
        it with both knobs set.

        max_prefill_chunks: cap on prefill units (chunked-prefill calls /
        slab token-steps) this round. Admission prefill becomes incremental:
        a long prompt spreads over several rounds while already-live slots
        keep decoding — bounding their inter-token latency. None (default)
        drains every insert within the round, byte-identical to the old
        blocking behaviour.
        defer_admission: turn PagePoolExhausted during admission into
        backpressure — the request stays at the queue head, the round keeps
        decoding live slots (which will release pages as they finish), and
        stats['admission_deferred'] counts the stall. The exception still
        raises when nothing is live or inserting, i.e. waiting could never
        free a page (and always with the default defer_admission=False)."""
        budget = max_prefill_chunks
        for slot in sorted(self._inserting):
            if budget is not None and budget <= 0:
                break
            req, gen = self._inserting[slot]
            try:
                budget = self._advance_insert(slot, req, gen, budget)
            except PagePoolExhausted:
                if defer_admission and (self.active or self._inserting):
                    self.stats["admission_deferred"] += 1
                    self.tracer.instant("engine.admission_deferred",
                                        kind="engine", level=2, rid=req.rid)
                else:
                    raise
        while self.queue and (budget is None or budget > 0):
            slot = self._free_slot()
            if slot is None:
                break
            req = self.queue.popleft()
            gen = self._insert_co(slot, req)
            self._inserting[slot] = (req, gen)
            try:
                budget = self._advance_insert(slot, req, gen, budget)
            except PagePoolExhausted:
                if defer_admission and (self.active or self._inserting):
                    # backpressure, not failure: decode below frees pages
                    self.stats["admission_deferred"] += 1
                    self.tracer.instant("engine.admission_deferred",
                                        kind="engine", level=2, rid=req.rid)
                    break
                raise
        if self.active:
            if self.tracer.enabled(2):
                name = "engine.verify_round" if self.spec else \
                    "engine.decode_step"
                with self.tracer.span(name, kind="engine", level=2,
                                      live=len(self.active)):
                    self._spec_step() if self.spec else self._step()
            else:
                self._spec_step() if self.spec else self._step()
        return bool(self.queue or self.active or self._inserting)

    def run(self, max_steps: int = 10_000, *, strict: bool = True):
        """Drain the queue. If `max_steps` is exhausted with requests still
        queued/active the run is *truncated*: stats["truncations"] is bumped
        and, under `strict` (default), `RunTruncated` is raised — partial
        results must never read as complete."""
        self.stats["runs"] += 1
        with self.tracer.span("engine.run", kind="engine",
                              queued=len(self.queue)):
            while (self.queue or self.active or self._inserting) and \
                    max_steps > 0:
                max_steps -= 1
                self.step()
        if self.queue or self.active or self._inserting:
            self.stats["truncations"] += 1
            if strict:
                raise RunTruncated(
                    f"run() truncated at max_steps with {len(self.active)} "
                    f"active and {len(self.queue)} queued requests",
                    self.finished)
        return self.finished
