"""Batched serving engine with continuous batching (slot-based).

Requests prefill individually (exact length — correct for SSM state too),
land in a slot of the batched decode cache, and decode advances all live
slots each step with per-row cache positions (see layers.cache_write).
Finished rows free their slot immediately for queued requests — the
"extraction operator fleet" behaviour QUEST's per-document plans produce
(heterogeneous short extraction calls).

Fault tolerance: `drain_slot` evicts a request (e.g. on a simulated worker
failure) and requeues it; the scheduler resubmits from the prompt.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_decode_cache, prefill
from repro.models.config import ModelConfig
from repro.data import lm_data


@dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    eos_id: int = lm_data.EOS
    out: list = field(default_factory=list)
    done: bool = False
    submitted_s: float = 0.0
    finished_s: float = 0.0
    retries: int = 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 queue_depth: Optional[int] = None):
        """queue_depth: optional admission-control bound on queued requests;
        ServedExtractor splits its batch rounds into windows of this size
        (None = unbounded)."""
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.queue_depth = queue_depth
        self.queue: deque = deque()
        self.active: dict = {}          # slot -> Request
        self.finished: dict = {}
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "evictions": 0,
                      "runs": 0, "max_live": 0, "decode_slot_steps": 0}

        self.cache = init_decode_cache(cfg, slots, max_len)
        self.cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self._live = np.zeros((slots,), bool)
        self._tokens = jnp.zeros((slots, 1), jnp.int32)

        self._decode = jax.jit(partial(decode_step, cfg))
        self._prefill_cache = {}

    # ------------------------------------------------------------ intake --

    def submit(self, req: Request):
        if self.queue_depth is not None and len(self.queue) >= self.queue_depth:
            raise RuntimeError(
                f"serving queue full ({len(self.queue)} >= {self.queue_depth})")
        req.submitted_s = time.time()
        self.queue.append(req)

    def submit_many(self, reqs):
        """All-or-nothing admission: never leaves a batch half-enqueued."""
        reqs = list(reqs)
        if self.queue_depth is not None and \
                len(self.queue) + len(reqs) > self.queue_depth:
            raise RuntimeError(
                f"serving queue full ({len(self.queue)} + {len(reqs)} > "
                f"{self.queue_depth})")
        for req in reqs:
            req.submitted_s = time.time()
            self.queue.append(req)

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            self._prefill_cache[length] = jax.jit(
                partial(prefill, self.cfg, max_len=self.max_len))
        return self._prefill_cache[length]

    def _insert(self, slot: int, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        batch = {"tokens": toks}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros((1, self.cfg.encoder_seq, self.cfg.d_model),
                                        jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "vlm":
            from repro.models.model import VISION_DIM
            batch["image_embeds"] = jnp.zeros((1, self.cfg.n_image_tokens, VISION_DIM),
                                              jnp.float32)
        logits, c1 = self._prefill_fn(toks.shape[1])(self.params, batch)
        self.stats["prefill_tokens"] += toks.shape[1]

        def put(dst, src):
            # stacked caches: (L, B, ...) — batch dim is axis 1
            return dst.at[:, slot].set(src[:, 0])

        new_cache = dict(self.cache)
        for k in self.cache:
            if k == "pos":
                continue
            new_cache[k] = put(self.cache[k], c1[k])
        new_cache["pos"] = self.cache["pos"].at[slot].set(int(c1["pos"]))
        self.cache = new_cache
        nxt = int(jnp.argmax(logits[0, -1]))
        self._tokens = self._tokens.at[slot, 0].set(nxt)
        req.out.append(nxt)
        self.active[slot] = req
        self._live[slot] = True

    # ------------------------------------------------------------- decode --

    def _step(self):
        logits, self.cache = self._decode(self.params, self._tokens, self.cache)
        self.stats["decode_steps"] += 1
        self.stats["decode_slot_steps"] += len(self.active)
        self.stats["max_live"] = max(self.stats["max_live"], len(self.active))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.out.append(tok)
            full = int(np.asarray(self.cache["pos"])[slot]) >= self.max_len - 1
            if tok == req.eos_id or len(req.out) >= req.max_new or full:
                req.done = True
                req.finished_s = time.time()
                self.finished[req.rid] = req
                del self.active[slot]
                self._live[slot] = False
        self._tokens = jnp.asarray(nxt[:, None], jnp.int32)

    def drain_slot(self, slot: int):
        """Evict + requeue (straggler/failure mitigation)."""
        if slot in self.active:
            req = self.active.pop(slot)
            self._live[slot] = False
            req.out.clear()
            req.retries += 1
            self.stats["evictions"] += 1
            self.queue.appendleft(req)

    # --------------------------------------------------------------- run ---

    def run(self, max_steps: int = 10_000):
        self.stats["runs"] += 1
        while (self.queue or self.active) and max_steps > 0:
            max_steps -= 1
            while self.queue and not self._live.all():
                slot = int(np.argmin(self._live))
                self._insert(slot, self.queue.popleft())
            if self.active:
                self._step()
        return self.finished
