"""Batched serving engine with continuous batching (slot-based).

Requests prefill individually (exact length — correct for SSM state too),
land in a slot of the batched decode cache, and decode advances all live
slots each step with per-row cache positions (see layers.cache_write).
Finished rows free their slot immediately for queued requests — the
"extraction operator fleet" behaviour QUEST's per-document plans produce
(heterogeneous short extraction calls).

Shared-prefix KV reuse (DESIGN.md §10): with `prefix_cache` enabled, a
request that declares a shareable prompt boundary (`Request.shared_len`)
prefills in two phases — the shared prefix through the standard prefill
(snapshotted into the cache the first time), then the per-request suffix
token-by-token through the decode step, which is exact for every family
(attention KV is position-indexed; SSM/conv state advances through the
same recurrence decode uses). A later request whose prompt extends a
cached prefix copies the snapshot into its slot and prefills only the
unshared suffix. Saved prefill tokens are reported separately
(`stats["prefix_saved_tokens"]`); decoded outputs are identical with the
cache on or off (tests/test_prefix_cache.py).

Fault tolerance: `drain_slot` evicts a request (e.g. on a simulated worker
failure) and requeues it; the scheduler resubmits from the prompt. Retries
are bounded by `Request.max_retries` — beyond it the request fails visibly
into `engine.failed` instead of looping forever. `run()` raises
`RunTruncated` (strict default) when `max_steps` is exhausted with work
still queued/active, so callers can never mistake partial results for
complete ones.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_decode_cache, prefill
from repro.models.cache_ops import expand_snapshot, prefix_snapshot, write_slot
from repro.models.config import ModelConfig
from repro.data import lm_data
from .prefix_cache import PrefixCache


@dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    eos_id: int = lm_data.EOS
    shared_len: int = 0      # prompt[:shared_len] is shareable across requests
    max_retries: int = 3     # drain_slot evictions tolerated before failing
    out: list = field(default_factory=list)
    done: bool = False
    submitted_s: float = 0.0
    finished_s: float = 0.0
    retries: int = 0
    error: Optional[str] = None


class RunTruncated(RuntimeError):
    """`run()` exhausted max_steps with requests still queued/active."""

    def __init__(self, msg: str, finished: dict):
        super().__init__(msg)
        self.finished = finished


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 queue_depth: Optional[int] = None,
                 prefix_cache: Union[bool, PrefixCache, None] = False,
                 prefix_min_len: int = 8):
        """queue_depth: optional admission-control bound on queued requests;
        ServedExtractor splits its batch rounds into windows of this size
        (None = unbounded).
        prefix_cache: shared-prefix KV reuse — False/None off, True for a
        default `PrefixCache()`, or a configured instance.
        prefix_min_len: shortest prefix worth snapshotting/copying."""
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.queue_depth = queue_depth
        if isinstance(prefix_cache, PrefixCache):   # may be empty, i.e. falsy
            self.prefix_cache: Optional[PrefixCache] = prefix_cache
        else:
            self.prefix_cache = PrefixCache() if prefix_cache else None
        self.prefix_min_len = max(1, int(prefix_min_len))
        self.queue: deque = deque()
        self.active: dict = {}          # slot -> Request
        self.finished: dict = {}
        self.failed: dict = {}          # rid -> Request (retry cap exceeded)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "evictions": 0,
                      "runs": 0, "max_live": 0, "decode_slot_steps": 0,
                      "prefix_hits": 0, "prefix_saved_tokens": 0,
                      "prefix_inserts": 0, "truncations": 0, "failures": 0}

        self.cache = init_decode_cache(cfg, slots, max_len)
        self.cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self._live = np.zeros((slots,), bool)
        self._tokens = jnp.zeros((slots, 1), jnp.int32)

        self._decode = jax.jit(partial(decode_step, cfg))
        self._prefill_cache = {}

    # ------------------------------------------------------------ intake --

    def submit(self, req: Request):
        if self.queue_depth is not None and len(self.queue) >= self.queue_depth:
            raise RuntimeError(
                f"serving queue full ({len(self.queue)} >= {self.queue_depth})")
        req.submitted_s = time.time()
        self.queue.append(req)

    def submit_many(self, reqs):
        """All-or-nothing admission: never leaves a batch half-enqueued."""
        reqs = list(reqs)
        if self.queue_depth is not None and \
                len(self.queue) + len(reqs) > self.queue_depth:
            raise RuntimeError(
                f"serving queue full ({len(self.queue)} + {len(reqs)} > "
                f"{self.queue_depth})")
        for req in reqs:
            req.submitted_s = time.time()
            self.queue.append(req)

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            self._prefill_cache[length] = jax.jit(
                partial(prefill, self.cfg, max_len=self.max_len))
        return self._prefill_cache[length]

    # ----------------------------------------------------------- prefill --

    def _prefill_sub(self, tokens: list):
        """Standard exact-length prefill of `tokens` into a B=1 sub-cache.
        Returns (last-position logits, sub-cache)."""
        toks = jnp.asarray(tokens, jnp.int32)[None, :]
        batch = {"tokens": toks}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros((1, self.cfg.encoder_seq, self.cfg.d_model),
                                        jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "vlm":
            from repro.models.model import VISION_DIM
            batch["image_embeds"] = jnp.zeros((1, self.cfg.n_image_tokens, VISION_DIM),
                                              jnp.float32)
        return self._prefill_fn(toks.shape[1])(self.params, batch)

    def _suffix_prefill(self, sub: dict, tokens: list):
        """Advance a B=1 sub-cache through the unshared prompt suffix, one
        exact decode step per token (position-indexed KV writes; the same
        recurrence decode uses, so SSM/conv state stays correct). Returns
        (last-token logits, sub-cache)."""
        logits = None
        for t in tokens:
            logits, sub = self._decode(self.params,
                                       jnp.asarray([[t]], jnp.int32), sub)
        return logits, sub

    def _insert(self, slot: int, req: Request):
        prompt = req.prompt
        assert len(prompt) <= self.max_len, (
            f"prompt ({len(prompt)}) exceeds cache max_len={self.max_len}")
        sub, prefix_len = None, 0
        if self.prefix_cache is not None:
            entry = self.prefix_cache.match(prompt)
            if entry is not None and len(entry.tokens) >= self.prefix_min_len:
                prefix_len = len(entry.tokens)
                sub = expand_snapshot(entry.cache, self.max_len)
                self.stats["prefix_hits"] += 1
                self.stats["prefix_saved_tokens"] += prefix_len
            else:
                # first request of a prefix group: prefill the shared prefix
                # exactly (state-correct snapshot boundary), then continue
                boundary = min(int(req.shared_len), len(prompt) - 1)
                if boundary >= self.prefix_min_len:
                    _, sub = self._prefill_sub(prompt[:boundary])
                    self.stats["prefill_tokens"] += boundary
                    self.prefix_cache.insert(
                        prompt[:boundary], prefix_snapshot(sub, boundary))
                    self.stats["prefix_inserts"] += 1
                    prefix_len = boundary
        if sub is None:
            logits, sub = self._prefill_sub(prompt)
            self.stats["prefill_tokens"] += len(prompt)
        else:
            logits, sub = self._suffix_prefill(sub, prompt[prefix_len:])
            self.stats["prefill_tokens"] += len(prompt) - prefix_len
        self.cache = write_slot(self.cache, sub, slot)
        nxt = int(jnp.argmax(logits[0, -1]))
        self._tokens = self._tokens.at[slot, 0].set(nxt)
        req.out.append(nxt)
        self.active[slot] = req
        self._live[slot] = True

    # ------------------------------------------------------------- decode --

    def _step(self):
        logits, self.cache = self._decode(self.params, self._tokens, self.cache)
        self.stats["decode_steps"] += 1
        self.stats["decode_slot_steps"] += len(self.active)
        self.stats["max_live"] = max(self.stats["max_live"], len(self.active))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.out.append(tok)
            full = int(np.asarray(self.cache["pos"])[slot]) >= self.max_len - 1
            if tok == req.eos_id or len(req.out) >= req.max_new or full:
                req.done = True
                req.finished_s = time.time()
                self.finished[req.rid] = req
                del self.active[slot]
                self._live[slot] = False
        self._tokens = jnp.asarray(nxt[:, None], jnp.int32)

    def drain_slot(self, slot: int):
        """Evict + requeue (straggler/failure mitigation). Retries are
        bounded: past `req.max_retries` the request fails visibly into
        `self.failed` instead of requeueing forever."""
        if slot in self.active:
            req = self.active.pop(slot)
            self._live[slot] = False
            req.out.clear()
            req.retries += 1
            self.stats["evictions"] += 1
            if req.retries > req.max_retries:
                req.error = (f"evicted {req.retries} times "
                             f"(max_retries={req.max_retries})")
                self.failed[req.rid] = req
                self.stats["failures"] += 1
            else:
                self.queue.appendleft(req)

    # --------------------------------------------------------------- run ---

    def run(self, max_steps: int = 10_000, *, strict: bool = True):
        """Drain the queue. If `max_steps` is exhausted with requests still
        queued/active the run is *truncated*: stats["truncations"] is bumped
        and, under `strict` (default), `RunTruncated` is raised — partial
        results must never read as complete."""
        self.stats["runs"] += 1
        while (self.queue or self.active) and max_steps > 0:
            max_steps -= 1
            while self.queue and not self._live.all():
                slot = int(np.argmin(self._live))
                self._insert(slot, self.queue.popleft())
            if self.active:
                self._step()
        if self.queue or self.active:
            self.stats["truncations"] += 1
            if strict:
                raise RunTruncated(
                    f"run() truncated at max_steps with {len(self.active)} "
                    f"active and {len(self.queue)} queued requests",
                    self.finished)
        return self.finished
