"""Speculative decoding drafters (DESIGN.md §14).

QUEST's serving bottleneck after batching/prefix-reuse/paged-prefill is the
decode loop itself: one target-model invocation per generated token. In the
extraction workload the output is overwhelmingly text that already sits in
the prompt (the retrieved evidence segments), which is the ideal regime for
*draft/verify* decoding: a cheap drafter proposes k continuation tokens,
the target model scores all of them in ONE `verify_chunk` forward, and the
longest agreeing prefix is accepted plus one bonus token — so every verify
round emits between 1 and k+1 tokens at one target invocation, and greedy
output is byte-identical to plain decode by construction (every accepted
token equals the target's own greedy choice; the first disagreement is
replaced by it).

Two drafters, pluggable behind the engine's `spec_decode=` knob:

  PromptLookupDrafter — n-gram lookup over the request's own context
      (prompt + generated so far): match the trailing n-gram, propose the
      tokens that followed its most recent earlier occurrence. Zero model
      cost; wins whenever the model copies spans from the prompt or repeats
      itself. Among same-length matches the most recent wins, but a match
      with a longer available continuation is preferred (a rightmost match
      near the end of the sequence can only propose a truncated draft).

  DraftModelDrafter — a second, small engine-managed model (a zoo config)
      decodes the proposals. The draft keeps its own slab decode cache,
      batched over the engine's slots; after each verify round it is rolled
      back to the longest prefix of its fed tokens that the target actually
      kept (attention-family drafts only: rollback is a position reset, the
      pos-gated masks hide the rejected KV).

Drafters see the engine through a narrow protocol: `on_insert(slot, req)` /
`on_free(slot)` track slot lifecycle, `draft_round(reqs, k_eff)` returns
{slot: [token, ...]} proposals (len <= k_eff[slot]). Any object with that
shape can be passed as `spec_decode=` (tests inject adversarial drafters).
A drafter is *advisory*: wrong proposals cost wasted verify positions,
never wrong output.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_decode_cache, prefill
from repro.models.cache_ops import write_slot
from repro.models.config import ModelConfig


def prompt_lookup(context: list, k: int, ngram: int = 3) -> list:
    """Propose up to `k` tokens continuing `context` by n-gram lookup.

    Tries the longest n-gram first (n = `ngram` down to 1); for a given n,
    scans matches from most recent to oldest and keeps the first one with a
    full k-token continuation, falling back to the longest continuation
    seen. Contexts shorter than the n-gram window simply try shorter
    n-grams (and return [] when nothing matches). Never proposes past the
    end of the context."""
    n_ctx = len(context)
    for n in range(min(ngram, n_ctx - 1), 0, -1):
        g = tuple(context[-n:])
        best = None
        for i in range(n_ctx - n - 1, -1, -1):
            if tuple(context[i:i + n]) == g:
                cont = context[i + n:i + n + k]
                if best is None or len(cont) > len(best):
                    best = cont
                if len(cont) == k:
                    break
        if best:
            return list(best)
    return []


class PromptLookupDrafter:
    """Model-free drafting from the request's own token context."""

    def __init__(self, *, ngram: int = 3):
        self.ngram = max(1, int(ngram))
        self.stats = {"draft_model_steps": 0}

    def on_insert(self, slot: int, req) -> None:
        pass

    def on_free(self, slot: int) -> None:
        pass

    def draft_round(self, reqs: dict, k_eff: dict) -> dict:
        out = {}
        for slot, req in reqs.items():
            k = k_eff.get(slot, 0)
            if k <= 0:
                out[slot] = []
                continue
            context = list(req.prompt) + list(req.out)
            out[slot] = prompt_lookup(context, k, self.ngram)
        return out


class DraftModelDrafter:
    """Draft-model drafting: a small second model proposes continuations.

    The draft model runs its own batched slab decode cache (one row per
    engine slot). Each round it first catches up on tokens the target fed
    that the draft has not (at most the previous round's last draft token,
    on full acceptance), then feeds the pending token and k-1 of its own
    greedy proposals to produce k draft tokens. Rows are resynchronized to
    the target's kept history by common-prefix comparison at the start of
    every round, which makes rollback self-healing across partial
    acceptance, drain/requeue, and slot reuse.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int,
                 max_len: int, chunk_size: int = 32, mesh=None):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"draft model family must be dense/moe (attention KV rollback "
                f"is a position reset); got {cfg.family!r}")
        self.cfg = cfg
        if mesh is not None:
            # mesh-aware engines (DESIGN.md §15) shard the draft model with
            # the same FSDP+TP rules as the target; the draft's slab cache
            # stays small enough to leave replicated
            from repro.distributed.sharding import param_shardings
            params = jax.device_put(params,
                                    param_shardings(cfg, params, mesh))
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.chunk_size = max(1, int(chunk_size))
        self.cache = init_decode_cache(cfg, slots, max_len)
        self.cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self._decode = jax.jit(partial(decode_step, cfg))
        # one jitted prefill; chunk_size-bucketed padding below bounds the
        # distinct input shapes (and hence traces) it ever sees
        self._prefill = jax.jit(partial(prefill, self.cfg, max_len=max_len))
        self._hist: dict = {s: [] for s in range(slots)}   # tokens fed per row
        self.stats = {"draft_model_steps": 0, "draft_prefill_tokens": 0}

    # ---------------------------------------------------------- lifecycle --

    def on_insert(self, slot: int, req) -> None:
        prompt = [int(t) % self.cfg.vocab_size for t in req.prompt]
        n = len(prompt)
        assert n < self.max_len, (
            f"prompt ({n}) exceeds draft cache max_len={self.max_len}")
        b = self.chunk_size
        bucket = min(((n + b - 1) // b) * b, self.max_len)
        toks = jnp.asarray(prompt + [0] * (bucket - n), jnp.int32)[None, :]
        _, sub = self._prefill(self.params, {"tokens": toks},
                               length=jnp.asarray(n, jnp.int32))
        self.cache = write_slot(self.cache, sub, slot)
        self._hist[slot] = prompt
        self.stats["draft_prefill_tokens"] += n
    def on_free(self, slot: int) -> None:
        self._hist[slot] = []

    # ----------------------------------------------------------- drafting --

    def draft_round(self, reqs: dict, k_eff: dict) -> dict:
        V = self.cfg.vocab_size
        feeds, props, want = {}, {}, {}
        for slot, req in reqs.items():
            # resync: the longest prefix of this row's fed tokens that is
            # still the target's kept history (rollback after rejection)
            target = ([int(t) % V for t in req.prompt] +
                      [int(t) % V for t in req.out[:-1]])
            hist = self._hist[slot]
            v = 0
            while v < len(hist) and v < len(target) and hist[v] == target[v]:
                v += 1
            self._hist[slot] = hist = target[:v]
            lag = target[v:]
            k = min(k_eff.get(slot, 0),
                    self.max_len - 1 - len(target) - 1)
            props[slot] = []
            if k <= 0:
                feeds[slot] = []
                want[slot] = 0
                continue
            pending = int(req.out[-1]) % V
            feeds[slot] = lag + [pending]
            want[slot] = k
        steps = max((len(feeds[s]) + max(want[s] - 1, 0)
                     for s in feeds), default=0)
        if steps == 0:
            return props
        # roll every participating row back to its valid fed length
        pos = np.asarray(self.cache["pos"]).copy()
        for slot in feeds:
            pos[slot] = len(self._hist[slot])
        self.cache["pos"] = jnp.asarray(pos, jnp.int32)
        for _ in range(steps):
            row_tok = np.zeros((self.slots, 1), np.int64)
            fed_now = {}
            for slot in feeds:
                if feeds[slot]:
                    tok = feeds[slot].pop(0)
                elif len(props[slot]) < want[slot] and props[slot]:
                    tok = props[slot][-1]
                else:
                    continue                     # row done: dummy zero feed
                row_tok[slot, 0] = tok
                fed_now[slot] = tok
                self._hist[slot].append(tok)
            logits, self.cache = self._decode(
                self.params, jnp.asarray(row_tok, jnp.int32), self.cache)
            self.stats["draft_model_steps"] += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            for slot in list(fed_now):
                if not feeds[slot] and len(props[slot]) < want[slot]:
                    props[slot].append(int(nxt[slot]))
        # drop rows' pos back to their true fed length (dummy feeds advanced
        # every row; garbage KV past pos is masked and overwritten later)
        pos = np.asarray(self.cache["pos"]).copy()
        for slot in props:
            pos[slot] = len(self._hist[slot])
        self.cache["pos"] = jnp.asarray(pos, jnp.int32)
        return props
