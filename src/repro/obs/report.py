"""EXPLAIN ANALYZE (DESIGN.md §19): join the optimizer's estimates with
what the query actually did.

`QueryHandle.report()` calls `build_report(handle)` after the query
completes. The estimated side is `Session._explain()` — per-stage
selectivity and mean cost from the sampling investment (re-read at report
time, i.e. with this query's own sampling folded in, which is exactly
what its per-document plans were built from). The actual side is pulled
from three places the run already maintains:

  * per-attr token/call columns on the query's child ledger
    (`CostLedger.per_attr` / `per_attr_calls`, charged at the scheduler's
    extraction sites);
  * per-filter evaluation counts on the `QueryRun`
    (`filter_evals[(table, filter)] = [evaluated, passed]`, bumped in
    `_eval_plan_co` where the short-circuit actually decided);
  * the ledger's savings columns (prefix/spec/cascade) and, when a tracer
    is attached, per-kind wall attribution from the span stream.

This closes the loop the paper's cost model needs: estimated vs. actual
selectivity per stage is the direct residual of the sample statistics,
and tokens-per-invocation vs. `mean_cost_tokens` is the residual of the
cost model.
"""
from __future__ import annotations


def _stage_actuals(run, ledger, table: str, stage: dict) -> dict:
    attr = stage["attr"]
    evals = run.filter_evals.get((table, stage["filter"]))
    evaluated, passed = evals if evals else (0, 0)
    tokens = ledger.per_attr.get(attr, 0)
    calls = ledger.per_attr_calls.get(attr, 0)
    return {
        "filter": stage["filter"],
        "attr": attr,
        "est_selectivity": stage["selectivity"],
        "actual_selectivity": (round(passed / evaluated, 4)
                               if evaluated else None),
        "evaluated": evaluated,
        "passed": passed,
        "est_cost_tokens": stage["mean_cost_tokens"],
        "actual_tokens": tokens,
        "invocations": calls,
        "actual_tokens_per_call": (round(tokens / calls, 2)
                                   if calls else None),
        "predicted_tier_split": stage.get("predicted_tier_split"),
    }


def build_report(handle) -> dict:
    """Estimated-vs-actual post-query report for a finished QueryHandle."""
    if not handle.done:
        raise RuntimeError(
            f"query {handle.qid} still in flight — report() joins "
            f"estimates with actuals, so it needs the query finished")
    session = handle.session
    ledger = handle.ledger
    plan = session._explain(handle.query)
    run = handle.run
    snap = ledger.snapshot()
    tables = []
    for t in plan["tables"]:
        entry = {
            "table": t["table"],
            "candidate_docs": t["candidate_docs"],
            "sampling": {
                "estimated": t["sampling"],
                "reused": run.sampling_reused.get(t["table"]),
            },
            "stages": [_stage_actuals(run, ledger, t["table"], st)
                       for st in t.get("stages", [])],
        }
        if "est_total_cost_tokens" in t:
            entry["est_total_cost_tokens"] = t["est_total_cost_tokens"]
            entry["est_pass_rate"] = t["est_pass_rate"]
        tables.append(entry)
    report = {
        "qid": handle.qid,
        "query": plan["query"],
        "tenant": handle.tenant,
        "rows": len(handle._rows),
        "wall_s": round(ledger.wall_time_s, 6),
        "tables": tables,
        "totals": {
            "input_tokens": snap["input_tokens"],
            "output_tokens": snap["output_tokens"],
            "llm_calls": snap["llm_calls"],
            "extractions": snap["extractions"],
            "per_phase": snap["per_phase"],
        },
        "savings": {
            "prefix_hits": snap["prefix_hits"],
            "saved_prefill_tokens": snap["saved_prefill_tokens"],
            "draft_tokens": snap["draft_tokens"],
            "accepted_tokens": snap["accepted_tokens"],
            "decode_steps_saved": snap["decode_steps_saved"],
            "cascade_small": snap["cascade_small"],
            "cascade_escalations": snap["cascade_escalations"],
            "target_tokens_saved": snap["target_tokens_saved"],
        },
    }
    tracer = getattr(session, "tracer", None)
    if tracer is not None and tracer.spans:
        report["trace"] = {"clock": tracer.clock_kind,
                           "spans": len(tracer.spans),
                           "by_kind": tracer.by_kind()}
    return report


def _fmt(v, nd=4):
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}g}"
    return str(v)


def render_report(report: dict) -> str:
    """Human-readable EXPLAIN ANALYZE table (examples/explain_analyze.py)."""
    lines = [f"EXPLAIN ANALYZE  query {report['qid']}: {report['query']}",
             f"  rows={report['rows']} wall={report['wall_s']:.3f}s "
             f"tokens={report['totals']['input_tokens']}+"
             f"{report['totals']['output_tokens']} "
             f"calls={report['totals']['llm_calls']}"]
    hdr = (f"    {'stage':<34} {'est_sel':>8} {'act_sel':>8} "
           f"{'est_tok':>8} {'act_tok/call':>12} {'calls':>6}")
    for t in report["tables"]:
        samp = t["sampling"]
        est = samp["estimated"]
        est_txt = (f"reused ({est.get('n_sampled', '?')} docs)"
                   if est.get("reused")
                   else f"planned ~{est.get('planned_sample', '?')} docs")
        act_txt = ("reused" if samp["reused"] else
                   "paid" if samp["reused"] is not None else "-")
        lines.append(f"  TABLE {t['table']}: {t['candidate_docs']} candidates"
                     f" | sampling est: {est_txt} | actual: {act_txt}")
        if t["stages"]:
            lines.append(hdr)
        for st in t["stages"]:
            name = st["filter"]
            if len(name) > 34:
                name = name[:31] + "..."
            lines.append(
                f"    {name:<34} {_fmt(st['est_selectivity']):>8} "
                f"{_fmt(st['actual_selectivity']):>8} "
                f"{_fmt(st['est_cost_tokens']):>8} "
                f"{_fmt(st['actual_tokens_per_call']):>12} "
                f"{st['invocations']:>6}")
        if "est_total_cost_tokens" in t:
            lines.append(f"    => est total ~{t['est_total_cost_tokens']} "
                         f"tokens, est pass rate {t['est_pass_rate']}")
    sav = report["savings"]
    parts = []
    if sav["prefix_hits"]:
        parts.append(f"prefix: {sav['prefix_hits']} hits / "
                     f"{sav['saved_prefill_tokens']} tok saved")
    if sav["draft_tokens"]:
        parts.append(f"spec: {sav['accepted_tokens']}/{sav['draft_tokens']} "
                     f"accepted, {sav['decode_steps_saved']} steps saved")
    if sav["cascade_small"] or sav["cascade_escalations"]:
        parts.append(f"cascade: {sav['cascade_small']} small / "
                     f"{sav['cascade_escalations']} escalated / "
                     f"{sav['target_tokens_saved']} tok saved")
    lines.append("  savings: " + ("; ".join(parts) if parts else "none"))
    tr = report.get("trace")
    if tr:
        kinds = ", ".join(f"{k}={v['spans']}"
                          for k, v in sorted(tr["by_kind"].items()))
        lines.append(f"  trace: {tr['spans']} spans ({tr['clock']} clock): "
                     f"{kinds}")
    return "\n".join(lines)
