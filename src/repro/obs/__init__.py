"""Unified telemetry (DESIGN.md §19): request-lifecycle tracing, a typed
metrics registry, and the EXPLAIN ANALYZE report joiner.

Zero-dependency by design — stdlib only — so it can thread through every
layer (core/, serving/, extract/, live/) without changing what the repo
can run on. The three pieces:

  * `Tracer` (trace.py): structured spans with an injectable clock,
    exported as Chrome trace-event JSON (Perfetto) or deterministic
    JSONL. `NULL_TRACER` is the shared no-op default.
  * `MetricsRegistry` (metrics.py): typed Counter/Gauge/Histogram behind
    a registered-name schema; `StatsDict` re-backs the legacy stats-dict
    surfaces with registry instruments; Prometheus text exposition.
  * `build_report`/`render_report` (report.py): join `explain()`'s
    per-stage estimates with per-attr/per-filter actuals —
    `QueryHandle.report()`.

Wiring: construct one `Tracer` (and optionally one shared
`MetricsRegistry`) and hand it to `Session(tracer=...)`,
`ServingEngine(tracer=...)` and `ServingFrontend(tracer=...)`; see
examples/explain_analyze.py and the README "profiling a query"
quickstart.
"""
from .metrics import (SCHEMA, Counter, Gauge, Histogram, MetricsRegistry,
                      MetricsSchemaError, StatsDict, schema_stem)
from .report import build_report, render_report
from .trace import (LEVEL_FULL, LEVEL_OFF, LEVEL_PHASES, NULL_TRACER,
                    NullTracer, Span, TickClock, Tracer, as_tracer,
                    resolve_level)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "TickClock", "Span",
    "as_tracer", "resolve_level", "LEVEL_OFF", "LEVEL_PHASES", "LEVEL_FULL",
    "MetricsRegistry", "MetricsSchemaError", "Counter", "Gauge", "Histogram",
    "StatsDict", "SCHEMA", "schema_stem",
    "build_report", "render_report",
]
