"""Request-lifecycle tracing (DESIGN.md §19).

A `Tracer` records structured spans — name, kind, start/end, parent,
attrs — from every layer of the stack: `Session` query lifecycle,
`BatchScheduler` rounds, `ServingFrontend` admission, `ServingEngine`
phases, cascade tier routing, and live-corpus invalidation. Spans nest by
a plain stack: the whole runtime is a cooperative single-thread pump
(DESIGN.md §11), so "current span" is well-defined without thread locals,
and the resulting tree is well-formed by construction (every parent is an
open enclosing span; siblings cannot overlap).

Two clock modes, injectable at construction:

  * wall  — `time.perf_counter` relative to tracer construction; what you
            profile with (`examples/explain_analyze.py`, Perfetto).
  * ticks — any zero-arg callable; `TickClock()` increments by one per
            read, so the same deterministic run produces byte-identical
            trace JSONL (tests/test_obs.py pins this on both the oracle
            and the served extractor).

Long-lived operations that span many pump rounds (a query's life from
submit to finish, a serving request from admission to completion) do not
fit the stack: they are recorded as *async* spans via `begin()`/`end()`
(Chrome "b"/"e" events, grouped by id), while stack spans export as
complete "X" events. `instant()` marks point events (prefix-cache hits,
shed decisions, mutations).

Levels gate cost: 0 = off, 1 = phases (query/round/run granularity),
2 = full (per prefill chunk, decode step, verify round). `NULL_TRACER`
is the shared no-op every layer defaults to, so tracing-off call sites
pay one predicate per would-be span — the <5% overhead budget
`benchmarks/bench_obs_overhead.py` gates (alongside byte-invariance of
rows and ledger token columns, tracing on vs. off).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

LEVEL_OFF = 0
LEVEL_PHASES = 1
LEVEL_FULL = 2

_LEVEL_NAMES = {"off": LEVEL_OFF, "phases": LEVEL_PHASES, "full": LEVEL_FULL}


def resolve_level(level) -> int:
    """Accept 0/1/2 or "off"/"phases"/"full" (the `obs_level` knob)."""
    if isinstance(level, str):
        try:
            return _LEVEL_NAMES[level]
        except KeyError:
            raise ValueError(
                f"obs_level must be one of {sorted(_LEVEL_NAMES)} or 0-2, "
                f"got {level!r}") from None
    lv = int(level)
    if not LEVEL_OFF <= lv <= LEVEL_FULL:
        raise ValueError(f"obs_level must be 0..2, got {level!r}")
    return lv


class TickClock:
    """Deterministic clock: each read advances one tick. Two identical
    runs read the clock in the same order, so every span gets the same
    timestamps — the byte-stability the trace-determinism tests pin."""

    def __init__(self, start: int = 0):
        self.t = start

    def __call__(self) -> int:
        self.t += 1
        return self.t


@dataclass
class Span:
    sid: int
    parent: Optional[int]
    name: str
    kind: str
    t0: float
    t1: Optional[float] = None       # None while open / for instants of 0 dur
    attrs: dict = field(default_factory=dict)
    phase: str = "X"                 # X complete | i instant | b/e async

    def to_dict(self) -> dict:
        d = {"sid": self.sid, "parent": self.parent, "name": self.name,
             "kind": self.kind, "t0": self.t0, "t1": self.t1,
             "ph": self.phase}
        if self.attrs:
            d["attrs"] = self.attrs
        return d


class _SpanCtx:
    """Context manager for one stack span; reused objects would race under
    re-entrancy, so each `span()` call makes a fresh one (cheap: two
    attributes)."""
    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, *exc) -> None:
        self._tracer._close(self._span)


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> None:
        return None


_NULL_CTX = _NullCtx()


class Tracer:
    """Span recorder with an injectable clock and coarse/fine levels.

    clock: "wall" (perf_counter, relative to construction), "ticks"
    (fresh `TickClock`), or any zero-arg callable returning a number.
    level: 0/1/2 or "off"/"phases"/"full" — spans above the level are
    dropped at the call site (`enabled()` / no-op context)."""

    def __init__(self, *, clock="wall", level=LEVEL_FULL):
        self.level = resolve_level(level)
        if clock == "wall":
            base = time.perf_counter()
            self._clock: Callable[[], float] = \
                lambda: time.perf_counter() - base
            self.clock_kind = "wall"
        elif clock == "ticks":
            self._clock = TickClock()
            self.clock_kind = "ticks"
        elif callable(clock):
            self._clock = clock
            self.clock_kind = "external"
        else:
            raise ValueError(
                f"clock must be 'wall', 'ticks' or a callable, got {clock!r}")
        self.spans: list = []
        self._stack: list = []          # open stack spans (sync nesting)
        self._open_async: dict = {}     # sid -> Span (begin()ed, not end()ed)
        self._next_sid = 0

    # -------------------------------------------------------------- record --

    def enabled(self, level: int = LEVEL_PHASES) -> bool:
        return self.level >= level

    def now(self) -> float:
        return self._clock()

    def _new_span(self, name, kind, phase, attrs) -> Span:
        sid = self._next_sid
        self._next_sid += 1
        parent = self._stack[-1].sid if self._stack else None
        return Span(sid, parent, name, kind, self.now(), None,
                    attrs, phase)

    def span(self, name: str, *, kind: str = "span",
             level: int = LEVEL_PHASES, **attrs):
        """Open a nested stack span; use as a context manager."""
        if self.level < level:
            return _NULL_CTX
        span = self._new_span(name, kind, "X", attrs)
        self._stack.append(span)
        self.spans.append(span)
        return _SpanCtx(self, span)

    def _close(self, span: Span) -> None:
        # pop through anything left open by an exception below this span
        while self._stack and self._stack[-1] is not span:
            leaked = self._stack.pop()
            leaked.t1 = leaked.t0
        if self._stack:
            self._stack.pop()
        span.t1 = self.now()

    def instant(self, name: str, *, kind: str = "event",
                level: int = LEVEL_PHASES, **attrs) -> None:
        """Zero-duration point event attached to the current stack span."""
        if self.level < level:
            return
        span = self._new_span(name, kind, "i", attrs)
        span.t1 = span.t0
        self.spans.append(span)

    def begin(self, name: str, *, kind: str = "async",
              level: int = LEVEL_PHASES, **attrs) -> int:
        """Open a long-lived async span (query lifecycle, serving request)
        that outlives the current stack frame. Returns an id for `end()`;
        -1 when disabled at this level."""
        if self.level < level:
            return -1
        span = self._new_span(name, kind, "b", attrs)
        span.parent = None              # async spans are roots of their track
        self.spans.append(span)
        self._open_async[span.sid] = span
        return span.sid

    def end(self, sid: int, **attrs) -> None:
        span = self._open_async.pop(sid, None)
        if span is None:                # begin() was disabled or double-end
            return
        span.t1 = self.now()
        if attrs:
            span.attrs.update(attrs)

    # -------------------------------------------------------------- export --

    def _finalized(self) -> list:
        """Spans with open ends closed out (export may happen mid-run)."""
        out = []
        for s in self.spans:
            if s.t1 is None:
                s = Span(s.sid, s.parent, s.name, s.kind, s.t0, s.t0,
                         s.attrs, s.phase)
            out.append(s)
        return out

    def to_jsonl(self) -> str:
        """One deterministic JSON object per span, in emit order — the
        byte-stable export the determinism tests compare."""
        lines = [json.dumps(s.to_dict(), sort_keys=True,
                            separators=(",", ":"))
                 for s in self._finalized()]
        return "\n".join(lines) + ("\n" if lines else "")

    def write_jsonl(self, path) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_jsonl())

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON (load in Perfetto / chrome://tracing).
        Stack spans export as complete "X" events; async spans as "b"/"e"
        pairs grouped by id; instants as "i". Tick clocks scale 1 tick =
        1 us so Perfetto renders a readable timeline."""
        scale = 1e6 if self.clock_kind == "wall" else 1.0
        events = []
        for s in self._finalized():
            base = {"name": s.name, "cat": s.kind, "pid": 1, "tid": 1,
                    "ts": round(s.t0 * scale, 3)}
            if s.attrs:
                base["args"] = s.attrs
            if s.phase == "X":
                events.append({**base, "ph": "X",
                               "dur": round((s.t1 - s.t0) * scale, 3)})
            elif s.phase == "i":
                events.append({**base, "ph": "i", "s": "t"})
            else:                       # async begin/end pair
                ev_id = str(s.sid)
                events.append({**base, "ph": "b", "id": ev_id})
                events.append({"name": s.name, "cat": s.kind, "pid": 1,
                               "tid": 1, "ph": "e", "id": ev_id,
                               "ts": round(s.t1 * scale, 3)})
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"clock": self.clock_kind}}

    def write_chrome(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_chrome(), fh, sort_keys=True,
                      separators=(",", ":"))

    # ------------------------------------------------------------- queries --

    def by_kind(self) -> dict:
        """{kind: {"spans": n, "wall": summed duration}} — the per-phase
        wall attribution `QueryHandle.report()` folds in."""
        agg: dict = {}
        for s in self._finalized():
            slot = agg.setdefault(s.kind, {"spans": 0, "wall": 0.0})
            slot["spans"] += 1
            slot["wall"] += (s.t1 - s.t0)
        return agg

    def find(self, name: str) -> list:
        return [s for s in self.spans if s.name == name]


class NullTracer:
    """Shared no-op tracer: default for every instrumented layer, so the
    tracing-off path is one attribute load + one method call per span
    site (gated <5% by bench_obs_overhead)."""

    level = LEVEL_OFF
    clock_kind = "off"
    spans: list = []

    def enabled(self, level: int = LEVEL_PHASES) -> bool:
        return False

    def now(self) -> float:
        return 0.0

    def span(self, name, **kw):
        return _NULL_CTX

    def instant(self, name, **kw) -> None:
        return None

    def begin(self, name, **kw) -> int:
        return -1

    def end(self, sid, **kw) -> None:
        return None

    def by_kind(self) -> dict:
        return {}

    def find(self, name) -> list:
        return []

    def to_jsonl(self) -> str:
        return ""

    def to_chrome(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "otherData": {"clock": "off"}}


NULL_TRACER = NullTracer()


def as_tracer(tracer) -> "Tracer":
    """Normalize an optional tracer argument: None -> NULL_TRACER."""
    return tracer if tracer is not None else NULL_TRACER
