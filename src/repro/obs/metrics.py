"""Typed metrics registry (DESIGN.md §19).

One `MetricsRegistry` replaces the ad-hoc counter dicts that grew per
subsystem (`engine.stats`, `frontend.stats`, `SchedulerStats`) with typed
instruments — `Counter` (monotone; a decrement is a hard error), `Gauge`
(set/max semantics for peaks), `Histogram` (bucketed latency counts) —
behind a *registered-name schema*: every metric the runtime may report is
declared in `SCHEMA` below, creating an undeclared instrument raises, and
`check_complete()` turns "a counter silently stopped being reported" into
a hard error instead of drift (`benchmarks/compare.py` validates bench
counters against the same schema).

The existing dict/dataclass read surfaces stay intact so no call site or
test changes shape: `StatsDict` is a `MutableMapping` whose values live
in registry instruments (`engine.stats["prefill_tokens"] += n` increments
the `engine.prefill_tokens` Counter; reading the key reads the Counter),
and the scheduler's `SchedulerStats` gets the same treatment via
attribute access. Prometheus-style text exposition (`exposition()`) hangs
off the registry; `ServingFrontend.metrics_text()` serves it.

Metric naming scheme: `<subsystem>.<what>[_<unit>]` — subsystems are
`engine`, `frontend`, `scheduler`, `session`, `ledger`. Exposition
rewrites dots to underscores (Prometheus name charset).
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Optional


class MetricsSchemaError(KeyError):
    """An instrument name outside the registered schema, a type clash, or
    a schema name that was never registered (stopped being reported)."""


# --------------------------------------------------------------- schema ----
# name -> (type, help). This is THE list of counters the runtime reports;
# engine/frontend/scheduler stats surfaces are built from it, so adding a
# counter means adding it here first (and removing one here breaks the
# construction of the surface that reported it — loudly).

ENGINE_STATS = {
    "prefill_tokens": ("counter", "prompt tokens prefilled (post prefix-hit)"),
    "decode_steps": ("counter", "batched decode steps executed"),
    "evictions": ("counter", "slot evictions (retry path)"),
    "runs": ("counter", "run() drains"),
    "max_live": ("gauge", "peak concurrently-decoding slots"),
    "decode_slot_steps": ("counter", "per-slot decode work (steps x live)"),
    "prefix_hits": ("counter", "prefix-cache hits on insert"),
    "prefix_saved_tokens": ("counter", "prompt tokens skipped via prefix KV"),
    "prefix_inserts": ("counter", "prefix-cache snapshot inserts"),
    "truncations": ("counter", "run() hit max_steps with work left"),
    "failures": ("counter", "requests failed past the retry cap"),
    "prefill_invocations": ("counter", "prefill kernel dispatches"),
    "prefill_chunks": ("counter", "chunked-prefill chunks processed"),
    "cow_copies": ("counter", "copy-on-write page copies"),
    "kv_bytes_peak": ("gauge", "peak live KV-cache bytes"),
    "prefill_ctx_positions": ("counter", "attention positions prefilled"),
    "spec_rounds": ("counter", "speculative verify rounds"),
    "draft_tokens": ("counter", "draft tokens proposed"),
    "accepted_tokens": ("counter", "draft tokens accepted"),
    "decode_steps_saved": ("counter", "decode steps saved by acceptance"),
    "cancelled": ("counter", "requests cancelled"),
    "admission_deferred": ("counter", "admissions deferred on page pressure"),
}

FRONTEND_STATS = {
    "pumps": ("counter", "scheduling rounds pumped"),
    "submitted": ("counter", "tickets submitted"),
    "admitted": ("counter", "tickets admitted to the engine"),
    "completed": ("counter", "tickets completed"),
    "failed": ("counter", "tickets failed"),
    "shed": ("counter", "tickets shed (too large / queue full)"),
    "cancelled": ("counter", "tickets cancelled"),
    "timeouts": ("counter", "tickets expired in queue"),
    "deferred": ("counter", "dispatches deferred on page pressure"),
    "pool_exhausted_absorbed": ("counter", "PagePoolExhausted absorbed"),
    "queue_depth_peak": ("gauge", "peak frontend queue depth"),
}

SCHEDULER_STATS = {
    "rounds": ("counter", "extract_batch submissions"),
    "submitted": ("counter", "extractions sent to the extractor"),
    "dedup_hits": ("counter", "duplicate (doc, attr) folded into one charge"),
    "cache_hits": ("counter", "needs answered from the session cache"),
    "empty_retrievals": ("counter", "no relevant segments -> free negative"),
    "max_batch": ("gauge", "largest extraction batch"),
}

SESSION_STATS = {
    "queries": ("counter", "queries submitted"),
    "queries_finished": ("counter", "queries finished"),
    "queries_failed": ("counter", "queries failed"),
    "steps": ("counter", "multiplexer pump rounds"),
}

# CostLedger token columns — the parity-critical surface (rows + these
# must stay byte-identical tracing on vs. off). The ledger dataclass
# remains authoritative; the registry mirrors it for exposition and for
# schema validation of bench counter names.
LEDGER_COLUMNS = {
    "input_tokens": ("counter", "prompt tokens charged"),
    "output_tokens": ("counter", "completion tokens charged"),
    "llm_calls": ("counter", "LLM invocations"),
    "extractions": ("counter", "attribute extractions"),
    "batches": ("counter", "batched extraction rounds"),
    "batched_extractions": ("counter", "extractions in batched rounds"),
    "max_batch": ("gauge", "largest batch"),
    "prefix_hits": ("counter", "prefix-cache hits"),
    "saved_prefill_tokens": ("counter", "prefill tokens saved by prefix KV"),
    "draft_tokens": ("counter", "speculative draft tokens"),
    "accepted_tokens": ("counter", "speculative tokens accepted"),
    "decode_steps_saved": ("counter", "decode steps saved by speculation"),
    "cascade_small": ("counter", "extractions served by the small tier"),
    "cascade_escalations": ("counter", "small-tier answers escalated"),
    "target_tokens_saved": ("counter", "target-tier tokens saved by cascade"),
    "wall_time_s": ("gauge", "wall seconds charged"),
}

_EXTRA = {
    "frontend.queue_delay": ("histogram", "ticks from submit to dispatch"),
}


def _prefixed(prefix: str, table: dict) -> dict:
    return {f"{prefix}.{k}": v for k, v in table.items()}


SCHEMA: dict = {
    **_prefixed("engine", ENGINE_STATS),
    **_prefixed("frontend", FRONTEND_STATS),
    **_prefixed("scheduler", SCHEDULER_STATS),
    **_prefixed("session", SESSION_STATS),
    **_prefixed("ledger", LEDGER_COLUMNS),
    **_EXTRA,
}

# short (unprefixed) counter names the benches may report under derived
# spellings ("prefill_tokens_on"); compare.py strips variant suffixes and
# checks the stem against this set
SCHEMA_STEMS = frozenset(k.split(".", 1)[1] for k in SCHEMA
                         if "." in k)


def schema_stem(counter_name: str) -> Optional[str]:
    """Map a bench counter spelling to the schema stem it derives from,
    or None if no schema metric matches. Benches suffix variant tags
    (`prefill_tokens_on`, `draft_tokens_dp2`) onto schema stems; strip
    trailing tags until a stem matches."""
    name = counter_name
    while True:
        if name in SCHEMA_STEMS or name in SCHEMA:
            return name
        if "_" not in name:
            return None
        name = name.rsplit("_", 1)[0]


# ---------------------------------------------------------- instruments ----


class Counter:
    """Monotone counter. `set_total` (the dict-compat write path) rejects
    decreases — regressions in reporting fail loudly."""
    kind = "counter"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {n})")
        self.value += n

    def set_total(self, v) -> None:
        if v < self.value:
            raise MetricsSchemaError(
                f"counter {self.name} would decrease ({self.value} -> {v})")
        self.value = v


class Gauge:
    """Point-in-time value; `set_max` gives peak semantics."""
    kind = "gauge"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    set_total = set                     # dict-compat write path

    def set_max(self, v) -> None:
        if v > self.value:
            self.value = v


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: le-bounds plus
    +Inf, running sum and count)."""
    kind = "histogram"
    __slots__ = ("name", "help", "bounds", "bucket_counts", "total", "count")

    DEFAULT_BOUNDS = (1, 2, 5, 10, 20, 50, 100, 200, 500, 1000)

    def __init__(self, name: str, help: str = "", bounds=None):
        self.name = name
        self.help = help
        self.bounds = tuple(bounds) if bounds is not None \
            else self.DEFAULT_BOUNDS
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram {name} bounds must be sorted")
        self.bucket_counts = [0] * (len(self.bounds) + 1)   # +Inf last
        self.total = 0.0
        self.count = 0

    def observe(self, v) -> None:
        self.bucket_counts[bisect_left(self.bounds, v)] += 1
        self.total += v
        self.count += 1

    @property
    def value(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "buckets": {str(b): c for b, c in
                            zip(list(self.bounds) + ["+Inf"],
                                self._cumulative())}}

    def _cumulative(self) -> list:
        out, run = [], 0
        for c in self.bucket_counts:
            run += c
            out.append(run)
        return out


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


# ------------------------------------------------------------- registry ----


class MetricsRegistry:
    """Central instrument store with schema enforcement.

    schema: name -> (type, help). Default is the repo-wide `SCHEMA`;
    pass `schema=None` for an open registry (tests, scratch). Creating
    an instrument whose name or type disagrees with the schema raises
    `MetricsSchemaError`; so does re-registering a name as a different
    type."""

    def __init__(self, schema: Optional[dict] = SCHEMA):
        self.schema = schema
        self._instruments: dict = {}

    # ---------------------------------------------------------- creation --

    def _make(self, name: str, typ: str, help: str, **kw):
        existing = self._instruments.get(name)
        if existing is not None:
            if existing.kind != typ:
                raise MetricsSchemaError(
                    f"metric {name} already registered as {existing.kind}, "
                    f"requested {typ}")
            return existing
        if self.schema is not None:
            decl = self.schema.get(name)
            if decl is None:
                raise MetricsSchemaError(
                    f"metric {name!r} is not in the registered schema "
                    f"(declare it in repro.obs.metrics.SCHEMA)")
            if decl[0] != typ:
                raise MetricsSchemaError(
                    f"metric {name} declared as {decl[0]}, requested {typ}")
            help = help or decl[1]
        inst = _TYPES[typ](name, help, **kw)
        self._instruments[name] = inst
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._make(name, "counter", help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._make(name, "gauge", help)

    def histogram(self, name: str, help: str = "",
                  bounds=None) -> Histogram:
        return self._make(name, "histogram", help, bounds=bounds)

    # ------------------------------------------------------------- reads --

    def get(self, name: str):
        try:
            return self._instruments[name]
        except KeyError:
            raise MetricsSchemaError(
                f"metric {name!r} was never registered") from None

    def value(self, name: str):
        return self.get(name).value

    def names(self) -> list:
        return sorted(self._instruments)

    def snapshot(self) -> dict:
        return {name: inst.value
                for name, inst in sorted(self._instruments.items())}

    def check_complete(self, prefix: str = "") -> None:
        """Hard-error if any schema metric (under `prefix`) was never
        registered — the "counter stopped being reported" guard."""
        if self.schema is None:
            return
        missing = [n for n in self.schema
                   if n.startswith(prefix) and n not in self._instruments]
        if missing:
            raise MetricsSchemaError(
                f"schema metrics never registered (stopped being "
                f"reported?): {sorted(missing)}")

    # -------------------------------------------------------- exposition --

    def exposition(self) -> str:
        """Prometheus text format. Dots become underscores; histograms
        expand to _bucket/_sum/_count families."""
        lines = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            pname = name.replace(".", "_")
            if inst.help:
                lines.append(f"# HELP {pname} {inst.help}")
            lines.append(f"# TYPE {pname} {inst.kind}")
            if inst.kind == "histogram":
                cum = inst._cumulative()
                for b, c in zip(list(inst.bounds) + ["+Inf"], cum):
                    lines.append(f'{pname}_bucket{{le="{b}"}} {c}')
                lines.append(f"{pname}_sum {inst.total}")
                lines.append(f"{pname}_count {inst.count}")
            else:
                lines.append(f"{pname} {inst.value}")
        return "\n".join(lines) + ("\n" if lines else "")


# ----------------------------------------------------- compat surfaces ----


class StatsDict:
    """MutableMapping view whose values live in registry instruments.

    Drop-in for the old plain-dict stats surfaces: `stats[k] += n`
    becomes a Counter increment (Gauge set for peak keys), reads are
    registry reads, and touching a key outside the declared table is a
    `MetricsSchemaError` instead of a silently-born counter."""

    def __init__(self, registry: MetricsRegistry, prefix: str, table: dict):
        self._reg = registry
        self._prefix = prefix
        self._inst = {}
        for key, (typ, help) in table.items():
            name = f"{prefix}.{key}"
            self._inst[key] = (registry.counter(name, help) if
                               typ == "counter" else
                               registry.gauge(name, help))

    def __getitem__(self, key):
        try:
            return self._inst[key].value
        except KeyError:
            raise MetricsSchemaError(
                f"stat {key!r} is not in the {self._prefix} metrics "
                f"schema") from None

    def __setitem__(self, key, value) -> None:
        inst = self._inst.get(key)
        if inst is None:
            raise MetricsSchemaError(
                f"stat {key!r} is not in the {self._prefix} metrics "
                f"schema")
        inst.set_total(value)

    def __contains__(self, key) -> bool:
        return key in self._inst

    def __iter__(self):
        return iter(self._inst)

    def __len__(self) -> int:
        return len(self._inst)

    def keys(self):
        return self._inst.keys()

    def values(self):
        return [i.value for i in self._inst.values()]

    def items(self):
        return [(k, i.value) for k, i in self._inst.items()]

    def get(self, key, default=None):
        inst = self._inst.get(key)
        return inst.value if inst is not None else default

    def snapshot(self) -> dict:
        return {k: i.value for k, i in self._inst.items()}

    def __repr__(self) -> str:
        return f"StatsDict({self.snapshot()!r})"

    def __eq__(self, other) -> bool:
        if isinstance(other, StatsDict):
            other = other.snapshot()
        return self.snapshot() == other
