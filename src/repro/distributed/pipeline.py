"""GPipe-style pipeline parallelism over the `pod` axis (DESIGN.md §4).

Alternative use of the multi-pod mesh: instead of cross-pod data
parallelism (one gradient all-reduce over the slow inter-pod links every
step), split the layer stack into `n_stages = pod` contiguous stages and
stream microbatches through with `collective_permute` handoffs — the only
cross-pod traffic is one activation tensor per microbatch per boundary,
which for large models is orders of magnitude less than a gradient
all-reduce.

Schedule: classic GPipe (fill/steady/drain) expressed as a lax.scan over
`n_micro + n_stages - 1` ticks inside a shard_map that is manual over
`pod` and auto over (data, model) — within a stage, the usual FSDP+TP
layout keeps working untouched.

Bubble fraction = (n_stages - 1) / (n_micro + n_stages - 1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map


def pipeline_forward(mesh, stage_fn, n_micro: int, *, axis: str = "pod"):
    """Builds fwd(stage_params, x_micro) running `stage_fn` as a pipeline.

    stage_fn(stage_params, x) -> y : one stage's computation (same shape in
    and out — e.g. a slice of transformer layers on the residual stream).
    stage_params: pytree whose leaves have a leading `n_stages` dim
    (sharded over `axis`); x_micro: (n_micro, mb, ...) microbatched input
    (replicated across pods; stage 0 consumes it).

    Returns out: (n_micro, mb, ...) — stage `n_stages-1`'s outputs
    (valid on the last pod; psum-broadcast to all pods for convenience).
    """
    n_stages = mesh.shape[axis]

    def run(stage_params, x_micro):
        # shard_map gives each pod its (1, ...) slice of the stage stack
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        idx = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1
        mb_shape = x_micro.shape[1:]

        def tick(carry, t):
            inbuf, outs = carry
            # stage 0 injects microbatch t (when valid); others use inbuf
            mb_in = jnp.where(t < n_micro, jnp.minimum(t, n_micro - 1), 0)
            x0 = jax.lax.dynamic_index_in_dim(x_micro, mb_in, keepdims=False)
            x = jnp.where(idx == 0, x0, inbuf)
            y = stage_fn(stage_params, x)
            # my microbatch id at this tick: t - idx (valid if 0 <= . < n_micro)
            my_mb = t - idx
            valid = (my_mb >= 0) & (my_mb < n_micro)
            # last stage stores its result
            store_at = jnp.clip(my_mb, 0, n_micro - 1)
            is_last = idx == n_stages - 1
            outs = jax.lax.cond(
                valid & is_last,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y.astype(o.dtype), store_at, 0),
                lambda o: o, outs)
            # hand off to the next stage (ring permute; last->0 ignored)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        inbuf0 = jnp.zeros(mb_shape, x_micro.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, x_micro.dtype)
        (_, outs), _ = jax.lax.scan(tick, (inbuf0, outs0), jnp.arange(ticks))
        # broadcast final-stage outputs to every pod
        mask = (idx == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, axis)

    def fwd(stage_params, x_micro):
        pspecs = jax.tree.map(lambda _: P(axis), stage_params)
        return shard_map(
            run, mesh=mesh,
            in_specs=(pspecs, P()),
            out_specs=P(),
            axis_names={axis},
            check_vma=False,
        )(stage_params, x_micro)

    return fwd


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
