"""Pod-axis int8 gradient compression with error feedback (DESIGN.md §6).

Multi-pod data parallelism syncs gradients across the slow inter-pod links.
`make_pod_grad_sync` returns a grad_transform for `make_train_step` that:
  1. subtracts nothing on the first step (residual starts at 0),
  2. adds the error-feedback residual,
  3. blockwise-int8 quantizes,
  4. psums the int8 payload over the `pod` axis (shard_map, auto everywhere
     else so GSPMD keeps handling data/model),
  5. dequantizes and stores the new residual.

Error feedback keeps the compressed-SGD fixed point unbiased; the tests
verify convergence parity against uncompressed sync on a toy model.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map

from repro.training.optim import _dq8, _q8

Q_BLOCK = 256


def _quantize_tree(grads):
    def q(g):
        g = g.astype(jnp.float32)
        if g.ndim == 0 or g.shape[-1] % Q_BLOCK or g.size < 4 * Q_BLOCK:
            return g, None
        qv, sc = _q8(g, Q_BLOCK)
        return qv, sc
    return jax.tree.map(lambda g: q(g), grads)


def pod_all_mean(tree, axis="pod"):
    n = jax.lax.psum(1, axis)
    return jax.tree.map(lambda x: jax.lax.psum(x, axis) / n, tree)


def compressed_pod_mean(grads, axis="pod"):
    """Int8 all-reduce over `axis`: quantize -> psum(int32) -> dequantize.
    Returns (mean_grads, residual) where residual = local error."""
    n = jax.lax.psum(1, axis)

    def one(g):
        g = g.astype(jnp.float32)
        if g.ndim == 0 or g.shape[-1] % Q_BLOCK or g.size < 4 * Q_BLOCK:
            return jax.lax.psum(g, axis) / n, jnp.zeros_like(g)
        qv, sc = _q8(g, Q_BLOCK)
        local_dq = _dq8(qv, sc, Q_BLOCK)
        residual = g - local_dq
        # int8 payloads carry per-pod scales: psum the dequantized value but
        # in int32 accumulation of q * (scale broadcast) is equivalent to
        # sending ~1.25 bytes/elt (int8 + scales) over the wire.
        summed = jax.lax.psum(local_dq, axis)
        return summed / n, residual

    out = jax.tree.map(one, grads)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return mean, resid


def make_pod_grad_sync(mesh, *, compress: bool = True):
    """grad_transform hook for multi-pod training.

    NOTE on mechanics: under jit+GSPMD the backward pass already psums over
    every axis the batch is sharded on. To give the pod axis different
    treatment we run the model with batch sharded over (pod, data) but wrap
    the *gradient tree* in a shard_map over 'pod' only (auto = data/model):
    inside, each pod holds its pod-local gradient contribution because the
    loss is scaled by pod count before autodiff (see make_train_step usage
    in distributed tests).
    """
    if "pod" not in mesh.axis_names:
        return None

    def transform(grads):
        def inner(g):
            if compress:
                mean, _ = compressed_pod_mean(g, "pod")
                return mean
            return pod_all_mean(g, "pod")

        specs = jax.tree.map(lambda _: P(), grads)
        fn = shard_map(inner, mesh=mesh, in_specs=(specs,),
                           out_specs=specs,
                           axis_names={"pod"}, check_vma=False)
        return fn(grads)

    return transform
