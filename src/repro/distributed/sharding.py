"""Sharding rules: FSDP+TP 2D parameter layout, activation/cache specs.

Policy (DESIGN.md §4):
  - every large matrix: "feature" dim over `model` (TP), other big dim over
    `data` (FSDP / ZeRO-3); XLA all-gathers FSDP shards per layer inside the
    scan loop (overlappable) and all-reduces TP partials.
  - axes only apply when the dim is divisible by the axis size (GQA kv=8 on
    a 16-way model axis stays replicated; qk-norm scales etc. replicate).
  - batch over (pod, data); KV caches: batch over data, *sequence over
    model* (sequence-sharded decode: GSPMD reduces the masked softmax over
    the sharded axis; the shard_map flash-decoding variant is the optimized
    path); SSM state: d_inner over model.
  - optimizer state mirrors its parameter's spec (extra leading quant-block
    dims for adam8bit replicate).
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.launch.mesh import batch_axes

FSDP = "data"
TP = "model"

# jax >= 0.5 exposes shard_map at the top level with axis_names/check_vma;
# 0.4.x has it under experimental with the complementary auto=/check_rep=
# spelling. The wrapper accepts the new-style call and translates.
def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=True):
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    # 0.4.x partial-auto lowers to PartitionId ops the SPMD partitioner
    # rejects; run fully manual instead — axes the specs don't mention are
    # replicated per device, numerically identical (just unpartitioned),
    # which needs the replication check off.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)

# trailing-dim roles per leaf name: 'f' = FSDP(data), 't' = TP(model),
# '.' = replicated. Leading dims (layer stacks etc.) always replicate.
_ROLES = {
    "embed": "tf",
    "lm_head": "ft",
    "dec_pos": "..",
    "wq": "ft.", "wk": "ft.", "wv": "ft.",
    "wo": "t.f",
    "bq": "t.", "bk": "t.", "bv": "t.",
    "q_norm": ".", "k_norm": ".",
    "w": ".", "b": ".",                      # norms
    "w_gate": "ft", "w_up": "ft", "w_in": "ft", "w_down": "tf",
    "router": "f.",
    "wq_mla": "ft.",
    "w_dkv": "f.",
    "w_uk": "ft.", "w_uv": "ft.",
    "kv_norm": ".",
    "in_proj": "ft",                          # mamba1 (aligned halves)
    "in_proj_m2": "f.",                       # mamba2 (mixed boundary)
    "conv_w": ".t", "conv_b": "t",
    "x_proj": "t.", "dt_proj": ".t", "dt_bias": "t",
    "A_log": "t.", "A_log_1d": "t", "D": "t",
    "norm_w": "t",
    "out_proj": "tf",
    "w1": "f.", "w2": "f.",                   # mm projector
    "a_q": "f.", "a_k": "f.", "a_v": "f.", "a_o": "f.",
    "b_q": ".t", "b_k": ".t", "b_v": ".t", "b_o": "..",
}


def _spec_for_leaf(path, leaf, mesh, cfg: ModelConfig, overrides=None) -> P:
    name = None
    for p in reversed(path):
        if hasattr(p, "key"):
            name = p.key
            break
    roles = (overrides or {}).get(name, _ROLES.get(name))
    # disambiguate shared names
    if name == "in_proj" and cfg.mamba_version == 2:
        roles = _ROLES["in_proj_m2"]
    if name == "A_log" and getattr(leaf, "ndim", 0) >= 1 and cfg.mamba_version == 2:
        roles = None  # stacked (L, h): trailing dim h
        roles = "t"
    if roles is None:
        return P()
    shape = leaf.shape
    ndim = len(shape)
    roles = roles[-ndim:] if len(roles) > ndim else roles
    lead = ndim - len(roles)
    spec = [None] * lead
    msizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, role in zip(shape[lead:], roles):
        if role == "f" and FSDP in msizes and dim % msizes[FSDP] == 0 and dim >= msizes[FSDP]:
            spec.append(FSDP)
        elif role == "t" and TP in msizes and dim % msizes[TP] == 0 and dim >= msizes[TP]:
            spec.append(TP)
        else:
            spec.append(None)
    return P(*spec)


def param_specs(cfg: ModelConfig, params_shape, mesh, overrides=None):
    """PartitionSpec pytree mirroring an (abstract) param pytree.

    `overrides`: {leaf_name: role_string} — variant sharding layouts (e.g.
    expert parallelism: w_gate -> "tf." shards experts over `model`)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [_spec_for_leaf(path, leaf, mesh, cfg, overrides) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(cfg: ModelConfig, params_shape, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, params_shape, mesh))


def opt_state_specs(cfg: ModelConfig, opt_shape, pspecs, mesh):
    """Optimizer-state specs: mirror the param spec where shapes match
    (adam m/v); 8-bit Adam quant blocks shard their block dim over
    (data, model); factored stats and scalars replicate."""
    import jax.tree_util as jtu

    pflat = {jtu.keystr(path): spec
             for path, spec in jtu.tree_flatten_with_path(pspecs)[0]}
    total = 1
    for a in ("data", "model"):
        if a in mesh.axis_names:
            total *= mesh.shape[a]

    def parent_param_spec(path):
        s = jtu.keystr(path[:-1])            # drop the mq/ms/m/v component
        for pkey, pspec in pflat.items():
            if s.endswith(pkey):
                return pspec
        return None

    def spec_of(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("mq", "vq", "ms", "vs", "m", "v"):
            pspec = parent_param_spec(path)
            if pspec is not None and len(pspec) == leaf.ndim:
                if name in ("ms", "vs"):
                    # scales: last axis shrank by q_block; keep axis only if
                    # still divisible
                    last = pspec[-1]
                    msz = mesh.shape[last] if last else 1
                    ok = last is not None and leaf.shape[-1] % msz == 0
                    return P(*pspec[:-1], last if ok else None)
                return pspec
        s = jtu.keystr(path)
        for pkey, pspec in pflat.items():
            if s.endswith(pkey):
                if len(pspec) == getattr(leaf, "ndim", 0):
                    return pspec
        return P()

    flat, treedef = jtu.tree_flatten_with_path(opt_shape)
    return jtu.tree_unflatten(treedef, [spec_of(p, l) for p, l in flat])


# ------------------------------------------------------- activations -------


def batch_spec(mesh, batch_size: int) -> tuple:
    """Largest prefix of (pod, data) that divides the batch."""
    axes = []
    n = 1
    for a in batch_axes(mesh):
        sz = mesh.shape[a]
        if batch_size % (n * sz) == 0:
            axes.append(a)
            n *= sz
    return tuple(axes) if axes else ()


def make_constrain(mesh, batch_size: int, *, ep_moe: bool = False):
    """Activation sharding hook threaded through model forward/decode.

    ep_moe: pin MoE dispatch/combine buffers (E, C, d) to P(data, None, None)
    — experts live on data shards, so GSPMD moves *tokens* (all-to-all)
    instead of all-gathering index tensors and reducing dispatch products."""
    baxes = batch_spec(mesh, batch_size)
    b = baxes if baxes else None

    def constrain(x, kind):
        if kind == "hidden":
            spec = P(b, *([None] * (x.ndim - 1)))
        elif kind == "logits":
            spec = P(b, *([None] * (x.ndim - 2)), TP)
        elif kind == "moe_dispatch" and ep_moe:
            e_ax = FSDP if x.shape[0] % mesh.shape[FSDP] == 0 else None
            spec = P(e_ax, *([None] * (x.ndim - 1)))
        elif kind == "moe_grouped":
            g_ax = FSDP if x.shape[0] % mesh.shape[FSDP] == 0 else None
            spec = P(g_ax, *([None] * (x.ndim - 1)))
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def input_sharding(mesh, batch_size: int, ndim: int):
    baxes = batch_spec(mesh, batch_size)
    b = baxes if baxes else None
    return NamedSharding(mesh, P(b, *([None] * (ndim - 1))))


def cache_specs(cfg: ModelConfig, cache_shape, mesh, batch_size: int):
    """Decode-cache specs: batch over data, sequence over model (for KV),
    d_inner over model (for SSM state)."""
    baxes = batch_spec(mesh, batch_size)
    b = baxes if baxes else None
    msz = mesh.shape[TP] if TP in mesh.axis_names else 1

    def spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        shp = leaf.shape
        if name in ("k", "v"):          # (L, B, S, Hkv, hd)
            s = TP if shp[2] % msz == 0 else None
            return P(None, b, s, None, None)
        if name in ("ck", "cv"):        # (L, B, enc_S, Hkv, hd)
            s = TP if shp[3] % msz == 0 else None
            return P(None, b, None, s, None)
        if name in ("ckv", "krope"):    # (L, B, S, r)
            s = TP if shp[2] % msz == 0 else None
            return P(None, b, s, None)
        if name == "ssm":               # (L, B, di, N) or (L, B, h, p, N)
            if len(shp) == 4:
                s = TP if shp[2] % msz == 0 else None
                return P(None, b, s, None)
            s = TP if shp[2] % msz == 0 else None
            return P(None, b, s, None, None)
        if name == "conv":              # (L, B, K-1, C)
            s = TP if shp[3] % msz == 0 else None
            return P(None, b, None, s)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def pool_specs(pools: dict, mesh) -> dict:
    """Paged-KV pool specs (DESIGN.md §15): page tables are host-local
    integers, so the page axis (axis 1) always replicates — a page id must
    dereference the same physical page on every device. The per-position
    feature axes shard over `model` where divisible: attention heads for
    k/v, the latent/rope rank for MLA's ckv/krope. Shapes are
    (layer_axis, num_pages, page_size, *tail)."""
    msz = mesh.shape[TP] if TP in mesh.axis_names else 1
    specs = {}
    for name, a in pools.items():
        tail = a.shape[3:]
        spec = [None, None, None]
        for i, dim in enumerate(tail):
            # shard the first tail dim that divides (heads for k/v, rank
            # for ckv/krope); everything after it replicates
            if i == 0 and dim % msz == 0 and dim >= msz:
                spec.append(TP)
            else:
                spec.append(None)
        specs[name] = P(*spec)
    return specs


def to_shardings(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
