"""Sequence-sharded decode attention: flash-decoding as an ICI collective.

The KV cache's sequence dim is sharded over the `model` axis. Each device
computes attention over its local KV shard, producing partial
(max m, denom l, weighted-sum acc); the cross-shard combine is three tiny
collectives:

    m*   = pmax(m)
    l*   = psum(l * exp(m - m*))
    out  = psum(acc * exp(m - m*)) / l*

vs. the GSPMD baseline, which reduces over the *masked score tensor* along
the sharded axis (wire O(B*H*S/shards)). Here the wire carries
O(B*H*head_dim) — independent of S. This is the decode hillclimb lever for
decode_32k / long_500k (EXPERIMENTS.md §Perf).

Composition: `make_seq_sharded_decode_attn(mesh)` returns an attn_impl for
`models.decode_step`; it shard_maps ONLY the attention op (manual over
`model`, every other axis stays under GSPMD), so the surrounding model code
is untouched.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map


def _partial_attn(axis, q, k_shard, v_shard, length):
    """Local partial attention + combine. q: (B,1,Hkv,G,hd) replicated;
    k/v_shard: (B, S_loc, Hkv, hd) = this device's sequence shard.
    `axis` may be one name or a tuple (major..minor order of the sharded
    sequence dim)."""
    hd = q.shape[-1]
    scale = hd ** -0.5
    axes = axis if isinstance(axis, tuple) else (axis,)
    idx = 0
    for a in axes:
        # jax.lax.axis_size is 0.5+; psum(1, axis) is the 0.4.x spelling
        size = (jax.lax.axis_size(a) if hasattr(jax.lax, "axis_size")
                else jax.lax.psum(1, a))
        idx = idx * size + jax.lax.axis_index(a)
    s_loc = k_shard.shape[1]
    start = idx * s_loc
    s = jnp.einsum("bqhgd,bshd->bhgqs", q, k_shard,
                   preferred_element_type=jnp.float32) * scale
    pos = start + jnp.arange(s_loc)
    lengthv = jnp.asarray(length)
    ok = (pos[None, :] < lengthv[:, None]) if lengthv.ndim else (pos < lengthv)[None, :]
    s = jnp.where(ok[:, None, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1)
    m_star = jax.lax.pmax(m, axes)
    m_safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(v_shard.dtype), v_shard)
    l_star = jax.lax.psum(l, axes)
    out = jax.lax.psum(acc, axes)
    out = out / jnp.maximum(l_star, 1e-30)[..., None].astype(out.dtype)
    return jnp.moveaxis(out, 3, 1)           # (B,1,Hkv,G,hd)


def make_seq_sharded_decode_attn(mesh, axis="model",
                                 batch_axis: str | None = "data"):
    """attn_impl for models.decode_step / layers.attn_decode_apply.

    Caches must be sharded P(batch_axis, axis, None, None) on (B, S, Hkv, hd);
    `axis` may be a tuple for combined-axis sequence sharding (ws2d layout:
    batch replicated, S over (data, model))."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    b = batch_axis if (batch_axis and batch_axis in mesh.axis_names
                       and batch_axis not in axes) else None

    def attn(q, k_cache, v_cache, length):
        lengthv = jnp.asarray(length)
        len_spec = P(b) if lengthv.ndim else P()
        fn = shard_map(
            partial(_partial_attn, axes),
            mesh=mesh,
            in_specs=(P(b, None, None, None, None),
                      P(b, axis, None, None),
                      P(b, axis, None, None),
                      len_spec),
            out_specs=P(b, None, None, None, None),
            axis_names=set(axes) | ({b} if b else set()),
            check_vma=False,
        )
        return fn(q, k_cache, v_cache,
                  lengthv if lengthv.ndim else lengthv[None])

    return attn
