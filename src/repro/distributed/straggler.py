"""Straggler mitigation for QUEST query execution (DESIGN.md §6).

Documents are partitioned into work units processed by a worker pool; a
deadline-based reissuer duplicates units whose worker exceeds the p95-based
deadline, and the first completion wins (duplicate suppression). The same
pattern drives the serving engine's eviction path at the request level.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable


@dataclass
class WorkUnit:
    uid: int
    payload: object
    attempts: int = 0


@dataclass
class PoolStats:
    completed: int = 0
    reissued: int = 0
    duplicates_suppressed: int = 0
    wall_s: float = 0.0


def run_with_stragglers(units: Iterable, fn: Callable, *, n_workers: int = 4,
                        deadline_factor: float = 3.0, min_deadline_s: float = 0.05,
                        poll_s: float = 0.005, worker_delay=None) -> tuple:
    """Executes fn(payload) per unit with duplicate-on-deadline.

    worker_delay(worker_id) -> extra sleep per unit (test hook to simulate a
    slow node). Returns (results dict uid->value, PoolStats)."""
    t0 = time.time()
    units = [WorkUnit(i, p) for i, p in enumerate(units)]
    todo: "queue.Queue" = queue.Queue()
    for u in units:
        todo.put(u)
    results: dict = {}
    started: dict = {}
    durations: list = []
    lock = threading.Lock()
    stats = PoolStats()
    stop = threading.Event()

    def worker(wid: int):
        while not stop.is_set():
            try:
                u = todo.get(timeout=poll_s)
            except queue.Empty:
                continue
            with lock:
                if u.uid in results:
                    stats.duplicates_suppressed += 1
                    continue
                started[u.uid] = time.time()
            if worker_delay is not None:
                time.sleep(worker_delay(wid))
            val = fn(u.payload)
            with lock:
                if u.uid in results:
                    stats.duplicates_suppressed += 1
                else:
                    results[u.uid] = val
                    stats.completed += 1
                    durations.append(time.time() - started.get(u.uid, time.time()))

    threads = [threading.Thread(target=worker, args=(w,), daemon=True)
               for w in range(n_workers)]
    for t in threads:
        t.start()

    # reissue loop
    while True:
        with lock:
            if len(results) >= len(units):
                break
            if durations:
                med = sorted(durations)[len(durations) // 2]
                deadline = max(min_deadline_s, deadline_factor * med)
            else:
                deadline = None
            now = time.time()
            for u in units:
                if u.uid in results or u.uid not in started:
                    continue
                if deadline is not None and now - started[u.uid] > deadline \
                        and u.attempts == 0:
                    u.attempts += 1
                    stats.reissued += 1
                    todo.put(WorkUnit(u.uid, u.payload, attempts=1))
        time.sleep(poll_s)
    stop.set()
    for t in threads:
        t.join(timeout=1.0)
    stats.wall_s = time.time() - t0
    return results, stats
