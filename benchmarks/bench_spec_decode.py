"""Speculative decoding vs plain decode on the extraction workload
(DESIGN.md §14).

Workload: the scheduler-shaped batch of (doc, attr) extraction needs a
QUEST plan emits over the synthetic SWDE corpus, served three times through
identical engines (paged KV + prefix cache) differing only in the
`spec_decode` knob:

  off           — one target decode invocation per generated token;
  prompt_lookup — n-gram drafting over each request's own prompt+output
                  context (zero extra model cost);
  draft         — draft-model drafting; the smoke workload self-drafts
                  (draft = target), which is the acceptance *ceiling* of
                  the verification machinery — a real deployment pairs a
                  large target with a small zoo config.

All three paths must return byte-identical result rows and identical ledger
token columns (speculation changes how tokens are produced, never which).
The decode economy is what moves: `decode_steps` counts target-model decode
invocations (verify rounds included), and the draft path must do >= 30%
fewer than plain decode at identical rows; acceptance rates are reported
for both drafters.

Emits `benchmarks/out/BENCH_spec_decode.json` (compared against the
committed baseline by `benchmarks/compare.py` in CI) plus a CSV of the
three paths. `--smoke` runs the reduced CI-sized workload.
"""
from __future__ import annotations

import argparse
import csv
import json
import time
from pathlib import Path

import jax

from repro.configs import get_smoke_config
from repro.core.ledger import CostLedger
from repro.core.scheduler import BatchScheduler
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.extract.served import ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_params
from repro.serving.engine import ServingEngine

OUT = Path(__file__).parent / "out"
ATTRS = ["tuition", "enrollment", "university_name"]
MAX_NEW = 32


def _items(corpus, n_docs: int):
    docs = sorted(corpus.tables["universities"])[:n_docs]
    return [(d, a, "universities") for d in docs for a in ATTRS]


def _run_path(corpus, items, *, spec: str, batch: int, params, cfg):
    draft = (cfg, params) if spec == "draft" else None
    engine = ServingEngine(cfg, params, slots=batch, max_len=1024,
                           prefix_cache=True, spec_decode=spec, spec_k=4,
                           draft_model=draft)
    extractor = ServedExtractor(corpus, engine, max_new=MAX_NEW)
    ledger = CostLedger()
    retriever = TwoLevelRetriever(corpus, mode="rag_topk")
    sched = BatchScheduler(retriever, extractor, ledger, {}, batch_size=batch)
    t0 = time.time()
    rows = sched.extract_many(items)
    wall = time.time() - t0
    s = engine.stats
    return {
        "rows": rows,
        "wall_s": wall,
        "decode_steps": s["decode_steps"],
        "decode_slot_steps": s["decode_slot_steps"],
        "spec_rounds": s["spec_rounds"],
        "draft_tokens": s["draft_tokens"],
        "accepted_tokens": s["accepted_tokens"],
        "decode_steps_saved": s["decode_steps_saved"],
        "prefill_tokens": s["prefill_tokens"],
        "draft_model_steps": (engine.drafter.stats.get("draft_model_steps", 0)
                              if engine.drafter else 0),
        "ledger": ledger.snapshot(),
    }


def run(quick: bool = False, smoke: bool = False):
    OUT.mkdir(exist_ok=True)
    small = quick or smoke
    corpus = make_swde_corpus()
    items = _items(corpus, 4 if small else 12)
    batch = 4 if small else 8

    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))

    off = _run_path(corpus, items, spec="off", batch=batch,
                    params=params, cfg=cfg)
    pl = _run_path(corpus, items, spec="prompt_lookup", batch=batch,
                   params=params, cfg=cfg)
    dr = _run_path(corpus, items, spec="draft", batch=batch,
                   params=params, cfg=cfg)

    rows_identical = pl["rows"] == off["rows"] and dr["rows"] == off["rows"]
    ledger_identical = all(
        p["ledger"][c] == off["ledger"][c]
        for p in (pl, dr)
        for c in ("input_tokens", "output_tokens", "total_tokens", "per_phase"))
    red_pl = 1 - pl["decode_steps"] / max(off["decode_steps"], 1)
    red_dr = 1 - dr["decode_steps"] / max(off["decode_steps"], 1)
    acc_pl = pl["accepted_tokens"] / max(pl["draft_tokens"], 1)
    acc_dr = dr["accepted_tokens"] / max(dr["draft_tokens"], 1)

    result = {
        "bench": "spec_decode",
        "smoke": bool(small),
        "items": len(items),
        "batch": batch,
        "max_new": MAX_NEW,
        "rows_identical": rows_identical,
        "ledger_token_columns_identical": ledger_identical,
        "decode_steps_off": off["decode_steps"],
        "decode_steps_pl": pl["decode_steps"],
        "decode_steps_draft": dr["decode_steps"],
        "step_reduction_pl": round(red_pl, 4),
        "step_reduction_draft": round(red_dr, 4),
        "acceptance_rate_pl": round(acc_pl, 4),
        "acceptance_rate_draft": round(acc_dr, 4),
        "draft_tokens_pl": pl["draft_tokens"],
        "accepted_tokens_pl": pl["accepted_tokens"],
        "decode_steps_saved_pl": pl["decode_steps_saved"],
        "decode_steps_saved_draft": dr["decode_steps_saved"],
        "draft_model_steps": dr["draft_model_steps"],
        "wall_off_s": round(off["wall_s"], 3),
        "wall_pl_s": round(pl["wall_s"], 3),
        "wall_draft_s": round(dr["wall_s"], 3),
    }
    with open(OUT / "BENCH_spec_decode.json", "w") as f:
        json.dump(result, f, indent=2)
    with open(OUT / "spec_decode.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["path", "decode_steps", "draft_tokens", "accepted_tokens",
                    "decode_steps_saved", "wall_s"])
        for name, r in (("off", off), ("prompt_lookup", pl), ("draft", dr)):
            w.writerow([name, r["decode_steps"], r["draft_tokens"],
                        r["accepted_tokens"], r["decode_steps_saved"],
                        f"{r['wall_s']:.3f}"])

    print(f"spec_decode: {len(items)} extractions @ batch {batch}, "
          f"max_new {MAX_NEW} | rows identical: {rows_identical} | "
          f"decode invocations off {off['decode_steps']} -> "
          f"prompt_lookup {pl['decode_steps']} ({red_pl:.1%} fewer, "
          f"acceptance {acc_pl:.1%}) -> draft {dr['decode_steps']} "
          f"({red_dr:.1%} fewer, acceptance {acc_dr:.1%}) | wall "
          f"{off['wall_s']:.2f}s / {pl['wall_s']:.2f}s / {dr['wall_s']:.2f}s")

    assert rows_identical, "speculative decoding changed result rows"
    assert ledger_identical, "speculation leaked into ledger token columns"
    assert pl["decode_steps"] <= off["decode_steps"], \
        "prompt-lookup must never need more decode invocations than plain decode"
    assert red_dr >= 0.30, (
        f"draft-path decode-invocation reduction {red_dr:.1%} below the 30% "
        f"bar at identical rows")
    assert pl["decode_steps_saved"] > 0, \
        "prompt-lookup accepted nothing on the extraction workload"
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized workload")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
