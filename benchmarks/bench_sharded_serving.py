"""Sharded serving: DP replica scaling and TP-mesh parity on the extraction
workload (DESIGN.md §15).

Workload: the scheduler-shaped batch of (doc, attr) extraction needs a QUEST
plan emits over the synthetic SWDE corpus, served through three paths that
must return byte-identical result rows and identical ledger token columns:

  single — one `ServingEngine` (paged KV + prefix cache), the baseline;
  dp2    — `ReplicaGroup(replicas=2)`: two engines behind one shared
           admission queue, shared prefix cache and shared KV page pool;
  mesh   — one engine on a (1, 2) tensor-parallel CPU mesh (the module
           forces 4 host devices before jax initializes).

The DP contract is *aggregate throughput at unchanged rows*. In-process
replicas interleave on one host thread, so wall-clock cannot show the win;
the clock unit is a **round** — one target-model invocation (a decode step
or a prefill call), which is what a deployment's step latency is made of.
A replica group's elapsed rounds are the max over its replicas (they run
concurrently in a deployment); `dp2_speedup = rounds_single /
max_replica_rounds` and the gate is >= 1.5x with 2 replicas, i.e. the
shared queue keeps both replicas fed instead of serializing behind one.
Aggregate tokens-per-round is reported alongside (same ratio: the token
totals are identical by the rows invariant).

The mesh path must be invisible in every counter: identical rows AND
identical engine stats to `single` — sharding is a layout change only.

Emits `benchmarks/out/BENCH_sharded_serving.json` (gated against the
committed baseline by `benchmarks/compare.py` in CI) plus a CSV of the
three paths. `--smoke` runs the reduced CI-sized workload.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import time
from pathlib import Path

# must precede the jax import: device count is fixed at backend init
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=4").strip()

import jax

from repro.configs import get_smoke_config
from repro.core.ledger import CostLedger
from repro.core.scheduler import BatchScheduler
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.extract.served import ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.launch.mesh import make_serving_mesh
from repro.models import init_params
from repro.serving.engine import ServingEngine
from repro.serving.replicas import ReplicaGroup

OUT = Path(__file__).parent / "out"
ATTRS = ["tuition", "enrollment", "university_name"]
MAX_NEW = 32
SLOTS = 4

ENGINE_KW = dict(slots=SLOTS, max_len=1024, prefix_cache=True,
                 kv_layout="paged")

# stats columns the mesh path must reproduce exactly (layout invisibility)
STAT_KEYS = ("prefill_tokens", "prefill_invocations", "decode_steps",
             "decode_slot_steps", "prefix_hits", "prefix_saved_tokens",
             "prefix_inserts")


def _items(corpus, n_docs: int):
    docs = sorted(corpus.tables["universities"])[:n_docs]
    return [(d, a, "universities") for d in docs for a in ATTRS]


def _rounds(stats: dict) -> int:
    """One round = one target-model invocation (decode step or prefill
    call) — the bench's clock unit; see the module docstring."""
    return stats["decode_steps"] + stats["prefill_invocations"]


def _run_path(corpus, items, engine, *, batch: int):
    extractor = ServedExtractor(corpus, engine, max_new=MAX_NEW)
    ledger = CostLedger()
    retriever = TwoLevelRetriever(corpus, mode="rag_topk")
    sched = BatchScheduler(retriever, extractor, ledger, {}, batch_size=batch)
    t0 = time.time()
    rows = sched.extract_many(items)
    return rows, time.time() - t0, ledger.snapshot()


def run(quick: bool = False, smoke: bool = False):
    OUT.mkdir(exist_ok=True)
    small = quick or smoke
    corpus = make_swde_corpus()
    items = _items(corpus, 4 if small else 12)
    batch = 2 * SLOTS                      # fills both dp2 replicas per round

    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))

    single = ServingEngine(cfg, params, **ENGINE_KW)
    rows_s, wall_s, led_s = _run_path(corpus, items, single, batch=batch)

    grp = ReplicaGroup(cfg, params, replicas=2, **ENGINE_KW)
    rows_d, wall_d, led_d = _run_path(corpus, items, grp, batch=batch)

    mesh_eng = ServingEngine(cfg, params, mesh=make_serving_mesh((1, 2)),
                             **ENGINE_KW)
    rows_m, wall_m, led_m = _run_path(corpus, items, mesh_eng, batch=batch)

    dp2_rows_identical = rows_d == rows_s
    mesh_rows_identical = rows_m == rows_s
    ledger_identical = all(
        led[c] == led_s[c]
        for led in (led_d, led_m)
        for c in ("input_tokens", "output_tokens", "total_tokens", "per_phase"))
    mesh_stats_identical = all(
        mesh_eng.stats[k] == single.stats[k] for k in STAT_KEYS)

    rounds_single = _rounds(single.stats)
    per_replica = [_rounds(e.stats) for e in grp.engines]
    rounds_dp2_max = max(per_replica)
    dp2_speedup = rounds_single / max(rounds_dp2_max, 1)
    dp2_balance = min(per_replica) / max(rounds_dp2_max, 1)
    gen_tokens = led_s["output_tokens"]
    tpr_single = gen_tokens / max(rounds_single, 1)
    tpr_dp2 = gen_tokens / max(rounds_dp2_max, 1)

    result = {
        "bench": "sharded_serving",
        "smoke": bool(small),
        "items": len(items),
        "slots": SLOTS,
        "replicas": 2,
        "mesh_shape": "1x2",
        "max_new": MAX_NEW,
        "dp2_rows_identical": dp2_rows_identical,
        "mesh_rows_identical": mesh_rows_identical,
        "ledger_token_columns_identical": ledger_identical,
        "mesh_stats_identical": mesh_stats_identical,
        "rounds_single": rounds_single,
        "rounds_dp2_max": rounds_dp2_max,
        "rounds_dp2_per_replica": per_replica,
        "dp2_speedup": round(dp2_speedup, 4),
        "dp2_balance": round(dp2_balance, 4),
        "tokens_per_round_single": round(tpr_single, 4),
        "tokens_per_round_dp2": round(tpr_dp2, 4),
        "decode_steps_single": single.stats["decode_steps"],
        "decode_steps_mesh": mesh_eng.stats["decode_steps"],
        "prefix_hits_dp2": grp.stats["prefix_hits"],
        "prefix_inserts_dp2": grp.stats["prefix_inserts"],
        "wall_single_s": round(wall_s, 3),
        "wall_dp2_s": round(wall_d, 3),
        "wall_mesh_s": round(wall_m, 3),
    }
    with open(OUT / "BENCH_sharded_serving.json", "w") as f:
        json.dump(result, f, indent=2)
    with open(OUT / "sharded_serving.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["path", "rounds", "tokens_per_round", "wall_s"])
        w.writerow(["single", rounds_single, f"{tpr_single:.3f}",
                    f"{wall_s:.3f}"])
        w.writerow(["dp2", rounds_dp2_max, f"{tpr_dp2:.3f}", f"{wall_d:.3f}"])
        w.writerow(["mesh_1x2", _rounds(mesh_eng.stats), f"{tpr_single:.3f}",
                    f"{wall_m:.3f}"])

    print(f"sharded_serving: {len(items)} extractions @ {SLOTS} slots | "
          f"rows identical: dp2 {dp2_rows_identical}, mesh "
          f"{mesh_rows_identical} | rounds single {rounds_single} -> dp2 "
          f"max-replica {rounds_dp2_max} ({dp2_speedup:.2f}x aggregate, "
          f"balance {dp2_balance:.2f}) | tokens/round {tpr_single:.2f} -> "
          f"{tpr_dp2:.2f} | wall {wall_s:.2f}s / {wall_d:.2f}s / "
          f"{wall_m:.2f}s")

    assert dp2_rows_identical, "replica group changed result rows"
    assert mesh_rows_identical, "mesh engine changed result rows"
    assert ledger_identical, "replica/mesh serving leaked into ledger columns"
    assert mesh_stats_identical, (
        "mesh engine's counters diverged from single-device: "
        + str({k: (mesh_eng.stats[k], single.stats[k]) for k in STAT_KEYS}))
    assert dp2_speedup >= 1.5, (
        f"2-replica aggregate speedup {dp2_speedup:.2f}x below the 1.5x bar "
        f"(per-replica rounds {per_replica} vs single {rounds_single})")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized workload")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
