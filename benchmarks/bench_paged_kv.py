"""Paged KV cache + chunked prefill vs the slab layout (DESIGN.md §12).

Workload: the scheduler-shaped batch of (doc, attr) extraction needs a
QUEST plan emits over the synthetic SWDE corpus, run through the serving
engine twice with the shared-prefix KV cache ON in both:

  slab   — PR 2's layout: per-slot contiguous KV; a prefix hit copies a
           materialized snapshot into the slot and the unshared suffix
           prefills one token per decode step.
  paged  — block/page-table layout: a prefix hit is an O(1) page-id splice
           (copy-on-write boundary page) and the suffix prefills in
           fixed-size chunks.

Both paths must return byte-identical result rows. The paged path must do
strictly fewer prefill jit invocations (chunks vs per-token suffix steps),
compute against materially fewer KV positions during prefill (the
attention-FLOPs proxy `prefill_ctx_positions` — token-steps pay the whole
max_len buffer each, chunks only their pow2-bucketed context view), and
peak at fewer KV-cache bytes (pages in use vs full per-slot slabs + a
snapshot copy per prefix entry). Wall-clock improves at batch >= 8 (full
mode; reported in smoke too, asserted only where CI noise can't flake it).

Emits `benchmarks/out/BENCH_paged_kv.json` (compared against the committed
baseline by `benchmarks/compare.py` in CI) plus a CSV of both paths.
`--smoke` runs the reduced CI-sized workload.
"""
from __future__ import annotations

import argparse
import csv
import json
import time
from pathlib import Path

import jax

from repro.configs import get_smoke_config
from repro.core.ledger import CostLedger
from repro.core.scheduler import BatchScheduler
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.extract.served import ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_params
from repro.serving.engine import ServingEngine

OUT = Path(__file__).parent / "out"
ATTRS = ["tuition", "enrollment", "university_name"]


def _items(corpus, n_docs: int):
    docs = sorted(corpus.tables["universities"])[:n_docs]
    return [(d, a, "universities") for d in docs for a in ATTRS]


def _run_path(corpus, items, *, layout: str, batch: int):
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, slots=batch, max_len=1024,
                           prefix_cache=True, kv_layout=layout)
    extractor = ServedExtractor(corpus, engine, max_new=8)
    ledger = CostLedger()
    retriever = TwoLevelRetriever(corpus, mode="rag_topk")
    sched = BatchScheduler(retriever, extractor, ledger, {}, batch_size=batch)
    t0 = time.time()
    rows = sched.extract_many(items)
    wall = time.time() - t0
    s = engine.stats
    return {
        "rows": rows,
        "wall_s": wall,
        "prefill_tokens": s["prefill_tokens"],
        "prefill_invocations": s["prefill_invocations"],
        "prefill_chunks": s["prefill_chunks"],
        "prefill_ctx_positions": s["prefill_ctx_positions"],
        "prefix_hits": s["prefix_hits"],
        "prefix_saved_tokens": s["prefix_saved_tokens"],
        "cow_copies": s["cow_copies"],
        "kv_bytes_peak": s["kv_bytes_peak"],
        "decode_steps": s["decode_steps"],
        "ledger": ledger.snapshot(),
    }


def run(quick: bool = False, smoke: bool = False):
    OUT.mkdir(exist_ok=True)
    small = quick or smoke
    corpus = make_swde_corpus()
    items = _items(corpus, 6 if small else 16)
    batch = 8

    slab = _run_path(corpus, items, layout="slab", batch=batch)
    paged = _run_path(corpus, items, layout="paged", batch=batch)

    rows_identical = paged["rows"] == slab["rows"]
    ledger_identical = all(paged["ledger"][c] == slab["ledger"][c]
                           for c in ("input_tokens", "output_tokens",
                                     "total_tokens", "per_phase"))
    inv_ratio = paged["prefill_invocations"] / max(slab["prefill_invocations"], 1)
    ctx_ratio = paged["prefill_ctx_positions"] / max(slab["prefill_ctx_positions"], 1)
    bytes_ratio = paged["kv_bytes_peak"] / max(slab["kv_bytes_peak"], 1)
    wall_ratio = paged["wall_s"] / max(slab["wall_s"], 1e-9)

    result = {
        "bench": "paged_kv",
        "smoke": bool(small),
        "items": len(items),
        "batch": batch,
        "rows_identical": rows_identical,
        "ledger_token_columns_identical": ledger_identical,
        "prefill_tokens_slab": slab["prefill_tokens"],
        "prefill_tokens_paged": paged["prefill_tokens"],
        "prefill_invocations_slab": slab["prefill_invocations"],
        "prefill_invocations_paged": paged["prefill_invocations"],
        "prefill_invocation_ratio": round(inv_ratio, 4),
        "prefill_ctx_positions_slab": slab["prefill_ctx_positions"],
        "prefill_ctx_positions_paged": paged["prefill_ctx_positions"],
        "prefill_ctx_ratio": round(ctx_ratio, 4),
        "kv_bytes_peak_slab": slab["kv_bytes_peak"],
        "kv_bytes_peak_paged": paged["kv_bytes_peak"],
        "kv_bytes_ratio": round(bytes_ratio, 4),
        "prefix_hits": paged["prefix_hits"],
        "cow_copies": paged["cow_copies"],
        "wall_slab_s": round(slab["wall_s"], 3),
        "wall_paged_s": round(paged["wall_s"], 3),
        "wall_ratio_paged_over_slab": round(wall_ratio, 4),
    }
    with open(OUT / "BENCH_paged_kv.json", "w") as f:
        json.dump(result, f, indent=2)
    with open(OUT / "paged_kv.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["path", "prefill_tokens", "prefill_invocations",
                    "prefill_ctx_positions", "kv_bytes_peak", "prefix_hits",
                    "wall_s"])
        for name, r in (("slab", slab), ("paged", paged)):
            w.writerow([name, r["prefill_tokens"], r["prefill_invocations"],
                        r["prefill_ctx_positions"], r["kv_bytes_peak"],
                        r["prefix_hits"], f"{r['wall_s']:.3f}"])

    print(f"paged_kv: {len(items)} extractions @ batch {batch} | "
          f"rows identical: {rows_identical} | prefill invocations "
          f"{slab['prefill_invocations']} -> {paged['prefill_invocations']} "
          f"({1 - inv_ratio:.1%} fewer) | prefill ctx positions "
          f"{slab['prefill_ctx_positions']} -> {paged['prefill_ctx_positions']} "
          f"({1 - ctx_ratio:.1%} fewer) | kv bytes peak "
          f"{slab['kv_bytes_peak']} -> {paged['kv_bytes_peak']} "
          f"({1 - bytes_ratio:.1%} lower) | wall "
          f"{slab['wall_s']:.2f}s -> {paged['wall_s']:.2f}s")

    assert rows_identical, "paged layout changed result rows"
    assert ledger_identical, "paged layout leaked into ledger token columns"
    assert paged["prefill_tokens"] == slab["prefill_tokens"], \
        "logical prefill-token accounting must be layout-invariant"
    assert paged["prefill_invocations"] < slab["prefill_invocations"], \
        "chunked prefill must use fewer jit invocations than per-token suffix"
    assert ctx_ratio < 0.5, (
        f"prefill ctx-position (FLOPs proxy) ratio {ctx_ratio:.2f} not "
        f"materially lower")
    assert bytes_ratio < 1.0, (
        f"paged peak KV bytes {paged['kv_bytes_peak']} not below slab "
        f"{slab['kv_bytes_peak']}")
    if not small:
        assert wall_ratio < 1.0, (
            f"paged wall {paged['wall_s']:.2f}s not below slab "
            f"{slab['wall_s']:.2f}s at batch {batch}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized workload")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
