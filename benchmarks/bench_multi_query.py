"""Cross-query multiplexing through one Session vs. serial sessions
(DESIGN.md §11).

Workload: two analytics queries on the same table (the second query's
attributes covered by the first's), run through the real serving engine
three ways:

  serial-sessions   two independent Sessions over two fresh engines —
                    each query pays its own sampling phase and warms its
                    own prefix cache (the pre-session cost model);
  shared-serial     one Session, queries submitted back to back — the
                    second query reuses the first's sampling investment;
  shared-concurrent one Session, both queries in flight at once — their
                    document coroutines feed the same scheduler rounds,
                    so extractions from different queries batch into the
                    same `engine.run()` calls and share prefix groups.

Checks (acceptance criteria of the session layer):
  * shared-concurrent rows are identical per query to shared-serial rows;
  * the second query's sampling-phase token column is 0 via stats reuse;
  * the shared engine needs fewer total `engine.run()` rounds and gets a
    higher prefix-cache hit *rate* than the two serial sessions combined.

Emits `benchmarks/out/BENCH_multi_query.json` (uploaded as a CI artifact
per run) plus a CSV of the three paths. `--smoke` runs the reduced
CI-sized workload.
"""
from __future__ import annotations

import argparse
import csv
import json
import time
from pathlib import Path

import jax

from repro.configs import get_smoke_config
from repro.core import Filter, Query, Session, conj
from repro.data import lm_data
from repro.data.corpus import Corpus, make_swde_corpus
from repro.extract.served import ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_params
from repro.serving.engine import ServingEngine

OUT = Path(__file__).parent / "out"


def _corpus(small: bool) -> Corpus:
    full = make_swde_corpus()
    if not small:
        return full
    n = 10
    uni = [d for d in sorted(full.docs) if "universities" in d][:n]
    lap = [d for d in sorted(full.docs) if "laptops" in d][:n]
    return full.subset(uni + lap)


def _queries():
    q1 = Query(tables=["universities"],
               select=[("universities", "university_name")],
               where=conj(Filter("tuition", "<", 30000, table="universities"),
                          Filter("enrollment", ">", 20000,
                                 table="universities")))
    # attrs ⊆ q1's sampled set -> eligible for sampling reuse
    q2 = Query(tables=["universities"],
               select=[("universities", "university_name")],
               where=Filter("enrollment", ">", 30000, table="universities"))
    return q1, q2


def _fresh_session(corpus, cfg, params, batch):
    engine = ServingEngine(cfg, params, slots=batch, max_len=1024,
                           prefix_cache=True)
    extractor = ServedExtractor(corpus, engine, max_new=6)
    sess = Session(TwoLevelRetriever(corpus), extractor, batch_size=batch)
    return sess, engine


def _row_keys(res):
    return sorted(tuple(sorted(r["_docs"].items())) for r in res.rows)


def run(quick: bool = False, smoke: bool = False):
    OUT.mkdir(exist_ok=True)
    small = quick or smoke
    corpus = _corpus(small)
    batch = 4 if small else 8
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    q1, q2 = _queries()

    # --- serial sessions: two engines, two sampling phases ----------------
    t0 = time.time()
    sess_a, eng_a = _fresh_session(corpus, cfg, params, batch)
    r1_serial = sess_a.execute(q1)
    sess_b, eng_b = _fresh_session(corpus, cfg, params, batch)
    r2_serial = sess_b.execute(q2)
    wall_serial = time.time() - t0
    serial_runs = eng_a.stats["runs"] + eng_b.stats["runs"]
    serial_reqs = sess_a.extractor.stats.requests + \
        sess_b.extractor.stats.requests
    serial_hits = eng_a.stats["prefix_hits"] + eng_b.stats["prefix_hits"]
    serial_prefill = eng_a.stats["prefill_tokens"] + eng_b.stats["prefill_tokens"]

    # --- shared session, serial submits (row-identity reference) ----------
    sess_ref, _eng_ref = _fresh_session(corpus, cfg, params, batch)
    ref1 = sess_ref.execute(q1)
    ref2 = sess_ref.execute(q2)

    # --- shared session, concurrent submits -------------------------------
    t0 = time.time()
    sess_m, eng_m = _fresh_session(corpus, cfg, params, batch)
    h1 = sess_m.submit(sess_m.prepare(q1))
    h2 = sess_m.submit(sess_m.prepare(q2))
    sess_m.drain()
    r1_multi, r2_multi = h1.result(), h2.result()
    wall_multi = time.time() - t0
    multi_runs = eng_m.stats["runs"]
    multi_reqs = sess_m.extractor.stats.requests
    multi_hits = eng_m.stats["prefix_hits"]
    multi_prefill = eng_m.stats["prefill_tokens"]

    rows_identical = (_row_keys(r1_multi) == _row_keys(ref1)
                      and _row_keys(r2_multi) == _row_keys(ref2))
    q2_sampling_multi = r2_multi.ledger.per_phase.get("sampling", 0)
    q2_sampling_serial = r2_serial.ledger.per_phase.get("sampling", 0)
    # prefix *misses* (cold template prefills) are the sharing metric: the
    # shared session warms each (attr, table) template once across BOTH
    # queries, where serial sessions each re-warm their own prefix cache.
    # (Raw hit counts can only fall when sampling reuse removes the very
    # requests that would have hit.)
    serial_misses = serial_reqs - serial_hits
    multi_misses = multi_reqs - multi_hits

    result = {
        "bench": "multi_query", "smoke": bool(small), "batch": batch,
        "docs": len(corpus.docs),
        "rows_q1": len(r1_multi.rows), "rows_q2": len(r2_multi.rows),
        "rows_identical_to_serial_session": rows_identical,
        "q2_sampling_tokens_serial_sessions": q2_sampling_serial,
        "q2_sampling_tokens_shared": q2_sampling_multi,
        "q2_sampling_reused": r2_multi.meta["sampling_reused"],
        "engine_runs_serial_sessions": serial_runs,
        "engine_runs_shared": multi_runs,
        "prefix_hits_serial_sessions": serial_hits,
        "prefix_hits_shared": multi_hits,
        "prefix_misses_serial_sessions": serial_misses,
        "prefix_misses_shared": multi_misses,
        "prefill_tokens_serial_sessions": serial_prefill,
        "prefill_tokens_shared": multi_prefill,
        "requests_serial_sessions": serial_reqs,
        "requests_shared": multi_reqs,
        "total_tokens_serial_sessions":
            r1_serial.ledger.total_tokens + r2_serial.ledger.total_tokens,
        "total_tokens_shared": sess_m.ledger.total_tokens,
        "wall_serial_s": round(wall_serial, 3),
        "wall_shared_s": round(wall_multi, 3),
    }
    with open(OUT / "BENCH_multi_query.json", "w") as f:
        json.dump(result, f, indent=2)
    with open(OUT / "multi_query.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["path", "engine_runs", "requests", "prefix_hits",
                    "prefix_misses", "prefill_tokens", "q2_sampling_tokens",
                    "total_tokens", "wall_s"])
        w.writerow(["serial-sessions", serial_runs, serial_reqs, serial_hits,
                    serial_misses, serial_prefill, q2_sampling_serial,
                    result["total_tokens_serial_sessions"],
                    f"{wall_serial:.3f}"])
        w.writerow(["shared-concurrent", multi_runs, multi_reqs, multi_hits,
                    multi_misses, multi_prefill, q2_sampling_multi,
                    result["total_tokens_shared"], f"{wall_multi:.3f}"])

    print(f"multi_query: runs {serial_runs} -> {multi_runs} | "
          f"q2 sampling tokens {q2_sampling_serial} -> {q2_sampling_multi} | "
          f"prefix misses {serial_misses} -> {multi_misses} | "
          f"prefill tokens {serial_prefill} -> {multi_prefill} | "
          f"rows identical: {rows_identical} | "
          f"wall {wall_serial:.1f}s -> {wall_multi:.1f}s")

    assert rows_identical, "concurrent execution changed result rows"
    assert q2_sampling_multi == 0, (
        "second query paid a sampling phase despite covered attrs")
    assert q2_sampling_serial > 0, (
        "serial-sessions baseline unexpectedly skipped sampling")
    assert multi_runs < serial_runs, (
        f"shared session used {multi_runs} engine runs vs {serial_runs} "
        f"serial — multiplexing should merge rounds")
    assert multi_misses < serial_misses, (
        f"cross-query prefix sharing did not reduce cold prefills: "
        f"{multi_misses} misses vs {serial_misses} serial")
    assert multi_prefill < serial_prefill, (
        f"shared session prefilled more tokens ({multi_prefill}) than the "
        f"serial sessions ({serial_prefill})")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized workload")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
