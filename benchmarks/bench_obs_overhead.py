"""Observability overhead gate (DESIGN.md §19).

Telemetry must observe, never perturb. This bench runs the same oracle
three-query session workload with tracing off (the `NULL_TRACER` default)
and fully on (`Tracer(clock="ticks", level=2)` — every span site firing,
per-barrier instants included) and gates, against the committed baseline:

  invariants — rows byte-identical on vs. off; ledger token columns
               (input/output tokens, llm_calls, extractions, per_phase)
               byte-identical; session/scheduler counter snapshots
               byte-identical; two traced runs byte-identical JSONL
               (tick-clock determinism on the full workload); median
               traced wall within the 5% overhead budget;
  counters   — spans_emitted (trace coverage must not silently shrink).

Wall measurement: median of `reps` alternating off/on runs — the oracle
workload is pure Python, so the median is stable enough to hold a 5%
budget without wall-clock noise dominating. The fraction is also
reported (`wall_overhead_fraction`) but gated only through the invariant
(spec_decode precedent: report walls, gate determinism).

Emits `benchmarks/out/BENCH_obs_overhead.json`, gated by
`compare.py --bench obs_overhead`.
"""
from __future__ import annotations

import argparse
import json
import statistics
import time
from pathlib import Path

from repro.core import Filter, Query, Session, conj
from repro.data.corpus import make_wiki_corpus
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.obs import LEVEL_FULL, Tracer

OUT = Path(__file__).parent / "out"

OVERHEAD_BUDGET = 0.05          # traced wall <= 1.05x untraced (median)

LEDGER_COLUMNS = ("input_tokens", "output_tokens", "llm_calls",
                  "extractions", "batches", "batched_extractions",
                  "max_batch", "per_phase")


def _queries():
    return [
        Query(tables=["players"], select=[("players", "player_name")],
              where=conj(Filter("age", ">", 30, table="players"),
                         Filter("all_stars", ">=", 5, table="players"))),
        Query(tables=["teams"], select=[("teams", "location")],
              where=Filter("championships", ">", 14, table="teams")),
        Query(tables=["owners"], select=[("owners", "industry")],
              where=Filter("net_worth", ">", 3.0, table="owners")),
    ]


def _run_once(corpus, tracer):
    """One multiplexed three-query session; returns (rows per query,
    ledger snapshot, scheduler counter snapshot, wall seconds)."""
    sess = Session(TwoLevelRetriever(corpus), OracleExtractor(corpus),
                   batch_size=8, tracer=tracer)
    t0 = time.perf_counter()
    handles = [sess.submit(q) for q in _queries()]
    results = [h.result() for h in handles]
    wall = time.perf_counter() - t0
    rows = [sorted(tuple(sorted(r["_docs"].items())) for r in res.rows)
            for res in results]
    snap = sess.ledger.snapshot()
    ledger = {k: snap[k] for k in LEDGER_COLUMNS}
    return rows, ledger, sess.scheduler.stats.snapshot(), wall


def run(smoke: bool = False, quick: bool = False):
    OUT.mkdir(exist_ok=True)
    small = smoke or quick
    reps = 5 if small else 9
    corpus = make_wiki_corpus(seed=0)

    # determinism: two fresh fully-traced runs, byte-identical JSONL
    tr_a = Tracer(clock="ticks", level=LEVEL_FULL)
    tr_b = Tracer(clock="ticks", level=LEVEL_FULL)
    rows_a, ledger_a, sched_a, _ = _run_once(corpus, tr_a)
    _run_once(corpus, tr_b)
    trace_deterministic = tr_a.to_jsonl() == tr_b.to_jsonl()

    # parity: untraced run must match the traced one byte for byte
    rows_off, ledger_off, sched_off, _ = _run_once(corpus, None)
    rows_identical = rows_a == rows_off
    ledger_identical = ledger_a == ledger_off
    counters_identical = sched_a == sched_off

    # overhead: alternate off/on, median wall each
    walls_off, walls_on = [], []
    for _ in range(reps):
        walls_off.append(_run_once(corpus, None)[3])
        walls_on.append(_run_once(
            corpus, Tracer(clock="ticks", level=LEVEL_FULL))[3])
    wall_off = statistics.median(walls_off)
    wall_on = statistics.median(walls_on)
    overhead = wall_on / wall_off - 1.0

    result = {
        "bench": "obs_overhead", "smoke": bool(small),
        "reps": reps, "queries": len(_queries()),
        # invariants
        "rows_identical": bool(rows_identical),
        "ledger_token_columns_identical": bool(ledger_identical),
        "counters_identical": bool(counters_identical),
        "trace_deterministic": bool(trace_deterministic),
        "overhead_within_budget": bool(overhead <= OVERHEAD_BUDGET),
        # gated counter: trace coverage must not silently shrink
        "spans_emitted": len(tr_a.spans),
        # reported context
        "overhead_budget": OVERHEAD_BUDGET,
        "wall_overhead_fraction": round(overhead, 4),
        "wall_off_s": round(wall_off, 4),
        "wall_on_s": round(wall_on, 4),
        "ledger_tokens": ledger_a["input_tokens"] + ledger_a["output_tokens"],
    }
    with open(OUT / "BENCH_obs_overhead.json", "w") as f:
        json.dump(result, f, indent=2)

    print(f"obs_overhead: {len(tr_a.spans)} spans over "
          f"{result['queries']} queries | wall {wall_off*1e3:.1f}ms off -> "
          f"{wall_on*1e3:.1f}ms on ({overhead:+.2%}, budget "
          f"{OVERHEAD_BUDGET:.0%}) | rows identical: {rows_identical} | "
          f"counters identical: {counters_identical} | "
          f"trace deterministic: {trace_deterministic}")

    assert rows_identical, "tracing changed result rows"
    assert ledger_identical, "tracing changed ledger token columns"
    assert counters_identical, "tracing changed scheduler counters"
    assert trace_deterministic, "tick-clock traces were not byte-identical"
    assert overhead <= OVERHEAD_BUDGET, (
        f"tracing overhead {overhead:.2%} exceeds the "
        f"{OVERHEAD_BUDGET:.0%} budget")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized workload")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke, quick=args.quick)
