"""Serial vs batched cross-document execution on the real serving engine
(DESIGN.md §9).

Workload: QUEST-style extraction calls over the synthetic SWDE corpus — the
retriever's segments become real prompts, prefill/decode run through
`ServingEngine`. The serial path is the seed behaviour (one request, one
`engine.run()` per extraction, slots=1); the batched path submits the whole
batch and drains it with a single continuous-batching round (slots=batch).
Both engines are warmed on the same prompt lengths first so jit compiles
don't pollute the timing.

Reported per batch size: wall-clock, tokens/sec (prompt + generated), and
the speedup over serial. Acceptance target: >= 2x tokens/sec at batch >= 8.
"""
from __future__ import annotations

import csv
import time
from pathlib import Path

import jax

from repro.configs import get_smoke_config
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.extract.served import ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_params
from repro.serving.engine import ServingEngine

OUT = Path(__file__).parent / "out"


def _workload(corpus, retriever, n_items: int):
    """(doc, attr, segments) extraction items, as the scheduler would emit."""
    items = []
    attrs = ["tuition", "enrollment", "university_name"]
    for doc_id in sorted(corpus.tables["universities"]):
        for attr in attrs:
            segs = retriever.segments(doc_id, attr, "universities")
            if segs:
                items.append((doc_id, attr, segs))
            if len(items) >= n_items:
                return items
    return items


def _run_batched(extractor, items, batch: int):
    t0 = time.time()
    for i in range(0, len(items), batch):
        extractor.extract_batch(items[i:i + batch])
    dt = time.time() - t0
    toks = extractor.stats.prompt_tokens + extractor.stats.generated_tokens
    return dt, toks


def run(quick: bool = False):
    OUT.mkdir(exist_ok=True)
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_swde_corpus()
    retriever = TwoLevelRetriever(corpus)

    n_items = 16 if quick else 48
    max_new = 12
    items = _workload(corpus, retriever, n_items)
    batches = [1, 8] if quick else [1, 4, 8, 16]

    # size the KV window to the workload (smallest power of two that fits
    # prompt + generation): decode attends over the whole window every step,
    # so an oversized cache buries the batching win under padded attention
    prompt_lens = [len(lm_data.encode(f"Extract {a}. Context: {' '.join(s)} Answer:"))
                   for _, a, s in items]
    max_len = 64
    while max_len < max(prompt_lens) + max_new + 1:
        max_len *= 2

    rows = []
    serial_tps = None
    for batch in batches:
        engine = ServingEngine(cfg, params, slots=batch, max_len=max_len)
        extractor = ServedExtractor(corpus, engine, max_new=max_new)
        _run_batched(extractor, items, batch)        # warm jit caches
        # best-of-N: host timings on shared CPUs are noisy, and the
        # per-round token count is deterministic, so min wall = least noise
        dt = float("inf")
        for _ in range(2 if quick else 3):
            extractor.stats = type(extractor.stats)()    # reset counters
            engine.stats = {k: 0 for k in engine.stats}
            rep_dt, toks = _run_batched(extractor, items, batch)
            dt = min(dt, rep_dt)
        tps = toks / max(dt, 1e-9)
        if batch == 1:
            serial_tps = tps
        speedup = tps / serial_tps if serial_tps else float("nan")
        rows.append((batch, len(items), dt, tps, speedup,
                     engine.stats["runs"], engine.stats["decode_steps"]))
        print(f"batch={batch:3d}  wall={dt:6.2f}s  tokens/s={tps:8.1f}  "
              f"speedup={speedup:4.2f}x  engine_runs={engine.stats['runs']}  "
              f"decode_steps={engine.stats['decode_steps']}")

    with open(OUT / "batching.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["batch", "items", "wall_s", "tokens_per_s", "speedup",
                    "engine_runs", "decode_steps"])
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run()
