"""Figure 7: join evaluation.

(a) two-table joins: QUEST (transform) vs Pushdown vs Optimal (true
    selectivities + exhaustive plan choice), grouped by filter count (G1-G3)
    and by realized IN-filter selectivity (E1-E3);
(b) multi-table joins (players-teams-cities / players-teams-owners):
    QUEST adaptive ordering vs Random edge order vs Pushdown vs Optimal.
"""
from __future__ import annotations

import csv
import random
from pathlib import Path

from repro.core import Engine, Filter, JoinEdge, Query, conj
from repro.core.expr import evaluate_expr
from repro.extract import OracleExtractor

from .common import BenchContext, Method, prf

OUT = Path(__file__).parent / "out"

JOINS = {
    ("players", "teams"): JoinEdge("players", "team_name", "teams", "team_name"),
    ("teams", "cities"): JoinEdge("teams", "location", "cities", "city_name"),
    ("teams", "owners"): JoinEdge("teams", "owner_name", "owners", "owner_name"),
}
NUMERIC = {
    "players": [("age", 25, 40), ("all_stars", 2, 12), ("ppg", 8.0, 25.0)],
    "teams": [("championships", 2, 15), ("founded", 1950, 1995),
              ("arena_capacity", 16000, 21000)],
    "cities": [("population", 100_000, 1_500_000), ("founded_year", 1800, 1900)],
    "owners": [("net_worth", 3.0, 30.0), ("owner_age", 45, 80)],
}


def _rand_filters(rng, table, k):
    out = []
    for attr, lo, hi in rng.sample(NUMERIC[table], min(k, len(NUMERIC[table]))):
        v = lo + (hi - lo) * rng.random()
        if isinstance(lo, int):
            v = int(v)
        else:
            v = round(v, 1)
        out.append(Filter(attr, rng.choice([">", "<"]), v, table=table))
    return out


def make_join_queries(rng, n, *, tables=("players", "teams"), k_filters=(1, 2)):
    edge = JOINS[tables]
    out = []
    for _ in range(n):
        f1 = _rand_filters(rng, tables[0], rng.randint(*k_filters))
        f2 = _rand_filters(rng, tables[1], rng.randint(*k_filters))
        expr = conj(*(f1 + f2))
        out.append(Query(tables=list(tables),
                         select=[(tables[0], list(NUMERIC[tables[0]])[0][0])],
                         where=expr, joins=[edge]))
    return out


def join_truth(corpus, query: Query):
    """Ground-truth joined rows (docs tuples)."""
    tabs = list(query.tables)
    rows = [{tabs[0]: d} for d in corpus.truth_rows(tabs[0])]
    for e in query.joins:
        t1, a1, t2, a2 = e.left_table, e.left_attr, e.right_table, e.right_attr
        if t1 not in rows[0] if rows else True:
            t1, a1, t2, a2 = t2, a2, t1, a1
        tr2 = corpus.truth_rows(t2)
        new = []
        for r in rows:
            v = corpus.truth_rows(t1)[r[t1]][a1]
            for d2, t in tr2.items():
                if t[a2] == v:
                    nr = dict(r)
                    nr[t2] = d2
                    new.append(nr)
        rows = new
    out = set()
    for r in rows:
        ok = True
        for t, d in r.items():
            truth = corpus.truth_rows(t)[d]
            expr = query.where_for(t)
            if expr is not None and not evaluate_expr(expr, truth):
                ok = False
                break
        if ok:
            out.add(tuple(sorted(r.items())))
    return out


def result_join_rows(res):
    return {tuple(sorted(r["_docs"].items())) for r in res.rows}


class OracleStatsEngine(Engine):
    """`Optimal` baseline: the engine but with ground-truth selectivities
    (wired in through the session's table-context hook)."""

    def __init__(self, *args, corpus=None, **kw):
        super().__init__(*args, **kw)
        self._corpus = corpus

    def _wrap_table_context(self, ctx, query):
        truth = self._corpus.truth_rows(ctx.name)

        class TruthStats:
            def __init__(s, inner):
                s.inner = inner
            def selectivity(s, flt):
                vals = [t.get(flt.attr) for t in truth.values()]
                sat = sum(1 for v in vals if flt.evaluate(v))
                return max(0.01, min(0.99, sat / max(len(vals), 1)))
            def in_filter_selectivity(s, attr, allowed):
                vals = [t.get(attr) for t in truth.values()]
                sat = sum(1 for v in vals if v in allowed)
                return max(0.01, min(0.99, sat / max(len(vals), 1)))
            def mean_cost(s, attr, default=500.0):
                return s.inner.mean_cost(attr, default)
            @property
            def sampled_values(s):
                return s.inner.sampled_values
            def values(s, attr):
                return s.inner.values(attr)

        ctx.stats = TruthStats(ctx.stats)
        return ctx


def run(ctx: BenchContext | None = None, quick: bool = False):
    ctx = ctx or BenchContext()
    OUT.mkdir(exist_ok=True)
    corpus = ctx.corpus("wiki")
    rng = random.Random(71)
    rows = []

    def execute(query, variant, qi):
        retr = ctx.retriever("wiki", "quest").fork()
        kw = dict(seed=qi)
        if variant == "Pushdown":
            eng = Engine(retr, OracleExtractor(corpus), join_strategy="pushdown", **kw)
        elif variant == "Optimal":
            eng = OracleStatsEngine(retr, OracleExtractor(corpus), corpus=corpus, **kw)
        else:
            eng = Engine(retr, OracleExtractor(corpus), **kw)
        return eng.execute(query)

    # (a) two-table joins, grouped by #filters
    groups = {"G1": (1, 1), "G2": (2, 2), "G3": (3, 3)}
    sel_buckets = {"E1": [], "E2": [], "E3": []}
    n_q = 2 if quick else 7
    for gname, (lo, hi) in groups.items():
        queries = make_join_queries(rng, n_q, k_filters=(lo, hi))
        for variant in ("QUEST", "Pushdown", "Optimal"):
            C = F = 0.0
            for qi, q in enumerate(queries):
                res = execute(q, variant, qi)
                _, _, f1 = prf(result_join_rows(res), join_truth(corpus, q))
                C += res.ledger.total_tokens
                F += f1
                if variant == "QUEST":
                    # realized IN selectivity bucket
                    surv = res.meta["survivors"]
                    tt = "teams" if "teams" in surv else list(surv)[0]
                    frac = surv.get(tt, 0) / max(len(corpus.truth_rows(tt)), 1)
                    bucket = "E1" if frac < 0.3 else ("E2" if frac < 0.6 else "E3")
                    sel_buckets[bucket].append((res.ledger.total_tokens, q, qi))
            rows.append({"bench": "two_table", "group": gname, "variant": variant,
                         "tokens_per_query": round(C / len(queries), 1),
                         "f1": round(F / len(queries), 3)})
            print(f"[join] {gname} {variant:9s} tok={rows[-1]['tokens_per_query']} "
                  f"f1={rows[-1]['f1']}", flush=True)

    # selectivity buckets: compare QUEST vs Pushdown on the same queries
    for bname, items in sel_buckets.items():
        if not items:
            continue
        Cq = sum(t for t, _, _ in items) / len(items)
        Cp = 0.0
        for _, q, qi in items:
            Cp += execute(q, "Pushdown", qi).ledger.total_tokens
        rows.append({"bench": "sel_bucket", "group": bname, "variant": "QUEST",
                     "tokens_per_query": round(Cq, 1), "f1": None})
        rows.append({"bench": "sel_bucket", "group": bname, "variant": "Pushdown",
                     "tokens_per_query": round(Cp / len(items), 1), "f1": None})

    # (b) multi-table joins (3 tables, 2 edges)
    n_multi = 2 if quick else 5
    multi_rows = []
    for qi in range(n_multi):
        f_p = _rand_filters(rng, "players", 1)
        f_t = _rand_filters(rng, "teams", 1)
        f_c = _rand_filters(rng, "cities", 1)
        q = Query(tables=["players", "teams", "cities"],
                  select=[("players", "age")],
                  where=conj(*(f_p + f_t + f_c)),
                  joins=[JOINS[("players", "teams")], JOINS[("teams", "cities")]])
        for variant in ("QUEST", "Random", "Pushdown", "Optimal"):
            if variant == "Random":
                retr = ctx.retriever("wiki", "quest").fork()
                eng = Engine(retr, OracleExtractor(corpus), seed=qi)
                # random edge order: shuffle by overriding the chooser
                eng._choose_first_edge = lambda query, ctxs: random.Random(qi).choice(list(query.joins))
                res = eng.execute(q)
            else:
                res = execute(q, variant, qi)
            _, _, f1 = prf(result_join_rows(res), join_truth(corpus, q))
            multi_rows.append({"bench": "multi_table", "query": qi,
                               "variant": variant,
                               "tokens": res.ledger.total_tokens,
                               "f1": round(f1, 3)})
    # aggregate
    for variant in ("QUEST", "Random", "Pushdown", "Optimal"):
        sel = [r for r in multi_rows if r["variant"] == variant]
        rows.append({"bench": "multi_table", "group": "all", "variant": variant,
                     "tokens_per_query": round(sum(r["tokens"] for r in sel) / len(sel), 1),
                     "f1": round(sum(r["f1"] for r in sel) / len(sel), 3)})
        print(f"[join-multi] {variant:9s} tok={rows[-1]['tokens_per_query']}",
              flush=True)

    with open(OUT / "fig7_join.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=["bench", "group", "variant",
                                          "tokens_per_query", "f1"])
        w.writeheader()
        w.writerows(rows)
    return rows
