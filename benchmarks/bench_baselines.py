"""Table 2 (accuracy) + Table 3 (token cost & latency): QUEST vs baselines
on the three corpora.
"""
from __future__ import annotations

import csv
from pathlib import Path

from .common import (METHODS, N_QUERIES, BenchContext, derived_latency_s,
                     generate_queries, prf, result_row_set, truth_row_set)

TABLES = {"wiki": "players", "swde": "universities", "legal": "cases"}
OUT = Path(__file__).parent / "out"


def run(ctx: BenchContext | None = None, quick: bool = False):
    ctx = ctx or BenchContext()
    OUT.mkdir(exist_ok=True)
    rows = []
    for corpus_name, table in TABLES.items():
        corpus = ctx.corpus(corpus_name)
        n_q = 3 if quick else N_QUERIES[corpus_name]
        queries = generate_queries(corpus, table, n_q, seed=11)
        n_docs = len(corpus.tables[table])
        for method in METHODS:
            P = R = F = C = W = 0.0
            for qi, q in enumerate(queries):
                res = ctx.run_query(corpus_name, method, q, seed=qi)
                p, r, f1 = prf(result_row_set(q, res), truth_row_set(corpus, q))
                P += p; R += r; F += f1
                C += res.ledger.total_tokens
                W += res.ledger.wall_time_s
            n = len(queries)
            rows.append({
                "dataset": corpus_name, "method": method.name,
                "precision": round(P / n, 3), "recall": round(R / n, 3),
                "f1": round(F / n, 3),
                "tokens_per_doc": round(C / n / n_docs, 1),
                "tokens_per_query": round(C / n, 1),
                "latency_s_derived": round(derived_latency_s(C / n), 2),
                "wall_s": round(W / n, 3),
            })
            print(f"[baselines] {corpus_name:6s} {method.name:9s} "
                  f"F1={rows[-1]['f1']:.3f} tok/doc={rows[-1]['tokens_per_doc']}",
                  flush=True)
    with open(OUT / "table2_table3_baselines.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows
