"""Roofline analysis (deliverable (g)): three terms per (arch x shape) on the
single-pod production mesh, derived from the dry-run artifacts.

Sources:
  - full cell records: compile status, per-device memory_analysis, raw
    (loop-hidden) HLO stats — the deployment artifact;
  - probe records (reduced depth, layer-scans unrolled, dense attention):
    per-device flops / bytes / collective bytes, extrapolated affinely in
    depth units to the full model (collectives inside lax.scan bodies appear
    once in HLO text, so the full artifact understates them; probes don't);
  - analytic corrections: Mamba1's time scan stays a while loop even in
    probes -> its interior FLOPs are added analytically (launch/flops.py).

Hardware constants (TPU v5e class, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI. cost_analysis numbers are per-device
(post-SPMD module), so terms divide by per-chip rates directly.
"""
from __future__ import annotations

import csv
import json
from pathlib import Path

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, SHAPE_ORDER
from repro.launch import flops as F
from repro.launch.dryrun import probe_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256

HERE = Path(__file__).parent
RESULTS = HERE / "dryrun_results.json"
OUT = HERE / "out"


def units(cfg, probe_n=None):
    """Depth units for affine extrapolation."""
    if probe_n is not None:
        return probe_n
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    if cfg.family == "moe" and cfg.first_dense_layers:
        return cfg.num_layers - cfg.first_dense_layers
    return cfg.num_layers


def _extrapolate(v2, v4, n2, n4, n_full):
    per = (v4 - v2) / max(n4 - n2, 1)
    fixed = v2 - n2 * per
    return max(fixed + n_full * per, 0.0)


def analyze(res: dict):
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in SHAPE_ORDER:
            key = f"{arch}|{shape_name}|single"
            rec = res.get(key)
            if rec is None:
                continue
            row = {"arch": arch, "shape": shape_name}
            if rec["status"] == "skipped":
                row.update(status="skipped", note=rec["reason"][:60])
                rows.append(row)
                continue
            if rec["status"] != "ok":
                row.update(status="error", note=rec.get("error", "")[:80])
                rows.append(row)
                continue
            p2 = res.get(key + "|probe2")
            p4 = res.get(key + "|probe4")
            shape = SHAPES[shape_name]
            n_full = units(cfg)
            if p2 and p4 and p2["status"] == "ok" and p4["status"] == "ok":
                n2, n4 = 2, 4
                flops_dev = _extrapolate(p2["cost"]["flops"], p4["cost"]["flops"],
                                         n2, n4, n_full)
                bytes_dev = _extrapolate(p2["cost"].get("bytes accessed", 0),
                                         p4["cost"].get("bytes accessed", 0),
                                         n2, n4, n_full)
                coll_dev = _extrapolate(p2["collectives"].get("_total", 0),
                                        p4["collectives"].get("_total", 0),
                                        n2, n4, n_full)
                src = "probe"
            else:
                flops_dev = rec["cost"].get("flops", 0)
                bytes_dev = rec["cost"].get("bytes accessed", 0)
                coll_dev = rec["collectives"].get("_total", 0)
                src = "raw(loop-hidden)"
            # analytic correction: mamba1 time-scan interior
            if cfg.mamba_version == 1 or (cfg.family == "hybrid" and cfg.mamba_version == 1):
                flops_dev += F.ssm_scan_flops(cfg, shape) / CHIPS

            compute_s = flops_dev / PEAK_FLOPS
            memory_s = bytes_dev / HBM_BW
            coll_s = coll_dev / LINK_BW
            terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
            dominant = max(terms, key=terms.get)
            model_fl = rec.get("model_flops", F.model_flops(cfg, shape))
            useful = model_fl / max(flops_dev * CHIPS, 1.0)
            bound_s = max(terms.values())
            # roofline fraction: useful model flops vs what the dominant
            # term allows at peak
            roofline_frac = (model_fl / CHIPS / PEAK_FLOPS) / max(bound_s, 1e-12)
            mem = rec.get("memory", {})
            row.update(
                status="ok", src=src,
                compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
                dominant=dominant,
                model_flops=model_fl,
                hlo_flops_global=flops_dev * CHIPS,
                useful_ratio=round(useful, 3),
                roofline_frac=round(roofline_frac, 4),
                temp_gib=round(mem.get("temp_size_in_bytes", 0) / 2**30, 2),
                arg_gib=round(mem.get("argument_size_in_bytes", 0) / 2**30, 2),
                analytic_mem_s=F.hbm_bytes(cfg, shape) / CHIPS / HBM_BW,
            )
            rows.append(row)
    return rows


def what_moves_it(row) -> str:
    d = row.get("dominant")
    if d == "compute":
        if row["useful_ratio"] < 0.5:
            return ("compute-bound with low useful ratio: cut redundant FLOPs "
                    "(causal block-skipping kernel, remat policy, dense-attn waste)")
        return "compute-bound near useful peak: only faster kernels help"
    if d == "memory":
        return ("memory-bound: raise arithmetic intensity (fuse attention "
                "tiles, bf16 gathers, larger per-chip batch)")
    return ("collective-bound: cut bytes (bf16/int8 gathers, 2D-sharding "
            "rebalance) or overlap (async collectives along scan)")


def run(quick: bool = False):
    OUT.mkdir(exist_ok=True)
    res = json.loads(RESULTS.read_text())
    rows = analyze(res)
    cols = ["arch", "shape", "status", "src", "compute_s", "memory_s",
            "collective_s", "dominant", "model_flops", "hlo_flops_global",
            "useful_ratio", "roofline_frac", "temp_gib", "arg_gib",
            "analytic_mem_s", "note"]
    with open(OUT / "roofline.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=cols)
        w.writeheader()
        for r in rows:
            w.writerow({c: r.get(c, "") for c in cols})
    # markdown for EXPERIMENTS.md
    lines = ["| arch | shape | compute s | memory s | collective s | dominant | useful | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']} | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |")
    (OUT / "roofline.md").write_text("\n".join(lines))
    for r in rows:
        if r["status"] == "ok":
            print(f"[roofline] {r['arch']:22s} {r['shape']:12s} dom={r['dominant']:10s} "
                  f"useful={r['useful_ratio']:.2f} frac={r['roofline_frac']:.3f}")
    return rows
