"""Figures 4/5: accuracy and cost grouped by filter count (C1: 1 filter,
C2: 2-3 filters, C3: 4+), per method.
"""
from __future__ import annotations

import csv
from pathlib import Path

from .common import (METHODS, BenchContext, generate_queries, prf,
                     result_row_set, truth_row_set)

OUT = Path(__file__).parent / "out"
GROUPS = {"C1": (1, 1), "C2": (2, 3), "C3": (4, 5)}


def run(ctx: BenchContext | None = None, quick: bool = False):
    ctx = ctx or BenchContext()
    OUT.mkdir(exist_ok=True)
    corpus_name, table = "wiki", "players"
    corpus = ctx.corpus(corpus_name)
    rows = []
    n_per_group = 3 if quick else 8
    for gname, (lo, hi) in GROUPS.items():
        queries = generate_queries(corpus, table, n_per_group, seed=23 + lo,
                                   min_filters=lo, max_filters=hi)
        for method in METHODS:
            F = C = 0.0
            for qi, q in enumerate(queries):
                res = ctx.run_query(corpus_name, method, q, seed=qi)
                _, _, f1 = prf(result_row_set(q, res), truth_row_set(corpus, q))
                F += f1
                C += res.ledger.total_tokens
            n = len(queries)
            rows.append({"group": gname, "method": method.name,
                         "f1": round(F / n, 3),
                         "tokens_per_query": round(C / n, 1)})
            print(f"[filter-groups] {gname} {method.name:9s} F1={rows[-1]['f1']:.3f} "
                  f"tok={rows[-1]['tokens_per_query']}", flush=True)
    with open(OUT / "fig4_fig5_filter_groups.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows
