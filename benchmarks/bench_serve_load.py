"""Sustained-QPS load test of the async serving tier (DESIGN.md §16).

Workload: three tenants (one with double fair-share weight) submit a
request stream on a fixed virtual-clock arrival schedule — `qps` requests
per pump tick — into a `ServingFrontend` over a paged-KV engine whose
page pool is deliberately small, so admission runs against real page
headroom and the backpressure path (defer, never an exception) engages.
Latencies are sampled in *pump ticks* (`clock="ticks"`), so every gated
number is deterministic: no wall-clock in the contract.

Phases:

  load   the sustained stream drains to completion. Checks: every ticket
         resolves DONE; per-request output tokens are byte-identical to
         a fresh serial engine running each request alone (scheduling
         policy must never change results); `PagePoolExhausted` never
         escapes (absorbed count is reported); p50/p99 submit→done and
         queue-wait tick latencies + pumps-to-drain are the gated
         latency/throughput counters.
  probe  the same stream re-submitted in one burst against a small
         `max_queue` bound. Checks: overflow sheds as *typed* tickets
         (SHED_QUEUE_FULL), nothing raises, and accepted requests still
         complete with correct outputs.

Emits `benchmarks/out/BENCH_serve_load.json` (+ per-tenant CSV), gated
by `compare.py --bench serve_load` against the committed smoke baseline.
"""
from __future__ import annotations

import argparse
import csv
import json
import time
from pathlib import Path

import jax

from repro.configs import get_smoke_config
from repro.data import lm_data
from repro.models import init_params
from repro.obs import Tracer
from repro.serving.costs import LatencySeries
from repro.serving.engine import Request, ServingEngine
from repro.serving.frontend import (DONE, SHED, SHED_QUEUE_FULL,
                                    ServingFrontend)

OUT = Path(__file__).parent / "out"

TENANTS = [("gold", 2.0), ("silver", 1.0), ("bronze", 1.0)]


def _workload(n_requests: int, max_new: int):
    """Deterministic request stream: round-robin tenants, shared task
    prefix (prefix-cache regime) + per-request payload, arrival tick per
    the schedule built in `run`."""
    prefix = "Task: summarize the record. Evidence: "
    reqs = []
    for i in range(n_requests):
        tenant = TENANTS[i % len(TENANTS)][0]
        payload = f"doc {i:03d} " + " ".join(
            f"field{j}={((i + 1) * (j + 3)) % 97}" for j in range(6))
        toks = lm_data.encode(prefix + payload + " Answer:")
        reqs.append((tenant, toks, len(lm_data.encode(prefix))))
    return reqs, max_new


def _engine(cfg, params, *, slots: int, num_pages: int, tracer=None):
    return ServingEngine(cfg, params, slots=slots, max_len=192,
                         kv_layout="paged", page_size=16,
                         num_pages=num_pages, prefix_cache=True,
                         tracer=tracer)


def _serial_outputs(cfg, params, workload, max_new, *, slots, num_pages):
    """Reference: each request alone on a fresh-state engine — the output
    any schedule must reproduce byte-for-byte."""
    eng = _engine(cfg, params, slots=slots, num_pages=num_pages)
    outs = {}
    for rid, (tenant, toks, shared) in enumerate(workload):
        req = Request(rid=rid, prompt=list(toks), max_new=max_new,
                      shared_len=shared)
        eng.submit(req)
        done = eng.run()
        outs[rid] = list(done[rid].out)
    return outs


def run(smoke: bool = False, quick: bool = False):
    OUT.mkdir(exist_ok=True)
    small = smoke or quick
    n_requests = 18 if small else 48
    qps = 2                      # arrivals per pump tick
    max_new = 10
    slots = 3
    num_pages = 20               # < slots * per-request page demand: the
    # page-headroom defer path and prefix-LRU eviction both run live
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    workload, max_new = _workload(n_requests, max_new)

    t0 = time.time()
    serial = _serial_outputs(cfg, params, workload, max_new,
                             slots=slots, num_pages=num_pages)
    wall_serial = time.time() - t0

    # ---------------------------------------------------------- load phase --
    # full-level tick tracer on the loaded run: the Chrome trace artifact
    # (TRACE_serve_load.json, uploaded by CI) shows admission/defer/engine
    # phases per pump; rows stay byte-identical (bench_obs_overhead gates)
    t0 = time.time()
    tracer = Tracer(clock="ticks", level=2)
    eng = _engine(cfg, params, slots=slots, num_pages=num_pages,
                  tracer=tracer)
    fe = ServingFrontend(eng, tenant_weights=dict(TENANTS),
                         max_prefill_chunks=2, clock="ticks", tracer=tracer)
    pool_baseline = eng.pool_free_pages()
    tickets, escaped = [], False
    pending = list(enumerate(workload))   # (rid, (tenant, toks, shared))
    try:
        while pending or fe.has_work():
            for rid, (tenant, toks, shared) in pending[:qps]:
                req = Request(rid=rid, prompt=list(toks), max_new=max_new,
                              shared_len=shared)
                tickets.append(fe.submit(req=req, tenant=tenant))
            pending = pending[qps:]
            fe.pump()
    except Exception:           # noqa: BLE001 — the invariant under test
        escaped = True
        raise
    finally:
        wall_load = time.time() - t0

    all_done = all(t.status == DONE for t in tickets)
    rows_identical = all(list(t.req.out) == serial[t.rid] for t in tickets)
    # pages still referenced by the prefix cache are *accounted* (clear()
    # releases them); anything short of baseline after that is a true leak
    eng.prefix_cache.clear()
    pool_restored = eng.pool_free_pages() == pool_baseline

    latency, qwait = LatencySeries(), LatencySeries()
    for t in tickets:
        latency.add(t.resolved_tick - t.submitted_tick)
        qwait.add(t.admitted_tick - t.submitted_tick)
    lat, qw = latency.snapshot(), qwait.snapshot()

    # --------------------------------------------------------- probe phase --
    eng_p = _engine(cfg, params, slots=slots, num_pages=num_pages)
    fe_p = ServingFrontend(eng_p, tenant_weights=dict(TENANTS),
                           max_queue=6, max_prefill_chunks=2)
    probe = [fe_p.submit(req=Request(rid=rid, prompt=list(toks),
                                     max_new=max_new, shared_len=shared),
                         tenant=tenant)
             for rid, (tenant, toks, shared) in enumerate(workload)]
    fe_p.pump_until_idle()
    shed = [t for t in probe if t.status == SHED]
    kept = [t for t in probe if t.status == DONE]
    sheds_typed = (len(shed) > 0 and
                   all(t.shed_reason == SHED_QUEUE_FULL for t in shed) and
                   len(shed) + len(kept) == len(probe))
    probe_rows_ok = all(list(t.req.out) == serial[t.rid] for t in kept)

    result = {
        "bench": "serve_load", "smoke": bool(small),
        "requests": n_requests, "qps_per_tick": qps,
        "tenants": len(TENANTS), "slots": slots, "num_pages": num_pages,
        # invariants
        "rows_identical_to_serial": bool(rows_identical),
        "all_requests_completed": bool(all_done),
        "pool_exhausted_never_escaped": not escaped,
        "pool_restored_after_drain": bool(pool_restored),
        "probe_sheds_typed": bool(sheds_typed),
        "probe_rows_identical": bool(probe_rows_ok),
        # gated latency/throughput counters (pump ticks — deterministic)
        "p50_latency_ticks": lat["p50"],
        "p99_latency_ticks": lat["p99"],
        "queue_wait_p50_ticks": qw["p50"],
        "queue_wait_p99_ticks": qw["p99"],
        "pumps_to_drain": fe.stats["pumps"],
        "decode_steps": eng.stats["decode_steps"],
        # reported context
        "queue_depth_peak": fe.stats["queue_depth_peak"],
        "deferred": fe.stats["deferred"],
        "admission_deferred": eng.stats["admission_deferred"],
        "pool_exhausted_absorbed": fe.stats["pool_exhausted_absorbed"],
        "shed_rate_probe": round(len(shed) / len(probe), 4),
        "trace_spans": len(tracer.spans),
        "wall_serial_s": round(wall_serial, 3),
        "wall_load_s": round(wall_load, 3),
    }
    tracer.write_chrome(OUT / "TRACE_serve_load.json")
    with open(OUT / "BENCH_serve_load.json", "w") as f:
        json.dump(result, f, indent=2)
    with open(OUT / "serve_load.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["tenant", "weight", "submitted", "completed",
                    "queue_wait_p50", "queue_wait_p99",
                    "latency_p50", "latency_p99"])
        for name, weight in TENANTS:
            s = fe.tenants[name].snapshot()
            w.writerow([name, weight, s["submitted"], s["completed"],
                        s["queue_wait"]["p50"], s["queue_wait"]["p99"],
                        s["latency"]["p50"], s["latency"]["p99"]])

    print(f"serve_load: {n_requests} reqs @ {qps}/tick over {len(TENANTS)} "
          f"tenants | p50/p99 latency {lat['p50']}/{lat['p99']} ticks | "
          f"queue wait p99 {qw['p99']} ticks | "
          f"deferred {result['deferred']}+{result['admission_deferred']} | "
          f"probe shed {len(shed)}/{len(probe)} typed={sheds_typed} | "
          f"rows identical: {rows_identical} | "
          f"wall {wall_serial:.1f}s serial -> {wall_load:.1f}s loaded")

    assert rows_identical, "load scheduling changed request outputs"
    assert all_done, "a request failed to complete under load"
    assert pool_restored, "paged-KV pages leaked across the load run"
    assert sheds_typed, "overload probe did not shed as typed tickets"
    assert probe_rows_ok, "a shed-phase survivor produced wrong output"
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized workload")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke, quick=args.quick)
