"""Benchmark harness entry point — one bench per paper table/figure.

`PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]`
Prints ``name,us_per_call,derived`` CSV lines per bench; detailed per-table
CSVs land in benchmarks/out/.
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    default=bool(os.environ.get("REPRO_BENCH_QUICK")))
    ap.add_argument("--only", default=None,
                    help="baselines|filter_groups|ordering|join|ablations|"
                         "kernels|roofline|batching|prefix_cache|multi_query|"
                         "paged_kv|spec_decode|sharded_serving|serve_load|"
                         "live_corpus|cascade|obs_overhead")
    args = ap.parse_args()

    from . import (bench_ablations, bench_baselines, bench_batching,
                   bench_cascade, bench_filter_groups, bench_join,
                   bench_kernels, bench_live_corpus, bench_multi_query,
                   bench_obs_overhead, bench_ordering, bench_paged_kv,
                   bench_prefix_cache, bench_roofline, bench_serve_load,
                   bench_sharded_serving, bench_spec_decode)
    from .common import BenchContext

    ctx = BenchContext()
    benches = {
        "kernels": lambda: bench_kernels.run(quick=args.quick),
        "batching": lambda: bench_batching.run(quick=args.quick),
        "prefix_cache": lambda: bench_prefix_cache.run(quick=args.quick),
        "multi_query": lambda: bench_multi_query.run(quick=args.quick),
        "paged_kv": lambda: bench_paged_kv.run(quick=args.quick),
        "spec_decode": lambda: bench_spec_decode.run(quick=args.quick),
        "sharded_serving": lambda: bench_sharded_serving.run(quick=args.quick),
        "serve_load": lambda: bench_serve_load.run(quick=args.quick),
        "live_corpus": lambda: bench_live_corpus.run(quick=args.quick),
        "cascade": lambda: bench_cascade.run(quick=args.quick),
        "obs_overhead": lambda: bench_obs_overhead.run(quick=args.quick),
        "ordering": lambda: bench_ordering.run(ctx, quick=args.quick),
        "join": lambda: bench_join.run(ctx, quick=args.quick),
        "filter_groups": lambda: bench_filter_groups.run(ctx, quick=args.quick),
        "ablations": lambda: bench_ablations.run(ctx, quick=args.quick),
        "baselines": lambda: bench_baselines.run(ctx, quick=args.quick),
        "roofline": lambda: bench_roofline.run(quick=args.quick),
    }
    if args.only:
        if args.only not in benches:
            ap.error(f"unknown bench {args.only!r} (choose from "
                     f"{', '.join(sorted(benches))})")
        benches = {args.only: benches[args.only]}

    print("name,us_per_call,derived")
    for name, fn in benches.items():
        t0 = time.time()
        try:
            fn()
            status = "ok"
        except FileNotFoundError as e:
            status = f"needs-dryrun({e})"
        dt = time.time() - t0
        print(f"bench_{name},{dt*1e6:.0f},{status}")


if __name__ == "__main__":
    main()
