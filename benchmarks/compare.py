"""Benchmark-regression gate: diff fresh BENCH_*.json against committed
baselines and fail CI on a >10% regression.

Baselines live in `benchmarks/baselines/BENCH_<name>.json` (committed smoke
runs); fresh results in `benchmarks/out/` (written by the bench scripts).
Three kinds of checks per bench:

  invariants — booleans that must simply hold in the fresh run
              (rows_identical, ledger columns untouched, ...);
  metrics    — deterministic counters (prefill tokens/invocations, hit
              counts, byte ratios): regression if the fresh value is >10%
              worse than baseline in the metric's direction;
  wall       — wall-clock, compared in *within-run ratio* form
              (e.g. wall_on/wall_off) so the gate transfers across machine
              speeds; >10% worse than the baseline ratio fails (tunable
              via --wall-tol for noisy runners).

Exit code 0 = green, 1 = regression (or missing/mismatched files).

    python benchmarks/compare.py --bench paged_kv
    python benchmarks/compare.py            # all benches with a baseline
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

HERE = Path(__file__).parent
BASELINES = HERE / "baselines"
FRESH = HERE / "out"

# direction: "lower" = lower is better, "higher" = higher is better
SPECS = {
    "prefix_cache": {
        "invariants": ["rows_identical", "ledger_token_columns_identical"],
        "metrics": [("prefill_tokens_on", "lower"),
                    ("prefill_saved_fraction", "higher"),
                    ("prefix_hits", "higher")],
        "wall": [("wall_on_s", "wall_off_s")],
    },
    "multi_query": {
        "invariants": ["rows_identical_to_serial_session"],
        "metrics": [("prefill_tokens_shared", "lower"),
                    ("engine_runs_shared", "lower"),
                    ("q2_sampling_tokens_shared", "lower"),
                    ("total_tokens_shared", "lower")],
        "wall": [("wall_shared_s", "wall_serial_s")],
    },
    "paged_kv": {
        "invariants": ["rows_identical", "ledger_token_columns_identical"],
        "metrics": [("prefill_tokens_paged", "lower"),
                    ("prefill_invocations_paged", "lower"),
                    ("prefill_ctx_ratio", "lower"),
                    ("kv_bytes_ratio", "lower")],
        "wall": [("wall_paged_s", "wall_slab_s")],
    },
    "spec_decode": {
        "invariants": ["rows_identical", "ledger_token_columns_identical"],
        "metrics": [("decode_steps_pl", "lower"),
                    ("decode_steps_draft", "lower"),
                    ("step_reduction_draft", "higher"),
                    ("acceptance_rate_pl", "higher"),
                    ("decode_steps_saved_pl", "higher")],
        # walls are reported but not gated: the smoke workload's tiny
        # models make its wall ratios compile/dispatch-noise-dominated
        # (±20% run to run), and the draft path self-drafts (draft ==
        # target) so its >1 ratio is expected. The speedup contract here
        # is the deterministic invocation counters above.
        "wall": [],
    },
    "serve_load": {
        "invariants": ["rows_identical_to_serial", "all_requests_completed",
                       "pool_exhausted_never_escaped",
                       "pool_restored_after_drain",
                       "probe_sheds_typed", "probe_rows_identical"],
        "metrics": [("p50_latency_ticks", "lower"),
                    ("p99_latency_ticks", "lower"),
                    ("queue_wait_p99_ticks", "lower"),
                    ("pumps_to_drain", "lower"),
                    ("decode_steps", "lower")],
        # latencies are gated in deterministic pump ticks, not seconds —
        # wall-clock on the tiny smoke model is dispatch-noise-dominated,
        # so walls are reported but not gated (spec_decode precedent)
        "wall": [],
    },
    "sharded_serving": {
        "invariants": ["dp2_rows_identical", "mesh_rows_identical",
                       "ledger_token_columns_identical",
                       "mesh_stats_identical"],
        "metrics": [("dp2_speedup", "higher"),
                    ("dp2_balance", "higher"),
                    ("rounds_dp2_max", "lower"),
                    ("tokens_per_round_dp2", "higher"),
                    ("decode_steps_mesh", "lower")],
        # in-process replicas interleave on one host thread and the CPU
        # mesh adds collective overhead to a tiny model: wall-clock cannot
        # show the win here. The DP contract is counter-gated (rounds =
        # target-model invocations, the deployment clock unit).
        "wall": [],
    },
}


def _load(path: Path):
    if not path.exists():
        return None
    with open(path) as f:
        return json.load(f)


def _check_metric(name, fresh_v, base_v, direction, tol):
    """Returns (ok, detail). Worse-than-baseline beyond tol fails; better
    never fails (improvements shift the baseline only when re-committed).
    A counter present in the fresh run but absent from the committed
    baseline is a *warning*, not a failure — new stats columns must not
    break the gate before their baseline is re-committed. A counter the
    fresh run stopped reporting, however, fails: that is a regression of
    the bench itself."""
    if fresh_v is None:
        return False, (f"{name}: missing from the fresh run "
                       f"(baseline {base_v!r}) — did the bench stop "
                       f"reporting it?")
    if base_v is None:
        return True, (f"{name}: WARN new counter (fresh {fresh_v}), absent "
                      f"from the committed baseline — skipped; re-commit "
                      f"the baseline to start gating it")
    if base_v == 0:
        return True, f"{name}: baseline {base_v!r}, skipped"
    if direction == "lower":
        worse = (fresh_v - base_v) / abs(base_v)
    else:
        worse = (base_v - fresh_v) / abs(base_v)
    ok = worse <= tol
    arrow = {"lower": "<=", "higher": ">="}[direction]
    return ok, (f"{name}: fresh {fresh_v} vs baseline {base_v} "
                f"(want {arrow} within {tol:.0%}; "
                f"{'regressed' if not ok else 'ok'} {worse:+.1%})")


def compare_bench(bench: str, tol: float, wall_tol: float) -> bool:
    spec = SPECS[bench]
    base = _load(BASELINES / f"BENCH_{bench}.json")
    fresh = _load(FRESH / f"BENCH_{bench}.json")
    if base is None:
        print(f"[{bench}] FAIL: no committed baseline "
              f"({BASELINES / f'BENCH_{bench}.json'})")
        return False
    if fresh is None:
        print(f"[{bench}] FAIL: no fresh result "
              f"({FRESH / f'BENCH_{bench}.json'}) — did the bench run?")
        return False
    if bool(base.get("smoke")) != bool(fresh.get("smoke")):
        print(f"[{bench}] FAIL: smoke/full mismatch "
              f"(baseline smoke={base.get('smoke')}, fresh={fresh.get('smoke')})")
        return False

    ok = True
    for key in spec["invariants"]:
        if not fresh.get(key):
            print(f"[{bench}] FAIL invariant {key} = {fresh.get(key)!r}")
            ok = False
    for key, direction in spec["metrics"]:
        good, detail = _check_metric(key, fresh.get(key), base.get(key),
                                     direction, tol)
        print(f"[{bench}] {'ok  ' if good else 'FAIL'} {detail}")
        ok = ok and good
    for num, den in spec["wall"]:
        # same missing-counter rules as metrics: absent from the baseline
        # warns, absent from the fresh run fails (a 0-coerced numerator
        # would otherwise read as a large improvement and mask a broken bench)
        if fresh.get(num) is None or fresh.get(den) is None:
            print(f"[{bench}] FAIL wall {num}/{den}: missing from the fresh "
                  f"run — did the bench stop reporting it?")
            ok = False
            continue
        if base.get(num) is None or base.get(den) is None:
            print(f"[{bench}] ok   wall {num}/{den}: WARN absent from the "
                  f"committed baseline — skipped")
            continue
        fb, bb = fresh.get(den) or 0, base.get(den) or 0
        if not fb or not bb:
            print(f"[{bench}] ok   wall {num}/{den}: zero denominator, skipped")
            continue
        fresh_ratio = round((fresh.get(num) or 0) / fb, 4)
        base_ratio = round((base.get(num) or 0) / bb, 4)
        good, detail = _check_metric(f"wall {num}/{den}", fresh_ratio,
                                     base_ratio, "lower", wall_tol)
        print(f"[{bench}] {'ok  ' if good else 'FAIL'} {detail}")
        ok = ok and good
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", choices=sorted(SPECS),
                    help="single bench to compare (default: all with baselines)")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed relative regression on counter metrics")
    ap.add_argument("--wall-tol", type=float, default=0.10,
                    help="allowed relative regression on wall-clock ratios")
    args = ap.parse_args(argv)

    benches = [args.bench] if args.bench else sorted(SPECS)
    results = {b: compare_bench(b, args.tol, args.wall_tol) for b in benches}
    bad = [b for b, good in results.items() if not good]
    if bad:
        print(f"\nREGRESSION: {', '.join(bad)}")
        return 1
    print(f"\nall green: {', '.join(benches)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
