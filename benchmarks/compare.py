"""Benchmark-regression gate: diff fresh BENCH_*.json against committed
baselines and fail CI on a >10% regression.

Baselines live in `benchmarks/baselines/BENCH_<name>.json` (committed smoke
runs); fresh results in `benchmarks/out/` (written by the bench scripts).
Three kinds of checks per bench:

  invariants — booleans that must simply hold in the fresh run
              (rows_identical, ledger columns untouched, ...);
  metrics    — deterministic counters (prefill tokens/invocations, hit
              counts, byte ratios): regression if the fresh value is >10%
              worse than baseline in the metric's direction;
  wall       — wall-clock, compared in *within-run ratio* form
              (e.g. wall_on/wall_off) so the gate transfers across machine
              speeds; >10% worse than the baseline ratio fails (tunable
              via --wall-tol for noisy runners).

Exit code 0 = green, 1 = regression (or missing/mismatched files).

    python benchmarks/compare.py --bench paged_kv
    python benchmarks/compare.py            # all benches with a baseline
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

try:                                    # PYTHONPATH=src (how CI invokes us)
    from repro.obs.metrics import schema_stem
except ImportError:                     # standalone diffing still works
    schema_stem = None

HERE = Path(__file__).parent
BASELINES = HERE / "baselines"
FRESH = HERE / "out"

def spec(*, invariants=(), lower=(), higher=(), wall=()):
    """One bench's gate, declaratively: `invariants` are must-hold
    booleans, `lower`/`higher` are counter metrics gated in that
    direction (lower/higher is better), `wall` is a list of
    (numerator, denominator) wall-clock ratio pairs. Normalizes to the
    dict shape `compare_bench` consumes."""
    return {
        "invariants": list(invariants),
        "metrics": ([(k, "lower") for k in lower]
                    + [(k, "higher") for k in higher]),
        "wall": [tuple(pair) for pair in wall],
    }


SPECS = {
    "prefix_cache": spec(
        invariants=["rows_identical", "ledger_token_columns_identical"],
        lower=["prefill_tokens_on"],
        higher=["prefill_saved_fraction", "prefix_hits"],
        wall=[("wall_on_s", "wall_off_s")],
    ),
    "multi_query": spec(
        invariants=["rows_identical_to_serial_session"],
        lower=["prefill_tokens_shared", "engine_runs_shared",
               "q2_sampling_tokens_shared", "total_tokens_shared"],
        wall=[("wall_shared_s", "wall_serial_s")],
    ),
    "paged_kv": spec(
        invariants=["rows_identical", "ledger_token_columns_identical"],
        lower=["prefill_tokens_paged", "prefill_invocations_paged",
               "prefill_ctx_ratio", "kv_bytes_ratio"],
        wall=[("wall_paged_s", "wall_slab_s")],
    ),
    # walls are reported but not gated: the smoke workload's tiny models
    # make its wall ratios compile/dispatch-noise-dominated (±20% run to
    # run), and the draft path self-drafts (draft == target) so its >1
    # ratio is expected. The speedup contract here is the deterministic
    # invocation counters.
    "spec_decode": spec(
        invariants=["rows_identical", "ledger_token_columns_identical"],
        lower=["decode_steps_pl", "decode_steps_draft"],
        higher=["step_reduction_draft", "acceptance_rate_pl",
                "decode_steps_saved_pl"],
    ),
    # latencies are gated in deterministic pump ticks, not seconds —
    # wall-clock on the tiny smoke model is dispatch-noise-dominated, so
    # walls are reported but not gated (spec_decode precedent)
    "serve_load": spec(
        invariants=["rows_identical_to_serial", "all_requests_completed",
                    "pool_exhausted_never_escaped",
                    "pool_restored_after_drain",
                    "probe_sheds_typed", "probe_rows_identical"],
        lower=["p50_latency_ticks", "p99_latency_ticks",
               "queue_wait_p99_ticks", "pumps_to_drain", "decode_steps"],
    ),
    # in-process replicas interleave on one host thread and the CPU mesh
    # adds collective overhead to a tiny model: wall-clock cannot show the
    # win here. The DP contract is counter-gated (rounds = target-model
    # invocations, the deployment clock unit).
    "sharded_serving": spec(
        invariants=["dp2_rows_identical", "mesh_rows_identical",
                    "ledger_token_columns_identical",
                    "mesh_stats_identical"],
        lower=["rounds_dp2_max", "decode_steps_mesh"],
        higher=["dp2_speedup", "dp2_balance", "tokens_per_round_dp2"],
    ),
    # the mutation-stream contract is counter-gated: re-embedded bytes per
    # localized edit (the §17 acceptance metric) and the incremental-vs-
    # rebuild embedding fraction. The live/rebuild wall ratio is reported
    # but not gated — the incremental leg is sub-second on the smoke
    # workload, so its jitter swamps a ratio whose baseline is ~0.05
    # (spec_decode precedent).
    "live_corpus": spec(
        invariants=["rows_match_oracle", "served_rows_match_oracle",
                    "replay_digest_identical", "no_dead_ids_in_results",
                    "pool_restored_after_delete"],
        lower=["reembedded_bytes_per_edit", "reembed_vs_rebuild_fraction",
               "reclustered_lists", "prefix_entries_invalidated"],
        higher=["cache_entries_retained_fraction", "reused_bytes_per_edit"],
    ),
    # the cascade contract is the paired gate from DESIGN.md §18: the
    # quality floor (F1 within a point of target-only) and the cost win
    # (target-model decode tokens down >= 25%) must hold together, plus
    # the three parity invariants (verify_all/off rows byte-identical,
    # ledger token columns cascade-invariant). Walls are reported but not
    # gated — tiny smoke models, spec_decode precedent.
    "cascade": spec(
        invariants=["f1_within_floor", "tokens_saved_floor_met",
                    "degenerate_rows_identical", "cascade_off_rows_identical",
                    "cascade_rows_identical",
                    "ledger_token_columns_identical"],
        lower=["target_decode_tokens_cascade", "escalations"],
        higher=["target_decode_token_reduction", "routed_small_fraction",
                "f1_cascade", "ledger_target_tokens_saved"],
    ),
    # observability must observe, never perturb (DESIGN.md §19): rows,
    # ledger token columns and counter snapshots byte-identical tracing
    # on vs. off; tick-clock traces byte-identical across runs; median
    # traced wall within the bench's 5% budget. Wall fractions are
    # reported, not ratio-gated (they sit in run-to-run noise); span
    # coverage is gated so the trace cannot silently shrink.
    "obs_overhead": spec(
        invariants=["rows_identical", "ledger_token_columns_identical",
                    "counters_identical", "trace_deterministic",
                    "overhead_within_budget"],
        higher=["spans_emitted"],
    ),
}


def _load(path: Path):
    if not path.exists():
        return None
    with open(path) as f:
        return json.load(f)


def _check_metric(name, fresh_v, base_v, direction, tol):
    """Returns (ok, detail). Worse-than-baseline beyond tol fails; better
    never fails (improvements shift the baseline only when re-committed).
    A counter present in the fresh run but absent from the committed
    baseline is a *warning*, not a failure — new stats columns must not
    break the gate before their baseline is re-committed. A counter the
    fresh run stopped reporting, however, fails: that is a regression of
    the bench itself."""
    if fresh_v is None:
        return False, (f"{name}: missing from the fresh run "
                       f"(baseline {base_v!r}) — did the bench stop "
                       f"reporting it?")
    if base_v is None:
        return True, (f"{name}: WARN new counter (fresh {fresh_v}), absent "
                      f"from the committed baseline — skipped; re-commit "
                      f"the baseline to start gating it")
    if base_v == 0:
        return True, f"{name}: baseline {base_v!r}, skipped"
    if direction == "lower":
        worse = (fresh_v - base_v) / abs(base_v)
    else:
        worse = (base_v - fresh_v) / abs(base_v)
    ok = worse <= tol
    arrow = {"lower": "<=", "higher": ">="}[direction]
    return ok, (f"{name}: fresh {fresh_v} vs baseline {base_v} "
                f"(want {arrow} within {tol:.0%}; "
                f"{'regressed' if not ok else 'ok'} {worse:+.1%})")


_META_KEYS = frozenset({"bench", "smoke"})


def _drift_warnings(bench: str, fresh: dict, base: dict) -> None:
    """Schema-driven counter-drift report (DESIGN.md §19): a numeric key
    the fresh run reports but the committed baseline lacks is ungated
    until the baseline is re-committed. If the spelling also derives from
    no metric in the obs registry schema (`schema_stem`), flag it harder —
    it is likely a typo or an undeclared counter, the exact drift the
    typed registry exists to prevent."""
    if schema_stem is None:
        return
    for key in sorted(fresh):
        if key in base or key in _META_KEYS:
            continue
        if not isinstance(fresh[key], (int, float)) or \
                isinstance(fresh[key], bool):
            continue
        stem = schema_stem(key)
        if stem is not None:
            print(f"[{bench}] ok   {key}: WARN ungated new counter "
                  f"(schema stem {stem!r}) — re-commit the baseline to "
                  f"start gating it")
        else:
            print(f"[{bench}] ok   {key}: WARN new counter matches NO "
                  f"metric in the obs schema — declare it in "
                  f"repro.obs.metrics.SCHEMA or fix the spelling")


def compare_bench(bench: str, tol: float, wall_tol: float) -> bool:
    spec = SPECS[bench]
    base = _load(BASELINES / f"BENCH_{bench}.json")
    fresh = _load(FRESH / f"BENCH_{bench}.json")
    if base is None:
        print(f"[{bench}] FAIL: no committed baseline "
              f"({BASELINES / f'BENCH_{bench}.json'})")
        return False
    if fresh is None:
        print(f"[{bench}] FAIL: no fresh result "
              f"({FRESH / f'BENCH_{bench}.json'}) — did the bench run?")
        return False
    if bool(base.get("smoke")) != bool(fresh.get("smoke")):
        print(f"[{bench}] FAIL: smoke/full mismatch "
              f"(baseline smoke={base.get('smoke')}, fresh={fresh.get('smoke')})")
        return False

    ok = True
    for key in spec["invariants"]:
        if not fresh.get(key):
            print(f"[{bench}] FAIL invariant {key} = {fresh.get(key)!r}")
            ok = False
    for key, direction in spec["metrics"]:
        good, detail = _check_metric(key, fresh.get(key), base.get(key),
                                     direction, tol)
        print(f"[{bench}] {'ok  ' if good else 'FAIL'} {detail}")
        ok = ok and good
    _drift_warnings(bench, fresh, base)
    for num, den in spec["wall"]:
        # same missing-counter rules as metrics: absent from the baseline
        # warns, absent from the fresh run fails (a 0-coerced numerator
        # would otherwise read as a large improvement and mask a broken bench)
        if fresh.get(num) is None or fresh.get(den) is None:
            print(f"[{bench}] FAIL wall {num}/{den}: missing from the fresh "
                  f"run — did the bench stop reporting it?")
            ok = False
            continue
        if base.get(num) is None or base.get(den) is None:
            print(f"[{bench}] ok   wall {num}/{den}: WARN absent from the "
                  f"committed baseline — skipped")
            continue
        fb, bb = fresh.get(den) or 0, base.get(den) or 0
        if not fb or not bb:
            print(f"[{bench}] ok   wall {num}/{den}: zero denominator, skipped")
            continue
        fresh_ratio = round((fresh.get(num) or 0) / fb, 4)
        base_ratio = round((base.get(num) or 0) / bb, 4)
        good, detail = _check_metric(f"wall {num}/{den}", fresh_ratio,
                                     base_ratio, "lower", wall_tol)
        print(f"[{bench}] {'ok  ' if good else 'FAIL'} {detail}")
        ok = ok and good
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", choices=sorted(SPECS),
                    help="single bench to compare (default: all with baselines)")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed relative regression on counter metrics")
    ap.add_argument("--wall-tol", type=float, default=0.10,
                    help="allowed relative regression on wall-clock ratios")
    args = ap.parse_args(argv)

    benches = [args.bench] if args.bench else sorted(SPECS)
    results = {b: compare_bench(b, args.tol, args.wall_tol) for b in benches}
    bad = [b for b, good in results.items() if not good]
    if bad:
        print(f"\nREGRESSION: {', '.join(bad)}")
        return 1
    print(f"\nall green: {', '.join(benches)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
