"""Figure 8 ablations: (a) two-level index, (b) evidence source,
(c) document threshold tau, (d) sample rate, (e) evidence cluster K.
"""
from __future__ import annotations

import csv
from pathlib import Path

from repro.core import Engine
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever

from .common import (BenchContext, Method, generate_queries, prf,
                     result_row_set, truth_row_set)

OUT = Path(__file__).parent / "out"


def _score(ctx, corpus, queries, retriever, **engine_kw):
    F = P = C = 0.0
    for qi, q in enumerate(queries):
        retr = retriever.fork() if hasattr(retriever, "fork") else retriever
        eng = Engine(retr, OracleExtractor(corpus), seed=qi, **engine_kw)
        res = eng.execute(q)
        p, r, f1 = prf(result_row_set(q, res), truth_row_set(corpus, q))
        F += f1; P += p; C += res.ledger.total_tokens
    n = len(queries)
    return round(F / n, 3), round(P / n, 3), round(C / n, 1)


def run(ctx: BenchContext | None = None, quick: bool = False):
    ctx = ctx or BenchContext()
    OUT.mkdir(exist_ok=True)
    corpus = ctx.corpus("wiki")
    n_q = 3 if quick else 10
    queries = generate_queries(corpus, "players", n_q, seed=93,
                               min_filters=2, max_filters=4)
    rows = []

    # (a) two-level vs segment-only
    for mode, label in [("quest", "two_level"), ("segment_only", "segment_only")]:
        f1, p, c = _score(ctx, corpus, queries, ctx.retriever("wiki", mode))
        rows.append({"ablation": "index", "variant": label, "f1": f1,
                     "precision": p, "tokens": c})
        print(f"[ablation-index] {label}: f1={f1} tok={c}", flush=True)

    # (b) evidence source
    for mode, label in [("quest", "doc_evidence"), ("no_evidence", "no_evidence"),
                        ("llm_evidence", "llm_evidence")]:
        f1, p, c = _score(ctx, corpus, queries, ctx.retriever("wiki", mode))
        rows.append({"ablation": "evidence", "variant": label, "f1": f1,
                     "precision": p, "tokens": c})
        print(f"[ablation-evidence] {label}: f1={f1} tok={c}", flush=True)

    # (c) tau sweep: fix tau manually around the adaptive value
    adaptive = TwoLevelRetriever(corpus)
    # run one query to let thresholds settle, then read adaptive tau
    Engine(adaptive, OracleExtractor(corpus)).execute(queries[0])
    tau0 = adaptive._tau.get("players", 1.2)
    for delta in (-0.4, -0.2, 0.0, 0.2, 0.4):
        class FixedTau(TwoLevelRetriever):
            def finalize_thresholds(self, table, attrs, stats, _d=delta, _t=tau0):
                super().finalize_thresholds(table, attrs, stats)
                self._tau[table] = _t + _d
        retr = FixedTau(corpus)
        f1, p, c = _score(ctx, corpus, queries[: max(3, n_q // 2)], retr)
        rows.append({"ablation": "tau", "variant": f"{tau0 + delta:.2f}",
                     "f1": f1, "precision": p, "tokens": c})
        print(f"[ablation-tau] tau={tau0+delta:.2f}: f1={f1} tok={c}", flush=True)

    # (d) sample rate
    for rate in (0.02, 0.05, 0.1, 0.2):
        retr = TwoLevelRetriever(corpus)
        f1, p, c = _score(ctx, corpus, queries[: max(3, n_q // 2)], retr,
                          sample_rate=rate)
        rows.append({"ablation": "sample_rate", "variant": str(rate),
                     "f1": f1, "precision": p, "tokens": c})
        print(f"[ablation-sample] rate={rate}: f1={f1} tok={c}", flush=True)

    # (e) evidence cluster K
    for k in (1, 2, 3, 5, 8):
        retr = TwoLevelRetriever(corpus, evidence_k=k)
        f1, p, c = _score(ctx, corpus, queries[: max(3, n_q // 2)], retr)
        rows.append({"ablation": "cluster_k", "variant": str(k),
                     "f1": f1, "precision": p, "tokens": c})
        print(f"[ablation-k] k={k}: f1={f1} tok={c}", flush=True)

    with open(OUT / "fig8_ablations.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows
