"""Difficulty-aware model cascade vs. target-only extraction
(DESIGN.md §18).

Workload: one analytics query over the synthetic SWDE university corpus,
executed through full served Sessions (sampling sweep + quest-ordered
query phase) four ways:

  target      — plain ServedExtractor on the target engine (baseline);
  cascade     — CascadeExtractor: a small zoo model serves the easy
                per-(doc, attr) extractions (difficulty = sampling
                agreement + retrieval margins + context length), the
                verifier escalates structurally invalid parses;
  verify_all  — degenerate-routing parity check: everything routes to the
                small tier and the verifier escalates *everything*, so
                rows must be byte-identical to target-only while the
                small tier's spend is pure waste;
  off         — cascade disabled: must be byte-identical to target-only
                (the small engine is never touched).

Paired gated counters (the §18 contract):
  quality — F1 vs. exact ground truth must be within 1 point of
            target-only (in this container both parse through the §8.1
            oracle fallback, so they are equal by construction — the gate
            guards the plumbing);
  cost    — target-model decode tokens must drop >= 25% vs. target-only
            at that F1; `target_tokens_saved` (ledger) reports the
            prompt+decode tokens that never reached the target model.

Ledger token columns stay cascade-invariant (routing changes which model
produced a value, never which value) — asserted like every other serving
optimization's bench. Walls are reported but not gated (tiny smoke
models; spec_decode precedent).

Emits `benchmarks/out/BENCH_cascade.json` (compared against the committed
baseline by `benchmarks/compare.py` in CI) plus a per-path CSV.
`--smoke` runs the reduced CI-sized workload.
"""
from __future__ import annotations

import argparse
import csv
import json
import time
from pathlib import Path

import jax

from repro.configs import get_smoke_config
from repro.core import DifficultyEstimator, Filter, Query, Session, conj
from repro.data import lm_data
from repro.data.corpus import Corpus, make_swde_corpus
from repro.extract import CascadeExtractor, ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_params
from repro.serving.engine import ServingEngine

try:
    from .common import prf, result_row_set, truth_row_set
except ImportError:  # run as a script (the CI smoke leg)
    from common import prf, result_row_set, truth_row_set

OUT = Path(__file__).parent / "out"
MAX_NEW = 6


def _corpus(small: bool) -> Corpus:
    full = make_swde_corpus()
    n_uni, n_lap = (40, 10) if small else (120, 30)
    uni = [d for d in sorted(full.docs) if "universities" in d][:n_uni]
    lap = [d for d in sorted(full.docs) if "laptops" in d][:n_lap]
    return full.subset(uni + lap)


def _query() -> Query:
    return Query(tables=["universities"],
                 select=[("universities", "university_name")],
                 where=conj(Filter("tuition", "<", 42000,
                                   table="universities"),
                            Filter("enrollment", ">", 15000,
                                   table="universities")))


def _small_cfg(cfg):
    """The cheap tier: a genuinely smaller zoo config (same family, ~1/20
    the parameters of the target smoke config)."""
    return cfg.replace(num_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                       head_dim=16, d_ff=48)


def _run_path(corpus, query, *, mode: str, batch: int, cfg, params,
              small_cfg, small_params):
    engine = ServingEngine(cfg, params, slots=batch, max_len=1024,
                           prefix_cache=True)
    retriever = TwoLevelRetriever(corpus)
    if mode == "target":
        extractor = ServedExtractor(corpus, engine, max_new=MAX_NEW)
    else:
        small = ServingEngine(small_cfg, small_params, slots=batch,
                              max_len=1024, prefix_cache=True)
        extractor = CascadeExtractor(
            corpus, engine, small, cascade=mode,
            difficulty=DifficultyEstimator(retriever), max_new=MAX_NEW)
    session = Session(retriever, extractor, batch_size=batch)
    t0 = time.time()
    result = session.execute(query)
    wall = time.time() - t0
    s = extractor.stats
    return {
        "rows": sorted(tuple(sorted(r["_docs"].items()))
                       for r in result.rows),
        "result": result,
        "wall_s": wall,
        "target_decode_tokens": s.generated_tokens,
        "target_prompt_tokens": s.prompt_tokens,
        "small_decode_tokens": getattr(s, "small_generated_tokens", 0),
        "small_prompt_tokens": getattr(s, "small_prompt_tokens", 0),
        "routed_small": getattr(s, "routed_small", 0),
        "routed_target": getattr(s, "routed_target", 0),
        "escalations": getattr(s, "escalations", 0),
        "accepted_small": getattr(s, "accepted_small", 0),
        "engine_decode_steps": engine.stats["decode_steps"],
        "ledger": session.ledger.snapshot(),
    }


def run(quick: bool = False, smoke: bool = False):
    OUT.mkdir(exist_ok=True)
    small = quick or smoke
    corpus = _corpus(small)
    query = _query()
    batch = 4 if small else 8

    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    scfg = _small_cfg(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    sparams = init_params(scfg, jax.random.PRNGKey(1))
    kw = dict(batch=batch, cfg=cfg, params=params,
              small_cfg=scfg, small_params=sparams)

    tgt = _run_path(corpus, query, mode="target", **kw)
    casc = _run_path(corpus, query, mode="on", **kw)
    dgen = _run_path(corpus, query, mode="verify_all", **kw)
    off = _run_path(corpus, query, mode="off", **kw)

    truth = truth_row_set(corpus, query)
    f1_tgt = prf(result_row_set(query, tgt["result"]), truth)[2]
    f1_casc = prf(result_row_set(query, casc["result"]), truth)[2]

    reduction = 1 - casc["target_decode_tokens"] / \
        max(tgt["target_decode_tokens"], 1)
    routed = casc["routed_small"] + casc["routed_target"]
    routed_small_frac = casc["routed_small"] / max(routed, 1)
    escalation_rate = casc["escalations"] / max(casc["routed_small"], 1)
    ledger_identical = all(
        p["ledger"][c] == tgt["ledger"][c]
        for p in (casc, dgen, off)
        for c in ("input_tokens", "output_tokens", "total_tokens",
                  "per_phase"))

    result = {
        "bench": "cascade",
        "smoke": bool(small),
        "docs": len(corpus.docs),
        "batch": batch,
        "max_new": MAX_NEW,
        # paired gated counters: quality floor + cost win
        "f1_target_only": round(f1_tgt, 4),
        "f1_cascade": round(f1_casc, 4),
        "f1_within_floor": f1_casc >= f1_tgt - 0.01,
        "target_decode_tokens_target_only": tgt["target_decode_tokens"],
        "target_decode_tokens_cascade": casc["target_decode_tokens"],
        "target_decode_token_reduction": round(reduction, 4),
        "tokens_saved_floor_met": reduction >= 0.25,
        # parity invariants
        "degenerate_rows_identical": dgen["rows"] == tgt["rows"],
        "cascade_off_rows_identical": off["rows"] == tgt["rows"],
        "cascade_rows_identical": casc["rows"] == tgt["rows"],
        "ledger_token_columns_identical": ledger_identical,
        # cascade economy
        "routed_small": casc["routed_small"],
        "routed_target": casc["routed_target"],
        "routed_small_fraction": round(routed_small_frac, 4),
        "escalations": casc["escalations"],
        "escalation_rate": round(escalation_rate, 4),
        "small_decode_tokens": casc["small_decode_tokens"],
        "small_prompt_tokens": casc["small_prompt_tokens"],
        "ledger_cascade_small": casc["ledger"]["cascade_small"],
        "ledger_target_tokens_saved": casc["ledger"]["target_tokens_saved"],
        "wall_target_s": round(tgt["wall_s"], 3),
        "wall_cascade_s": round(casc["wall_s"], 3),
        "wall_verify_all_s": round(dgen["wall_s"], 3),
    }
    with open(OUT / "BENCH_cascade.json", "w") as f:
        json.dump(result, f, indent=2)
    with open(OUT / "cascade.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["path", "target_decode_tokens", "small_decode_tokens",
                    "routed_small", "escalations", "f1", "wall_s"])
        for name, r, f1 in (("target", tgt, f1_tgt), ("cascade", casc, f1_casc),
                            ("verify_all", dgen, ""), ("off", off, "")):
            w.writerow([name, r["target_decode_tokens"],
                        r["small_decode_tokens"], r["routed_small"],
                        r["escalations"], f1, f"{r['wall_s']:.3f}"])

    print(f"cascade: {len(corpus.docs)} docs @ batch {batch} | "
          f"F1 target-only {f1_tgt:.3f} vs cascade {f1_casc:.3f} | "
          f"target decode tokens {tgt['target_decode_tokens']} -> "
          f"{casc['target_decode_tokens']} ({reduction:.1%} saved) | "
          f"routing small {casc['routed_small']}/{routed} "
          f"(escalated {casc['escalations']}) | wall "
          f"{tgt['wall_s']:.2f}s / {casc['wall_s']:.2f}s")

    assert result["degenerate_rows_identical"], \
        "verify_all (escalate-everything) rows diverged from target-only"
    assert result["cascade_off_rows_identical"], \
        "cascade=off must be byte-identical to a plain ServedExtractor"
    assert ledger_identical, "cascade leaked into ledger token columns"
    assert result["f1_within_floor"], (
        f"cascade F1 {f1_casc:.4f} fell more than 1 point below "
        f"target-only {f1_tgt:.4f}")
    assert reduction >= 0.25, (
        f"target decode-token reduction {reduction:.1%} below the 25% bar")
    assert casc["ledger"]["target_tokens_saved"] > 0, \
        "cascade accepted nothing — ledger shows no target tokens saved"
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized workload")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
