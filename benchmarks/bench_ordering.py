"""Figure 6: filter-ordering strategies (Random / Selectivity / Average-cost
/ Exhaust / QUEST): token cost per group + planner runtime scaling with the
number of filters (QUEST n log n vs Exhaust n!).
"""
from __future__ import annotations

import csv
import random
import time
from pathlib import Path

from repro.core.expr import And, Filter
from repro.core.ordering import exhaustive_plan, plan_expression

from .common import (BenchContext, generate_queries, prf, result_row_set,
                     truth_row_set, Method)

OUT = Path(__file__).parent / "out"
STRATEGIES = ["random", "selectivity", "avg_cost", "exhaust", "quest"]
GROUPS = {"C1": (1, 1), "C2": (2, 3), "C3": (4, 5)}


def run(ctx: BenchContext | None = None, quick: bool = False):
    ctx = ctx or BenchContext()
    OUT.mkdir(exist_ok=True)
    corpus_name, table = "wiki", "players"
    corpus = ctx.corpus(corpus_name)
    rows = []
    n_per_group = 3 if quick else 8
    for gname, (lo, hi) in GROUPS.items():
        queries = generate_queries(corpus, table, n_per_group, seed=37 + lo,
                                   min_filters=lo, max_filters=hi)
        for strat in STRATEGIES:
            method = Method(strat, "quest", strat)
            C = F = 0.0
            for qi, q in enumerate(queries):
                res = ctx.run_query(corpus_name, method, q, seed=qi)
                _, _, f1 = prf(result_row_set(q, res), truth_row_set(corpus, q))
                C += res.ledger.total_tokens
                F += f1
            n = len(queries)
            rows.append({"group": gname, "strategy": strat,
                         "tokens_per_query": round(C / n, 1),
                         "f1": round(F / n, 3)})
            print(f"[ordering] {gname} {strat:11s} tok={rows[-1]['tokens_per_query']}",
                  flush=True)
    with open(OUT / "fig6_ordering_cost.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)

    # planner runtime scaling (pure planning, no extraction)
    scale_rows = []
    rng = random.Random(5)
    for n_f in ([2, 4, 6, 8] if quick else [2, 4, 6, 8, 9, 10]):
        filters = tuple(Filter(f"a{i}", ">", 0) for i in range(n_f))
        expr = And(filters)
        costs = {f"a{i}": rng.uniform(10, 500) for i in range(n_f)}
        sels = {f"a{i}": rng.uniform(0.05, 0.95) for i in range(n_f)}
        cost_fn = lambda f: costs[f.attr]
        sel_fn = lambda f: sels[f.attr]
        t0 = time.time()
        for _ in range(20):
            plan_expression(expr, cost_fn, sel_fn)
        t_quest = (time.time() - t0) / 20
        t_ex = float("nan")
        if n_f <= 9:
            t0 = time.time()
            exhaustive_plan(expr, cost_fn, sel_fn)
            t_ex = time.time() - t0
        scale_rows.append({"n_filters": n_f,
                           "quest_ms": round(t_quest * 1e3, 4),
                           "exhaust_ms": round(t_ex * 1e3, 4)})
        print(f"[ordering-scale] n={n_f} quest={t_quest*1e3:.3f}ms "
              f"exhaust={t_ex*1e3:.1f}ms", flush=True)
    with open(OUT / "fig6_ordering_scaling.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=scale_rows[0].keys())
        w.writeheader()
        w.writerows(scale_rows)
    return rows, scale_rows
