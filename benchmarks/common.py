"""Shared benchmark machinery: query generation (paper §5.1), method
registry (QUEST + re-implemented baselines), and P/R/F1 evaluation.
"""
from __future__ import annotations

import random
import re
import time
from dataclasses import dataclass, field

from repro.core import Engine, Filter, JoinEdge, Query, conj, disj
from repro.core.expr import And, Or, evaluate_expr, iter_filters
from repro.data.corpus import (CORPORA, make_legal_corpus, make_swde_corpus,
                               make_wiki_corpus)
from repro.data.tokens import count_tokens
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever

# paper Table 1 scale: #queries per dataset
N_QUERIES = {"wiki": 25, "swde": 15, "legal": 10}


# -------------------------------------------------------- query generation --


def _numeric_filter(rng, table, attr, values):
    vals = sorted(values)
    q = vals[max(0, min(len(vals) - 1, int(rng.uniform(0.15, 0.85) * len(vals))))]
    op = rng.choice([">", ">=", "<", "<=", "="])
    if op == "=" and len(set(vals)) > 20:      # equality on near-unique ints
        op = ">="
    return Filter(attr, op, q, table=table)


def _categorical_filter(rng, table, attr, values):
    return Filter(attr, "=", rng.choice(sorted(set(values))), table=table)


def generate_queries(corpus, table: str, n: int, *, seed: int = 0,
                     min_filters=1, max_filters=5) -> list[Query]:
    """Random single-table queries: conjunctions, disjunctions and mixed
    trees in roughly equal shares (paper §5.1)."""
    rng = random.Random(seed)
    truth = corpus.truth_rows(table)
    specs = corpus.attr_specs[table]
    attrs = sorted(specs)
    out = []
    guard = 0
    while len(out) < n and guard < n * 30:
        guard += 1
        k = rng.randint(min_filters, max_filters)
        chosen = rng.sample(attrs, min(k, len(attrs)))
        filters = []
        for a in chosen:
            vals = [t[a] for t in truth.values()]
            if specs[a].kind in ("int", "float"):
                filters.append(_numeric_filter(rng, table, a, vals))
            else:
                filters.append(_categorical_filter(rng, table, a, vals))
        mode = rng.choice(["and", "or", "mix"])
        if len(filters) == 1 or mode == "and":
            expr = conj(*filters)
        elif mode == "or":
            expr = disj(*filters)
        else:
            split = rng.randint(1, len(filters) - 1)
            left = conj(*filters[:split]) if split > 1 else filters[0]
            right = disj(*filters[split:]) if len(filters) - split > 1 else filters[split]
            expr = And((left, right)) if rng.random() < 0.5 else Or((left, right))
        sel_attr = rng.choice([a for a in attrs if specs[a].kind == "str"] or attrs)
        q = Query(tables=[table], select=[(table, sel_attr)], where=expr)
        n_true = sum(1 for t in truth.values() if evaluate_expr(expr, t))
        if 0 < n_true < len(truth):            # validated, non-degenerate
            out.append(q)
    return out


def truth_row_set(corpus, query: Query):
    """Ground-truth result rows as tuples of select-attr values + doc ids."""
    table = query.tables[0]
    rows = set()
    for doc_id, t in corpus.truth_rows(table).items():
        if query.where is None or evaluate_expr(query.where, t):
            rows.add(tuple(t.get(a) for _, a in query.select) + (doc_id,))
    return rows


def result_row_set(query: Query, result):
    rows = set()
    for r in result.rows:
        key = tuple(r[f"{t}.{a}"] for t, a in query.select)
        rows.add(key + (r["_docs"][query.tables[0]],))
    return rows


def prf(pred: set, true: set):
    tp = len(pred & true)
    p = tp / max(len(pred), 1)
    r = tp / max(len(true), 1)
    return p, r, 2 * p * r / max(p + r, 1e-9)


# --------------------------------------------------------------- methods ---


class EvaExtractor(OracleExtractor):
    """Evaporate-like: LLM synthesizes extraction *code* from sampled docs;
    the code = the single most-frequent template pattern per attribute, so
    any other phrasing is missed (paper: rule rigidity costs accuracy).
    Query-time LLM cost ~ 0 (code generation charged at sampling)."""

    def extract(self, doc_id, attr, segments):
        text = " ".join(segments)
        doc = self.corpus.docs[doc_id]
        spec = self.corpus.spec(doc.domain, attr) or self._spec_for(attr)
        if spec is None or not text:
            return None, 0
        # "synthesized code" knows only the first template's leading phrase
        t0 = spec.templates[0]
        probe = re.escape(t0.split("{}")[0].strip()[:24])
        if probe and not re.search(probe, text):
            return None, 0
        return spec.parse(text), 0


class ClosedIEExtractor(OracleExtractor):
    """Fine-tuned-small-model stand-in: no LLM cost, weak cross-domain
    generalization (fixed high miss/hallucination rates)."""

    MISS = 0.45
    HALL = 0.08

    def extract(self, doc_id, attr, segments):
        import hashlib
        text = " ".join(segments)
        doc = self.corpus.docs[doc_id]
        spec = self.corpus.spec(doc.domain, attr) or self._spec_for(attr)
        v = spec.parse(text) if (spec and text) else None
        h = int.from_bytes(hashlib.blake2b(f"{doc_id}|{attr}|cie".encode(),
                                           digest_size=4).digest(), "little")
        r = (h % 10_000) / 10_000
        if v is not None and r < self.MISS:
            v = None
        elif v is None and r < self.HALL:
            v = 42
        return v, 0


@dataclass
class Method:
    name: str
    retriever_mode: str
    ordering: str
    extractor_cls: type = OracleExtractor
    join_strategy: str = "transform"


METHODS = [
    Method("QUEST", "quest", "quest"),
    Method("Lotus", "fulldoc", "random"),
    Method("RAG", "rag_topk", "random"),
    Method("PZ", "rag_topk", "selectivity"),
    Method("ZenDB", "segment_only", "selectivity", join_strategy="pushdown"),
    Method("Eva", "fulldoc", "random", extractor_cls=EvaExtractor),
    Method("ClosedIE", "fulldoc", "random", extractor_cls=ClosedIEExtractor),
]


class BenchContext:
    """Caches corpora and per-mode retrievers (index builds are expensive)."""

    def __init__(self):
        self._corpora = {}
        self._retrievers = {}

    def corpus(self, name: str):
        if name not in self._corpora:
            self._corpora[name] = CORPORA[name]()
        return self._corpora[name]

    def retriever(self, corpus_name: str, mode: str):
        key = (corpus_name, mode)
        if key not in self._retrievers:
            self._retrievers[key] = TwoLevelRetriever(self.corpus(corpus_name),
                                                      mode=mode)
        return self._retrievers[key]

    def run_query(self, corpus_name: str, method: Method, query: Query,
                  seed: int = 0, **engine_kw):
        corpus = self.corpus(corpus_name)
        retr = self.retriever(corpus_name, method.retriever_mode).fork()
        extractor = method.extractor_cls(corpus)
        eng = Engine(retr, extractor, ordering=method.ordering,
                     join_strategy=method.join_strategy, seed=seed, **engine_kw)
        t0 = time.time()
        res = eng.execute(query)
        res.ledger.wall_time_s = time.time() - t0
        return res


# serving-derived latency: tokens -> seconds at a nominal extraction-fleet
# throughput (tokens/s/replica); see benchmarks/bench_roofline.py for the
# roofline-backed value.
NOMINAL_TOKENS_PER_S = 20_000.0


def derived_latency_s(tokens: int) -> float:
    return tokens / NOMINAL_TOKENS_PER_S
