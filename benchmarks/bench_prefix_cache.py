"""Prefix KV cache on/off over a multi-document QUEST extraction sweep
(DESIGN.md §10).

Workload: the scheduler-shaped batch of (doc, attr) extraction needs a
QUEST plan emits over the synthetic SWDE corpus, run through the real
serving engine twice — once with the shared-prefix KV cache off (the
per-request full prefill of §7) and once with it on. Both paths must
return byte-identical result rows and ledger token columns; the cache
shows up only in engine prefill work and in the separately-reported
savings columns.

Acceptance target: >= 30% fewer prefill tokens with the cache on.
Emits `benchmarks/out/BENCH_prefix_cache.json` (uploaded as a CI artifact
per run, so the perf trajectory accumulates) plus a CSV of the sweep.

`--smoke` runs the reduced CI-sized workload.
"""
from __future__ import annotations

import argparse
import csv
import json
import time
from pathlib import Path

import jax

from repro.configs import get_smoke_config
from repro.core.ledger import CostLedger
from repro.core.scheduler import BatchScheduler
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.extract.served import ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_params
from repro.serving.engine import ServingEngine

OUT = Path(__file__).parent / "out"
ATTRS = ["tuition", "enrollment", "university_name"]


def _items(corpus, n_docs: int):
    docs = sorted(corpus.tables["universities"])[:n_docs]
    return [(d, a, "universities") for d in docs for a in ATTRS]


def _run_path(corpus, items, *, prefix_cache: bool, batch: int):
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, slots=batch, max_len=1024,
                           prefix_cache=prefix_cache)
    extractor = ServedExtractor(corpus, engine, max_new=8)
    ledger = CostLedger()
    retriever = TwoLevelRetriever(corpus, mode="rag_topk")
    sched = BatchScheduler(retriever, extractor, ledger, {}, batch_size=batch)
    t0 = time.time()
    rows = sched.extract_many(items)
    wall = time.time() - t0
    return {
        "rows": rows,
        "wall_s": wall,
        "prefill_tokens": engine.stats["prefill_tokens"],
        "decode_steps": engine.stats["decode_steps"],
        "prefix_hits": engine.stats["prefix_hits"],
        "prefix_saved_tokens": engine.stats["prefix_saved_tokens"],
        "prefix_inserts": engine.stats["prefix_inserts"],
        "ledger": ledger.snapshot(),
    }


def run(quick: bool = False, smoke: bool = False):
    OUT.mkdir(exist_ok=True)
    small = quick or smoke
    corpus = make_swde_corpus()
    items = _items(corpus, 6 if small else 16)
    batch = 4 if small else 8

    off = _run_path(corpus, items, prefix_cache=False, batch=batch)
    on = _run_path(corpus, items, prefix_cache=True, batch=batch)

    rows_identical = on["rows"] == off["rows"]
    led_on, led_off = on["ledger"], off["ledger"]
    token_cols = ("input_tokens", "output_tokens", "total_tokens", "per_phase")
    ledger_identical = all(led_on[c] == led_off[c] for c in token_cols)
    saved_frac = 1.0 - on["prefill_tokens"] / max(off["prefill_tokens"], 1)

    result = {
        "bench": "prefix_cache",
        "smoke": bool(small),
        "items": len(items),
        "batch": batch,
        "prefill_tokens_off": off["prefill_tokens"],
        "prefill_tokens_on": on["prefill_tokens"],
        "prefill_saved_fraction": round(saved_frac, 4),
        "prefix_hits": on["prefix_hits"],
        "prefix_saved_tokens": on["prefix_saved_tokens"],
        "prefix_inserts": on["prefix_inserts"],
        "rows_identical": rows_identical,
        "ledger_token_columns_identical": ledger_identical,
        "wall_off_s": round(off["wall_s"], 3),
        "wall_on_s": round(on["wall_s"], 3),
    }
    with open(OUT / "BENCH_prefix_cache.json", "w") as f:
        json.dump(result, f, indent=2)
    with open(OUT / "prefix_cache.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["path", "prefill_tokens", "decode_steps", "prefix_hits",
                    "saved_tokens", "wall_s"])
        w.writerow(["off", off["prefill_tokens"], off["decode_steps"], 0, 0,
                    f"{off['wall_s']:.3f}"])
        w.writerow(["on", on["prefill_tokens"], on["decode_steps"],
                    on["prefix_hits"], on["prefix_saved_tokens"],
                    f"{on['wall_s']:.3f}"])

    print(f"prefix_cache: {len(items)} extractions | prefill tokens "
          f"{off['prefill_tokens']} -> {on['prefill_tokens']} "
          f"({saved_frac:.1%} saved, {on['prefix_hits']} hits) | "
          f"rows identical: {rows_identical} | "
          f"ledger token columns identical: {ledger_identical}")

    assert rows_identical, "prefix cache changed result rows"
    assert ledger_identical, "prefix cache leaked into ledger token columns"
    assert saved_frac >= 0.30, (
        f"prefill saving {saved_frac:.1%} below the 30% acceptance bar")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized workload")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
