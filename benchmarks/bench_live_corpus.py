"""Live corpus subsystem: mutation-stream parity and incremental-index
economics (DESIGN.md §17).

Four legs, all seeded and deterministic:

  oracle stream — ingest/update/delete interleaved with queries on a wiki
      subset through `LiveSession`; rows must byte-match a corpus + index
      rebuilt from scratch at *every* mutation point (`rows_match_oracle`),
      the mutation log must replay to the same manifest digest, and the
      exact invalidation cascade's cache retention is reported
      (`cache_entries_retained_fraction`: everything not derived from the
      mutated doc survives). The same loop yields the gated wall ratio:
      incremental maintenance (`wall_live_s`) vs rebuild-per-mutation
      (`wall_rebuild_s`) — both embedding-bound legs of one run, so the
      ratio transfers across hosts.
  re-embed — localized edits on long legal documents through the
      content-hash memo: `reembedded_bytes_per_edit` is the §17 acceptance
      metric (bounded, far below the document), with the full-rebuild
      embedding cost as contrast (`reembed_vs_rebuild_fraction`).
  IVF churn — synthetic add/remove stream on an IVFIndex: bounded
      per-list re-clustering (`reclustered_lists`) and searches that never
      surface a tombstoned id (`no_dead_ids_in_results`).
  served — the same mutation semantics on the real engine: one update
      between queries still byte-matches a fresh-engine oracle
      (`served_rows_match_oracle`), doc-tagged prefix entries drop on
      delete (`prefix_entries_invalidated`), and their pages return to the
      allocator (`pool_restored_after_delete`).

Emits `benchmarks/out/BENCH_live_corpus.json` (compared against the
committed baseline by `benchmarks/compare.py` in CI) plus a per-mutation
CSV. `--smoke` runs the reduced CI-sized workload.
"""
from __future__ import annotations

import argparse
import csv
import json
import time
from pathlib import Path

import numpy as np

from repro.core import Filter, Query, Session, conj
from repro.data.corpus import (Document, make_legal_corpus, make_swde_corpus,
                               make_wiki_corpus)
from repro.extract import OracleExtractor
from repro.index.vector_index import IVFIndex
from repro.live import LiveCorpus, LiveRetriever, LiveSession, render_edit

OUT = Path(__file__).parent / "out"


def _copy_subset(full, ids):
    """Corpus.subset shares Document objects; live mutations land in
    place, so copy the docs to keep the generator corpus pristine."""
    sub = full.subset(ids)
    sub.docs = {d: Document(doc.doc_id, doc.domain, doc.text, dict(doc.truth),
                            dict(doc.spans), doc.tokens, version=doc.version,
                            sha=doc.sha)
                for d, doc in sub.docs.items()}
    return sub


def _rows_key(rows):
    return sorted(rows, key=repr)


# ------------------------------------------------------- oracle stream leg --


def _wiki_query():
    return Query(tables=["players"], select=[("players", "player_name")],
                 where=conj(Filter("age", ">", 30, table="players"),
                            Filter("all_stars", ">=", 3, table="players")))


def _oracle_leg(n_players: int, n_teams: int):
    full = make_wiki_corpus(seed=0)
    players = [d for d in full.docs if full.docs[d].domain == "players"]
    teams = [d for d in full.docs if full.docs[d].domain == "teams"]
    ids = players[:n_players] + teams[:n_teams]
    live = LiveCorpus(_copy_subset(full, ids))
    retr = LiveRetriever(live)
    sess = LiveSession(live, retr, OracleExtractor(live), batch_size=8)
    q = _wiki_query()

    donors = iter(d for d in players if d not in live.docs)
    mutations = [
        ("update", lambda: sess.update(
            players[0], render_edit(live, players[0], "age", 99))),
        ("delete", lambda: sess.delete(players[1])),
        ("ingest", lambda: sess.ingest(
            "players/new0", full.docs[next(donors)].text, "players")),
        ("update", lambda: sess.update(
            players[2], render_edit(live, players[2], "all_stars", 9))),
    ]

    def oracle_rows():
        snap = live.snapshot()
        osess = Session(retr.rebuild_reference(snap), OracleExtractor(snap),
                        batch_size=8)
        return _rows_key(osess.execute(q).rows)

    per_step = []
    wall_live = wall_rebuild = 0.0
    rows_match = True

    t0 = time.time()
    live_rows = _rows_key(sess.execute(q).rows)
    wall_live += time.time() - t0
    t0 = time.time()
    ref_rows = oracle_rows()
    wall_rebuild += time.time() - t0
    rows_match &= live_rows == ref_rows
    cache_before = 0
    retained_fraction = 1.0
    for i, (kind, apply) in enumerate(mutations):
        if i == 0:
            cache_before = len(sess.cache)
        t0 = time.time()
        apply()
        live_rows = _rows_key(sess.execute(q).rows)
        wall_live += time.time() - t0
        if i == 0 and cache_before:
            retained_fraction = ((cache_before
                                  - sess.cascade.stats.cache_entries_dropped)
                                 / cache_before)
        t0 = time.time()
        ref_rows = oracle_rows()
        wall_rebuild += time.time() - t0
        ok = live_rows == ref_rows
        rows_match &= ok
        per_step.append((kind, len(live_rows), ok))

    fresh = LiveCorpus(_copy_subset(full, ids))
    live.log.replay(fresh)
    replay_ok = fresh.log.manifest_digest() == live.log.manifest_digest()
    emb = retr.embedder
    return {
        "rows_match_oracle": rows_match,
        "replay_digest_identical": replay_ok,
        "cache_entries_retained_fraction": round(retained_fraction, 4),
        "samples_dropped": sess.cascade.stats.samples_dropped,
        "stream_reembedded_bytes": emb.reembedded_bytes,
        "stream_reused_bytes": emb.reused_bytes,
        "wall_live_s": round(wall_live, 3),
        "wall_rebuild_s": round(wall_rebuild, 3),
        "per_step": per_step,
    }


# ------------------------------------------------------------ re-embed leg --


def _reembed_leg(n_docs: int, n_edits: int):
    full = make_legal_corpus(seed=1)
    ids = sorted(full.docs)[:n_docs]
    live = LiveCorpus(_copy_subset(full, ids))
    retr = LiveRetriever(live)
    emb = retr.embedder
    build_bytes = emb.reembedded_bytes       # cost of the from-scratch build
    emb.reset_counters()
    edits = 0
    for i in range(n_edits):
        doc_id = ids[i % len(ids)]
        doc = live.docs[doc_id]
        int_attrs = [a for a, v in doc.truth.items()
                     if isinstance(v, int) and a in doc.spans]
        if not int_attrs:
            continue
        attr = int_attrs[i % len(int_attrs)]
        live.update(doc_id, render_edit(live, doc_id, attr, 424200 + i))
        edits += 1
    edits = max(edits, 1)
    return {
        "edited_bytes": live.stats.edited_bytes,
        "reembedded_bytes_per_edit": emb.reembedded_bytes // edits,
        "reused_bytes_per_edit": emb.reused_bytes // edits,
        # incremental cost of the whole edit stream vs paying a full
        # rebuild's embedding bill at every edit (the static path)
        "reembed_vs_rebuild_fraction": round(
            emb.reembedded_bytes / max(build_bytes * edits, 1), 4),
        "build_bytes": build_bytes,
        "n_edits": edits,
    }


# ----------------------------------------------------------- IVF churn leg --


def _ivf_leg(n0: int, n_ops: int):
    rng = np.random.default_rng(7)

    def rows(n):
        e = rng.normal(size=(n, 32)).astype(np.float32)
        return e / np.linalg.norm(e, axis=-1, keepdims=True)

    idx = IVFIndex(rows(n0), list(range(n0)), n_lists=8, nprobe=4, seed=0)
    alive = set(range(n0))
    nxt = n0
    clean = True
    for i in range(n_ops):
        if i % 3 == 2 or len(alive) <= 4:
            idx.add(rows(1), [nxt])
            alive.add(nxt)
            nxt += 1
        else:
            victim = sorted(alive)[int(rng.integers(len(alive)))]
            idx.remove([victim])
            alive.discard(victim)
        (ids, _d), = idx.search(rows(1)[0], k=8)
        clean &= all(g in alive for g in ids)
        clean &= len(idx) == len(alive)
    return {
        "no_dead_ids_in_results": clean,
        "reclustered_lists": idx.maint_stats["reclustered_lists"],
        "migrated_rows": idx.maint_stats["migrated_rows"],
        "compactions": idx.maint_stats["compactions"],
    }


# -------------------------------------------------------------- served leg --


def _served_leg(n_docs: int):
    import jax

    from repro.configs import get_smoke_config
    from repro.data import lm_data
    from repro.extract.served import ServedExtractor
    from repro.models import init_params
    from repro.serving.engine import ServingEngine

    full = make_swde_corpus()
    ids = [d for d in sorted(full.docs) if "universities" in d][:n_docs]
    live = LiveCorpus(_copy_subset(full, ids))
    retr = LiveRetriever(live)
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    kw = dict(slots=2, max_len=1024, prefix_cache=True,
              kv_layout="paged", page_size=16)
    eng = ServingEngine(cfg, params, **kw)
    ext = ServedExtractor(live, eng, max_new=4, doc_prefix_escalation=True)
    sess = LiveSession(live, retr, ext, batch_size=2)
    q = Query(tables=["universities"],
              select=[("universities", "university_name")],
              where=Filter("tuition", "<", 40000, table="universities"))

    def oracle_rows():
        snap = live.snapshot()
        oext = ServedExtractor(snap, ServingEngine(cfg, params, **kw),
                               max_new=4, doc_prefix_escalation=True)
        osess = Session(retr.rebuild_reference(snap), oext, batch_size=2)
        return _rows_key(osess.execute(q).rows)

    match = _rows_key(sess.execute(q).rows) == oracle_rows()
    sess.update(ids[0], render_edit(live, ids[0], "tuition", 12000))
    match &= _rows_key(sess.execute(q).rows) == oracle_rows()

    # doc-first escalation pins a doc-tagged prefix entry in the paged
    # pool; delete must drop the entry and return every page
    free0 = eng.pool_free_pages()
    victim = ids[1]
    text = live.docs[victim].text[:200]
    ext.escalate_batch([(victim, "tuition", [text]),
                        (victim, "enrollment", [text])])
    held = free0 - eng.pool_free_pages()
    sess.delete(victim)
    restored = eng.pool_free_pages() == free0
    return {
        "served_rows_match_oracle": match,
        "prefix_entries_invalidated":
            eng.prefix_cache.stats.invalidated_entries,
        "prefix_pages_held": held,
        "pool_restored_after_delete": restored,
    }


# -------------------------------------------------------------------- main --


def run(quick: bool = False, smoke: bool = False):
    OUT.mkdir(exist_ok=True)
    small = quick or smoke

    oracle = _oracle_leg(n_players=12 if small else 25,
                         n_teams=4 if small else 10)
    reembed = _reembed_leg(n_docs=4 if small else 8,
                           n_edits=4 if small else 12)
    ivf = _ivf_leg(n0=48 if small else 160, n_ops=24 if small else 80)
    served = _served_leg(n_docs=4 if small else 8)

    per_step = oracle.pop("per_step")
    result = {"bench": "live_corpus", "smoke": bool(small)}
    result.update(oracle)
    result.update(reembed)
    result.update(ivf)
    result.update(served)
    with open(OUT / "BENCH_live_corpus.json", "w") as f:
        json.dump(result, f, indent=2)
    with open(OUT / "live_corpus.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["mutation", "rows", "rows_match_oracle"])
        for kind, n_rows, ok in per_step:
            w.writerow([kind, n_rows, ok])

    print(f"live_corpus: oracle stream rows match at every mutation point: "
          f"{result['rows_match_oracle']} | replay digest: "
          f"{result['replay_digest_identical']} | cache retained after "
          f"update: {result['cache_entries_retained_fraction']:.0%} | "
          f"re-embed {result['reembedded_bytes_per_edit']}B/edit "
          f"(vs rebuild {result['reembed_vs_rebuild_fraction']:.2%}) | "
          f"IVF reclustered {result['reclustered_lists']} lists, clean "
          f"results: {result['no_dead_ids_in_results']} | served parity: "
          f"{result['served_rows_match_oracle']}, pool restored: "
          f"{result['pool_restored_after_delete']} | wall live "
          f"{result['wall_live_s']:.2f}s vs rebuild "
          f"{result['wall_rebuild_s']:.2f}s")

    assert result["rows_match_oracle"], \
        "live rows diverged from the rebuilt-from-scratch oracle"
    assert result["served_rows_match_oracle"], \
        "served live rows diverged from the fresh-engine oracle"
    assert result["replay_digest_identical"], "mutation log failed to replay"
    assert result["no_dead_ids_in_results"], "IVF surfaced a tombstoned id"
    assert result["pool_restored_after_delete"], \
        "prefix pages leaked across delete"
    assert result["reembed_vs_rebuild_fraction"] < 0.2, (
        "localized edits re-embedded "
        f"{result['reembed_vs_rebuild_fraction']:.0%} of the rebuild cost — "
        "the content-hash memo is not bounding re-embedding")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized workload")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
