"""Kernel microbenchmarks (CPU XLA-path wall time + derived bandwidth).

TPU performance is covered by the roofline analysis; this harness times the
jnp reference paths that the dry-run lowers (and validates the Pallas
wrappers once in interpret mode for plumbing).
"""
from __future__ import annotations

import csv
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.kernels import ref

OUT = Path(__file__).parent / "out"


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        leaf = out[0] if isinstance(out, tuple) else out
        leaf.block_until_ready()
    return (time.time() - t0) / iters * 1e6


def run(quick: bool = False):
    OUT.mkdir(exist_ok=True)
    key = jax.random.PRNGKey(0)
    rows = []

    B, S, H, Hkv, D = 1, 512, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(key, (B, S, Hkv, D))
    v = jax.random.normal(key, (B, S, Hkv, D))
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    us = _time(fa, q, k, v)
    fl = 4 * B * S * S * H * D
    rows.append(("flash_attention_ref_512", us, f"{fl/us*1e-3:.1f}MFLOP/s/core"))

    qd = jax.random.normal(key, (4, H, D))
    kc = jax.random.normal(key, (4, 4096, Hkv, D))
    vc = jax.random.normal(key, (4, 4096, Hkv, D))
    da = jax.jit(lambda q, k, v: ref.decode_attention_ref(q, k, v, 4096))
    us = _time(da, qd, kc, vc)
    by = 2 * kc.size * 4
    rows.append(("decode_attention_ref_4k", us, f"{by/us*1e-3:.1f}MB/s/core"))

    db = jax.random.normal(key, (8192, 256))
    qq = jax.random.normal(key, (16, 256))
    tk = jax.jit(lambda d, q: ref.topk_l2_ref(d, q, 10))
    us = _time(tk, db, qq)
    rows.append(("topk_l2_ref_8k", us, f"{db.size*4/us*1e-3:.1f}MB/s/core"))

    from repro.models.ssm import mamba2_ssd_ref
    x = jax.random.normal(key, (1, 512, 16, 64))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 512, 16)))
    A = -jnp.ones((16,))
    Bm = jax.random.normal(key, (1, 512, 64))
    Cm = jax.random.normal(key, (1, 512, 64))
    ssd = jax.jit(lambda x, dt, Bm, Cm: mamba2_ssd_ref(x, dt, A, Bm, Cm,
                                                       jnp.ones((16,)), chunk=64))
    us = _time(ssd, x, dt, Bm, Cm)
    rows.append(("mamba2_ssd_ref_512", us, "chunked-matrix-form"))

    logits = jax.random.normal(key, (4096, 64))
    mg = jax.jit(lambda l: ref.moe_gating_ref(l, 6))
    us = _time(mg, logits)
    rows.append(("moe_gating_ref_4k", us, "top6-of-64"))

    with open(OUT / "kernel_microbench.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["name", "us_per_call", "derived"])
        w.writerows(rows)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows
