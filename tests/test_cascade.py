"""Difficulty-aware model cascade (DESIGN.md §18): routing, escalation,
parity, and live invalidation.

The invariant everything leans on mirrors §14's speculation bar: the
cascade can only change *which model* produced a value, never *which
value* — `cascade="off"` is byte-identical to a plain ServedExtractor,
`cascade="verify_all"` (route everything small, escalate everything) is
byte-identical to target-only, and `cascade="on"` keeps exact row parity
on this container because the §8.1 parse is deterministic in
(doc, attr, segments). Around that sit the mechanism tests: deterministic
memoized difficulty scores, sampling-stat folding, the exactly-once
tier-escalation memo, ledger invariance of the logical token columns, and
the live-mutation drop of difficulty estimates + memo entries.
"""
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core import DifficultyEstimator, Filter, Query, Session, conj
from repro.core.ledger import CostLedger
from repro.core.scheduler import BatchScheduler
from repro.core.stats import SampleStats
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.extract import CascadeExtractor, OracleExtractor, ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_params
from repro.serving.engine import ServingEngine

QWEN = "qwen2.5-3b"


def _cfg():
    return get_smoke_config(QWEN).replace(vocab_size=lm_data.VOCAB)


def _small_cfg():
    return _cfg().replace(num_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                          head_dim=16, d_ff=48)


@pytest.fixture(scope="module")
def params():
    return init_params(_cfg(), jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def small_params():
    return init_params(_small_cfg(), jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def corpus():
    return make_swde_corpus()


def _engines(params, small_params, slots=2):
    target = ServingEngine(_cfg(), params, slots=slots, max_len=1024,
                           prefix_cache=True)
    small = ServingEngine(_small_cfg(), small_params, slots=slots,
                          max_len=1024, prefix_cache=True)
    return target, small


def _uni_docs(corpus, n):
    return [d for d in sorted(corpus.docs) if "universities" in d][:n]


# ------------------------------------------------------------ estimator ----


def _folded_estimator(presence=1.0, n=4, cost=30.0, table="universities",
                      attr="tuition", **kw):
    est = DifficultyEstimator(None, **kw)
    stats = SampleStats(table=table)
    docs = [f"d{i}" for i in range(n)]
    present = round(presence * n)
    for i, d in enumerate(docs):
        stats.record(d, attr, 1 if i < present else None, int(cost))
    est.fold_sample(table, [attr], stats, sampled=docs)
    return est


def test_difficulty_scores_deterministic_and_memoized():
    est = _folded_estimator(presence=1.0)
    s1 = est.score("docA", "tuition", "universities")
    s2 = est.score("docA", "tuition", "universities")
    assert s1 == s2
    assert est.stats.memo_hits == 1
    # a second estimator with the same evidence scores identically
    est2 = _folded_estimator(presence=1.0)
    assert est2.score("docA", "tuition", "universities") == s1
    assert 0.0 <= s1 <= 1.0


def test_routing_rule_thresholds():
    # full agreement + cheap context -> easy -> small tier
    easy = _folded_estimator(presence=1.0, cost=20.0)
    assert easy.route("d", "tuition", "universities") == "small"
    # zero agreement + saturating context cost -> hard -> target tier
    hard = _folded_estimator(presence=0.0, cost=400.0)
    assert hard.route("d", "tuition", "universities") == "target"
    # threshold=0 forces the target tier regardless of evidence
    forced = _folded_estimator(presence=1.0, cost=20.0, threshold=0.0)
    assert forced.route("d", "tuition", "universities") == "target"
    # threshold=1 trusts the small tier with everything
    trusting = _folded_estimator(presence=0.0, cost=500.0, threshold=1.0)
    assert trusting.route("d", "tuition", "universities") == "small"


def test_fold_sample_summary_and_predicted_split():
    est = _folded_estimator(presence=0.75, n=4, cost=40.0)
    info = est._attr[("universities", "tuition")]
    assert info["presence"] == 0.75
    assert info["n"] == 4
    assert info["mean_cost"] == 40.0
    split = est.predicted_split("universities", "tuition")
    assert split is not None
    assert abs(split["small"] + split["target"] - 1.0) < 1e-6
    # unfolded attrs predict nothing
    assert est.predicted_split("universities", "enrollment") is None


def test_fold_sample_refreshes_stale_scores():
    est = _folded_estimator(presence=1.0)
    before = est.score("docA", "tuition", "universities")
    # refold with contradicting evidence: memoized score must recompute
    stats = SampleStats(table="universities")
    for i in range(4):
        stats.record(f"d{i}", "tuition", None, 30)
    est.fold_sample("universities", ["tuition"], stats, sampled=[])
    after = est.score("docA", "tuition", "universities")
    assert after > before


def test_drop_doc_removes_only_that_docs_estimates():
    est = _folded_estimator()
    est.score("docA", "tuition", "universities")
    est.score("docB", "tuition", "universities")
    assert est.drop_doc("docA") == 1
    assert ("docA", "tuition") not in est._scores
    assert ("docB", "tuition") in est._scores
    assert est.stats.estimates_dropped == 1


def test_retriever_margin_feeds_scores(corpus):
    retr = TwoLevelRetriever(corpus, mode="rag_topk")
    doc = _uni_docs(corpus, 1)[0]
    margin = retr.score_margin(doc, "tuition", "universities")
    assert margin is None or 0.0 <= margin <= 1.0
    est = DifficultyEstimator(retr)
    s = est.score(doc, "tuition", "universities", 30)
    assert 0.0 <= s <= 1.0


# ------------------------------------------------- extractor-level parity --


def _extract_direct(corpus, ext, items):
    """One extractor-level batch round over (doc, attr, [segment]) items."""
    return ext.extract_batch(items)


def _items_for(corpus, docs, attrs):
    # full doc text as the segment: the §8.1 fallback parse can always
    # find the value, so "on"-mode acceptance is exercised (a prefix slice
    # would escalate everything and only test the verify_all path)
    return [(d, a, [corpus.docs[d].text]) for d in docs for a in attrs]


def test_cascade_off_byte_identical_to_served(corpus, params, small_params):
    docs = _uni_docs(corpus, 2)
    items = _items_for(corpus, docs, ["tuition", "enrollment"])

    target, _ = _engines(params, small_params)
    plain = ServedExtractor(corpus, target, max_new=6)
    base = _extract_direct(corpus, plain, items)

    target2, small2 = _engines(params, small_params)
    casc = CascadeExtractor(corpus, target2, small2, cascade="off", max_new=6)
    off = _extract_direct(corpus, casc, items)

    assert off == base
    assert casc.stats.small_requests == 0
    assert casc.stats.routed_small == 0
    assert small2.stats["decode_steps"] == 0  # the small engine never runs
    # None small engine degrades to off, whatever mode was asked for
    assert CascadeExtractor(corpus, target2, None, cascade="on",
                            max_new=6).cascade == "off"


def test_verify_all_escalates_everything_rows_identical(
        corpus, params, small_params):
    docs = _uni_docs(corpus, 2)
    items = _items_for(corpus, docs, ["tuition", "enrollment"])

    target, _ = _engines(params, small_params)
    base = _extract_direct(corpus, ServedExtractor(corpus, target, max_new=6),
                           items)

    target2, small2 = _engines(params, small_params)
    casc = CascadeExtractor(corpus, target2, small2, cascade="verify_all",
                            max_new=6)
    rows = _extract_direct(corpus, casc, items)

    assert rows == base
    assert casc.stats.routed_small == len(items)
    assert casc.stats.escalations == len(items)    # verifier bounces all
    assert casc.stats.accepted_small == 0
    assert casc.stats.target_tokens_saved == 0     # pure waste, by design
    assert casc.stats.small_requests == len(items)
    assert small2.stats["decode_steps"] > 0


def test_cascade_on_values_identical_and_saves_target_tokens(
        corpus, params, small_params):
    docs = _uni_docs(corpus, 2)
    items = _items_for(corpus, docs, ["tuition", "enrollment"])

    target, _ = _engines(params, small_params)
    base = _extract_direct(corpus, ServedExtractor(corpus, target, max_new=6),
                           items)

    target2, small2 = _engines(params, small_params)
    est = _folded_estimator(presence=1.0, cost=20.0)
    stats = SampleStats(table="universities")
    for i in range(4):
        stats.record(f"d{i}", "enrollment", 1, 20)
    est.fold_sample("universities", ["enrollment"], stats, sampled=[])
    casc = CascadeExtractor(corpus, target2, small2, cascade="on",
                            difficulty=est, max_new=6)
    rows = _extract_direct(corpus, casc, items)

    # §8.1 parse is deterministic per (doc, attr, segments): accepted
    # small-tier values are exactly what the target would have produced
    assert rows == base
    assert casc.stats.accepted_small == len(items)
    assert casc.stats.target_tokens_saved > 0
    assert casc.stats.routed_small == len(items)
    # inherited columns stayed target-tier-only
    assert casc.stats.requests == 0
    assert casc.stats.small_requests == len(items)


def test_routing_is_deterministic_across_runs(corpus, params, small_params):
    docs = _uni_docs(corpus, 3)
    items = _items_for(corpus, docs, ["tuition", "enrollment"])

    def run():
        target, small = _engines(params, small_params)
        est = _folded_estimator(presence=0.5, n=4, cost=30.0)
        casc = CascadeExtractor(corpus, target, small, cascade="on",
                                difficulty=est, max_new=6)
        rows = _extract_direct(corpus, casc, items)
        return rows, (casc.stats.routed_small, casc.stats.routed_target,
                      casc.stats.escalations)

    r1, s1 = run()
    r2, s2 = run()
    assert r1 == r2
    assert s1 == s2


def test_escalation_memo_exactly_once(corpus, params, small_params):
    doc = _uni_docs(corpus, 1)[0]
    # a segment with no parseable value: the decoded text won't parse and
    # the §8.1 context fallback finds nothing -> verifier escalates
    items = [(doc, "tuition", ["no evidence in this segment"])]

    target, small = _engines(params, small_params)
    est = _folded_estimator(presence=1.0, cost=10.0)
    casc = CascadeExtractor(corpus, target, small, cascade="on",
                            difficulty=est, max_new=6)

    first = casc.extract_batch(items)
    assert first[0][0] is None
    assert casc.stats.escalations == 1
    assert (doc, "tuition") in casc.tier_memo
    small_reqs = casc.stats.small_requests

    # second round: the memo routes straight to target — the small model
    # is never paid twice for a (doc, attr) it already failed
    second = casc.extract_batch(items)
    assert second == first
    assert casc.stats.small_requests == small_reqs
    assert casc.stats.memo_target_routes == 1
    assert casc.stats.escalations == 1


def test_bad_cascade_mode_rejected(corpus, params, small_params):
    target, small = _engines(params, small_params)
    with pytest.raises(ValueError, match="unknown cascade mode"):
        CascadeExtractor(corpus, target, small, cascade="sometimes")


# ------------------------------------------- scheduler + session plumbing --


def test_cascade_counters_flow_to_ledger(corpus, params, small_params):
    docs = _uni_docs(corpus, 2)
    items = [(d, a, "universities") for d in docs
             for a in ("tuition", "enrollment")]

    def run(mode):
        target, small = _engines(params, small_params)
        retr = TwoLevelRetriever(corpus, mode="rag_topk")
        est = _folded_estimator(presence=1.0, cost=20.0)
        est.retriever = retr
        casc = CascadeExtractor(corpus, target, small, cascade=mode,
                                difficulty=est, max_new=6)
        ledger = CostLedger()
        sched = BatchScheduler(retr, casc, ledger, {}, batch_size=2)
        rows = sched.extract_many(items)
        return rows, casc, ledger

    rows_off, _, led_off = run("off")
    rows_on, casc, led_on = run("on")
    assert rows_on == rows_off
    # logical token columns are cascade-invariant; savings reported apart
    for col in ("input_tokens", "output_tokens", "total_tokens", "per_phase"):
        assert led_on.snapshot()[col] == led_off.snapshot()[col]
    snap = led_on.snapshot()
    assert snap["cascade_small"] == casc.stats.accepted_small
    assert snap["cascade_escalations"] == casc.stats.escalations
    assert snap["target_tokens_saved"] == casc.stats.target_tokens_saved
    if casc.stats.accepted_small:
        assert snap["target_tokens_saved"] > 0


def test_session_folds_difficulty_and_explains_tier_split(
        corpus, params, small_params):
    docs = _uni_docs(corpus, 8) + \
        [d for d in sorted(corpus.docs) if "laptops" in d][:4]
    sub = corpus.subset(docs)
    target, small = _engines(params, small_params)
    retr = TwoLevelRetriever(sub)
    casc = CascadeExtractor(sub, target, small, cascade="on",
                            difficulty=DifficultyEstimator(retr), max_new=6)
    session = Session(retr, casc, batch_size=2)
    query = Query(tables=["universities"],
                  select=[("universities", "university_name")],
                  where=conj(Filter("tuition", "<", 60000,
                                    table="universities")))
    session.execute(query)
    sample = session._samples["universities"]
    assert "tuition" in sample.difficulty
    assert set(sample.difficulty["tuition"]) >= {"presence", "mean_cost", "n",
                                                 "predicted_small"}
    # explain() after the sampling phase reports the predicted tier mix
    prepared = session.prepare(query)
    stage = prepared.explain()["tables"][0]["stages"][0]
    split = stage.get("predicted_tier_split")
    assert split is not None
    assert abs(split["small"] + split["target"] - 1.0) < 1e-6
    assert "cascade small" in prepared.explain_text()


# ------------------------------------------------------- live invalidation --


def test_live_mutation_drops_difficulty_and_tier_memo():
    from repro.data.corpus import make_wiki_corpus
    from repro.live import LiveCorpus, LiveRetriever, LiveSession, render_edit

    full = make_wiki_corpus(seed=0)
    ids = [d for d in full.docs if full.docs[d].domain == "players"][:6]
    live = LiveCorpus(full.subset(ids))
    retr = LiveRetriever(live)
    # an oracle extractor wearing the cascade's routing state: the drop
    # path only needs `difficulty` / `tier_memo` attributes (duck-typed
    # exactly like Session.drop_doc_state reads them)
    ext = OracleExtractor(live)
    ext.difficulty = DifficultyEstimator(retr)
    ext.tier_memo = {(ids[0], "age"), (ids[1], "age")}
    sess = LiveSession(live, retr, ext, batch_size=4)
    casc = sess.cascade     # LiveSession wires its own InvalidationCascade

    ext.difficulty.score(ids[0], "age", "players", 30)
    ext.difficulty.score(ids[1], "age", "players", 30)

    live.update(ids[0], render_edit(live, ids[0], "age", 41))

    assert (ids[0], "age") not in ext.difficulty._scores
    assert (ids[1], "age") in ext.difficulty._scores
    assert (ids[0], "age") not in ext.tier_memo
    assert (ids[1], "age") in ext.tier_memo
    assert casc.stats.difficulty_dropped == 1
    assert casc.stats.tier_memo_dropped == 1
    # post-mutation the doc re-scores fresh (fresh shot at the small tier)
    s = ext.difficulty.score(ids[0], "age", "players", 30)
    assert 0.0 <= s <= 1.0
