"""Paged KV cache (DESIGN.md §12): allocator semantics + layout parity.

The page/block layout is a serving-substrate change only — decoded outputs
must be byte-identical to the slab layout (with the prefix cache on or off)
for every model family, while prefill happens in strictly fewer jit
invocations than the slab path's per-token suffix decode. The allocator
tests pin down the failure modes that corrupt shared KV: double frees,
writes into shared prefix pages (copy-on-write boundary), and eviction of
entries whose pages are pinned by live slots.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import lm_data
from repro.models import init_decode_cache, init_params, prefill, prefill_chunk
from repro.models.cache_ops import (PAGE_SINK, PageAllocator,
                                    PagePoolExhausted, gather_page_views)
from repro.serving.engine import Request, ServingEngine
from repro.serving.prefix_cache import PrefixCache

QWEN = "qwen2.5-3b"


def _cfg(arch=QWEN):
    return get_smoke_config(arch).replace(vocab_size=lm_data.VOCAB)


# --------------------------------------------------------- allocator unit --


def test_page_allocator_free_list_and_exhaustion():
    alloc = PageAllocator(_cfg(), num_pages=5, page_size=8)
    assert alloc.free_pages == 4                      # page 0 is the sink
    a = alloc.alloc(3)
    assert len(set(a)) == 3 and PAGE_SINK not in a
    assert alloc.used_pages == 3
    with pytest.raises(PagePoolExhausted):
        alloc.alloc(2)
    assert alloc.free_pages == 1                      # all-or-nothing: no leak
    alloc.release(a)
    assert alloc.free_pages == 4 and alloc.used_pages == 0


def test_page_allocator_refcounts_and_double_free():
    alloc = PageAllocator(_cfg(), num_pages=4, page_size=8)
    (p,) = alloc.alloc(1)
    alloc.retain([p])                                 # rc=2 (shared prefix)
    alloc.release([p])                                # rc=1: still live
    assert alloc.free_pages == 2
    alloc.release([p])                                # rc=0: freed
    assert alloc.free_pages == 3
    with pytest.raises(RuntimeError, match="double free"):
        alloc.release([p])
    with pytest.raises(RuntimeError, match="retain of free"):
        alloc.retain([p])


def test_page_allocator_cow_copies_content():
    cfg = _cfg()
    alloc = PageAllocator(cfg, num_pages=6, page_size=8)
    (src,) = alloc.alloc(1)
    key = next(iter(alloc.pools))
    filled = alloc.pools[key].at[:, src].set(1.25)
    alloc.pools[key] = filled
    dst = alloc.copy_page(src)
    assert dst != src and alloc.refcount[dst] == 1
    np.testing.assert_array_equal(np.asarray(alloc.pools[key][:, dst]),
                                  np.asarray(alloc.pools[key][:, src]))
    # the copy is independent: writing dst leaves src intact
    alloc.pools[key] = alloc.pools[key].at[:, dst].set(-3.0)
    assert float(alloc.pools[key][:, src].max()) == 1.25


def test_gather_page_views_roundtrip():
    cfg = _cfg()
    alloc = PageAllocator(cfg, num_pages=8, page_size=4)
    ids = alloc.alloc(3)
    key = next(iter(alloc.pools))
    pool = alloc.pools[key]
    for n, i in enumerate(ids):
        pool = pool.at[:, i].set(float(n + 1))
    view = gather_page_views({key: pool}, jnp.asarray([ids], jnp.int32))[key]
    # (L, 1, 3*ps, ...): page order follows the table, not physical order
    got = np.asarray(view)[0, 0, :, 0]
    want = np.repeat([1.0, 2.0, 3.0], 4)
    np.testing.assert_array_equal(got[..., 0] if got.ndim > 1 else got, want)


# ------------------------------------------------------ layout parity ------


def _run_engine(cfg, params, prompts, shared, *, layout, pc, page_size=8,
                chunk_size=5, num_pages=None):
    eng = ServingEngine(cfg, params, slots=2, max_len=64, kv_layout=layout,
                        prefix_cache=pc, prefix_min_len=4,
                        page_size=page_size, chunk_size=chunk_size,
                        num_pages=num_pages)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=4, eos_id=-1,
                           shared_len=shared))
    done = eng.run()
    return eng, {i: done[i].out for i in range(len(prompts))}


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "deepseek-v2-lite-16b",
                                  "falcon-mamba-7b", "zamba2-2.7b",
                                  "whisper-medium", "llava-next-mistral-7b"])
def test_paged_slab_identical_outputs_all_families(arch):
    """dense / moe+MLA / ssm / hybrid / encdec / vlm: decoded outputs are
    byte-identical across {slab, paged} x {prefix cache off, on}, and the
    paged path prefills in strictly fewer jit invocations than the slab
    path's per-token suffix decode."""
    cfg = _cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    shared = [7, 3, 9, 4, 2, 8, 1, 6, 5, 7, 3, 2]
    prompts = [shared + [10 + i, 20 + i, 30 + i] for i in range(3)]
    _, slab_off = _run_engine(cfg, params, prompts, len(shared),
                              layout="slab", pc=False)
    e_pg_off, paged_off = _run_engine(cfg, params, prompts, len(shared),
                                      layout="paged", pc=False)
    e_slab, slab_on = _run_engine(cfg, params, prompts, len(shared),
                                  layout="slab", pc=True)
    e_paged, paged_on = _run_engine(cfg, params, prompts, len(shared),
                                    layout="paged", pc=True)
    assert slab_off == paged_off == slab_on == paged_on
    # token accounting is layout-invariant
    assert e_paged.stats["prefill_tokens"] == e_slab.stats["prefill_tokens"]
    assert e_paged.stats["prefix_hits"] == e_slab.stats["prefix_hits"] == 2
    # chunked suffix prefill beats token-at-a-time suffix prefill
    assert e_paged.stats["prefill_invocations"] < \
        e_slab.stats["prefill_invocations"]
    # every slot page returned to the pool; only prefix entries hold refs
    live = sum(1 for rc in e_paged.alloc.refcount[1:] if rc > 0)
    entry_pages = sum(len(e.pages) + (e.tail_page is not None)
                      for e in e_paged.prefix_cache._entries.values())
    assert live == entry_pages


def test_paged_cow_boundary_page_isolation():
    """A prefix hit writes its suffix through a CoW copy — the entry's
    boundary page must stay byte-identical so later hits replay the same
    prefix KV."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    pc = PrefixCache(max_entries=8)
    eng = ServingEngine(cfg, params, slots=1, max_len=64, prefix_cache=pc,
                        prefix_min_len=4, page_size=8, chunk_size=6)
    shared = [7, 3, 9, 4, 2, 8, 1, 6, 5, 7]        # 10 tokens: tail page busy
    eng.submit(Request(rid=0, prompt=shared + [11, 12], max_new=3, eos_id=-1,
                       shared_len=len(shared)))
    eng.run()
    (entry,) = pc._entries.values()
    assert entry.tail_page is not None and len(entry.pages) == 1
    key = next(iter(eng.alloc.pools))
    before = np.asarray(eng.alloc.pools[key][:, entry.tail_page]).copy()
    # two hits, each decoding a different suffix through its own CoW copy
    for rid, tail in ((1, [21, 22]), (2, [31, 32, 33])):
        eng.submit(Request(rid=rid, prompt=shared + tail, max_new=3,
                           eos_id=-1, shared_len=len(shared)))
    done = eng.run()
    after = np.asarray(eng.alloc.pools[key][:, entry.tail_page])
    np.testing.assert_array_equal(before, after)
    assert eng.stats["prefix_hits"] == 2 and eng.stats["cow_copies"] >= 3
    # and the hits decode exactly what a cold engine would
    eng2 = ServingEngine(cfg, params, slots=1, max_len=64, prefix_cache=False,
                         page_size=8, chunk_size=6)
    for rid, tail in ((1, [21, 22]), (2, [31, 32, 33])):
        eng2.submit(Request(rid=rid, prompt=shared + tail, max_new=3,
                            eos_id=-1, shared_len=len(shared)))
    done2 = eng2.run()
    assert {r: done[r].out for r in (1, 2)} == {r: done2[r].out for r in (1, 2)}


def test_paged_pool_pressure_evicts_lru_then_pins_win():
    """Under pool pressure the engine evicts LRU prefix entries to free
    pages; entries pinned by a live slot free nothing, and hard exhaustion
    surfaces as PagePoolExhausted with the partial allocation rolled back."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    pc = PrefixCache(max_entries=8)
    # pool of 6 usable pages: slot 0's 4 blocks + the snapshot's CoW tail
    # leave exactly one free page
    eng = ServingEngine(cfg, params, slots=2, max_len=32, prefix_cache=pc,
                        prefix_min_len=4, page_size=8, chunk_size=8,
                        num_pages=7)
    p1 = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]
    eng._insert(0, Request(rid=0, prompt=p1 + [11], max_new=20, eos_id=-1,
                           shared_len=len(p1)))       # slot 0 stays live
    assert len(pc) == 1 and eng.alloc.free_pages == 1
    entries_before = pc.stats.evictions
    # a second, different prefix group: needs 4 fresh blocks -> pressure.
    # The only evictable entry is pinned by slot 0, so eviction frees
    # nothing and allocation must fail cleanly.
    free_before = eng.alloc.free_pages
    p2 = [9, 9, 9, 9, 8, 8, 8, 8, 7, 7]
    with pytest.raises(PagePoolExhausted):
        eng._insert(1, Request(rid=1, prompt=p2 + [1], max_new=20, eos_id=-1,
                               shared_len=len(p2)))
    assert pc.stats.evictions > entries_before        # it did try the LRU
    assert eng.alloc.free_pages >= free_before        # rollback: no leak
    # freeing the pinning slot releases its pages and the insert succeeds
    eng.drain_slot(0)
    eng._insert(1, Request(rid=1, prompt=p2 + [1], max_new=4, eos_id=-1,
                           shared_len=len(p2)))
    assert eng.active[1].rid == 1


def test_paged_prefix_eviction_returns_pages():
    """PrefixCache LRU eviction must release page references: a bounded
    store over many prefix groups cannot grow the pool footprint."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    pc = PrefixCache(max_entries=2)
    eng = ServingEngine(cfg, params, slots=1, max_len=64, prefix_cache=pc,
                        prefix_min_len=4, page_size=8, chunk_size=8)
    for g in range(4):                                # 4 groups, store holds 2
        base = [g + 1] * 10
        for t in range(2):
            eng.submit(Request(rid=10 * g + t, prompt=base + [30 + t],
                               max_new=3, eos_id=-1, shared_len=len(base)))
        eng.run()
    assert len(pc) == 2 and pc.stats.evictions == 2
    live = sum(1 for rc in eng.alloc.refcount[1:] if rc > 0)
    entry_pages = sum(len(e.pages) + (e.tail_page is not None)
                      for e in pc._entries.values())
    assert live == entry_pages                        # evicted pages returned


# -------------------------------------------------- bucketed jit prefill ---


def test_slab_prefill_signatures_bucketed():
    """Distinct prompt lengths inside one chunk_size bucket share a single
    prefill compile, and padding never changes the decoded output."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))

    def outs(chunk_size):
        eng = ServingEngine(cfg, params, slots=2, max_len=64,
                            kv_layout="slab", chunk_size=chunk_size)
        for i, n in enumerate((9, 12, 15)):
            eng.submit(Request(rid=i, prompt=list(range(1, n + 1)),
                               max_new=4, eos_id=-1))
        done = eng.run()
        return eng, {i: done[i].out for i in range(3)}

    e16, o16 = outs(16)
    e1, o1 = outs(1)                   # bucket==exact length: PR 2 behaviour
    assert o16 == o1
    assert len(e16._prefill_cache) == 1        # 9, 12, 15 -> one 16-signature
    assert len(e1._prefill_cache) == 3


def test_slab_bucket_respects_image_tokens():
    """Bucket padding must never push text + image tokens past max_len for
    a prompt that legally fits (regression: vlm near the cache bound)."""
    cfg = _cfg("llava-next-mistral-7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_img = cfg.n_image_tokens
    eng = ServingEngine(cfg, params, slots=1, max_len=64, kv_layout="slab",
                        chunk_size=32)
    n = 64 - n_img - 1                 # fits exactly, bucket would round past
    eng.submit(Request(rid=0, prompt=list(range(1, n + 1)), max_new=2,
                       eos_id=-1))
    done = eng.run()
    assert len(done[0].out) == 2


def test_bucketed_prefill_short_ssm_prompt_exact():
    """length < ssm_conv-1: the conv window must see zero history, not a
    clamped misaligned slice (regression)."""
    cfg = _cfg("falcon-mamba-7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = [5, 9]
    exact_l, exact_c = prefill(
        cfg, params, {"tokens": jnp.asarray(toks, jnp.int32)[None]}, 16)
    padded = toks + [0] * 6
    buck_l, buck_c = prefill(
        cfg, params, {"tokens": jnp.asarray(padded, jnp.int32)[None]}, 16,
        jnp.int32(2))
    np.testing.assert_allclose(np.asarray(buck_l), np.asarray(exact_l),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(buck_c["conv"]),
                                  np.asarray(exact_c["conv"]))


def test_run_requeues_request_on_pool_exhaustion():
    """A PagePoolExhausted mid-run() must leave the victim request at the
    queue head, never silently dropped (regression)."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    # pool too small for even one request's pages
    eng = ServingEngine(cfg, params, slots=1, max_len=32, page_size=8,
                        num_pages=2, prefix_cache=False)
    req = Request(rid=0, prompt=list(range(1, 20)), max_new=4, eos_id=-1)
    eng.submit(req)
    with pytest.raises(PagePoolExhausted):
        eng.run()
    assert list(eng.queue) == [req]
    assert not eng.active and not eng.finished


def test_chunked_prefill_matches_full_prefill():
    """Direct model-level check: successive prefill_chunk calls reproduce
    full-prefill logits and cache position."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = list(np.random.RandomState(7).randint(1, 200, size=13))
    full_logits, full_cache = prefill(
        cfg, params, {"tokens": jnp.asarray(toks, jnp.int32)[None]}, 32)
    cache = init_decode_cache(cfg, 1, 32)
    logits = None
    for a, b in ((0, 5), (5, 9), (9, 13)):
        logits, cache = prefill_chunk(
            cfg, params, {"tokens": jnp.asarray(toks[a:b], jnp.int32)[None]},
            cache)
    assert int(cache["pos"]) == int(full_cache["pos"]) == 13
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               atol=1e-5, rtol=1e-5)


# ----------------------------------------------- allocator properties ------
# Hypothesis-driven invariants for the *shared-pool* regime (serving/
# replicas.py shares one PageAllocator across engine replicas): two clients
# interleave acquire / retain (prefix splice) / CoW / release against one
# allocator. Whatever the interleaving, ref-counts must match an exact model
# (conservation — every acquire is balanced by exactly one release), the
# free list must never hold a live page, and a drained client's second
# release must fail loudly (double free). Skips when hypothesis is absent.


def test_page_allocator_shared_pool_properties():
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    cfg = _cfg()
    op = st.tuples(st.sampled_from(["alloc", "retain", "release", "cow",
                                    "drain"]),
                   st.integers(0, 1),         # client id
                   st.integers(0, 7))         # operand selector
    NUM_PAGES = 9

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(op, min_size=1, max_size=40))
    def run(ops):
        alloc = PageAllocator(cfg, num_pages=NUM_PAGES, page_size=4)
        model = {}                    # page -> expected refcount
        owned = {0: [], 1: []}        # client -> refs held (dups = refs)
        for action, client, sel in ops:
            refs = owned[client]
            if action == "alloc":
                n = sel % 3 + 1
                if n <= alloc.free_pages:
                    ids = alloc.alloc(n)
                    assert all(model.get(i, 0) == 0 for i in ids), \
                        "allocated a live page"
                    for i in ids:
                        model[i] = 1
                    refs.extend(ids)
                else:                 # all-or-nothing: nothing leaks
                    before = alloc.free_pages
                    with pytest.raises(PagePoolExhausted):
                        alloc.alloc(n)
                    assert alloc.free_pages == before
            elif action == "retain":
                both = owned[0] + owned[1]
                if both:              # cross-client prefix splice
                    p = both[sel % len(both)]
                    alloc.retain([p])
                    model[p] += 1
                    refs.append(p)
            elif action == "release":
                if refs:
                    p = refs.pop(sel % len(refs))
                    alloc.release([p])
                    model[p] -= 1
            elif action == "cow":
                if refs and alloc.free_pages:
                    dst = alloc.copy_page(refs[sel % len(refs)])
                    assert model.get(dst, 0) == 0
                    model[dst] = 1
                    refs.append(dst)
            elif action == "drain":   # replica frees a whole slot at once
                if refs:
                    alloc.release(refs)
                    for p in refs:
                        model[p] -= 1
                    refs.clear()
            # invariants, after every single op
            live = {p for p, c in model.items() if c > 0}
            for p in range(1, NUM_PAGES):
                assert alloc.refcount[p] == model.get(p, 0), f"page {p}"
            free = alloc._free
            assert len(free) == len(set(free)), "free list duplicate"
            assert not set(free) & live, "free list holds a live page"
            assert PAGE_SINK not in free
            assert alloc.free_pages + alloc.used_pages == NUM_PAGES - 1
        # conservation at the end: refs held == total live refcount
        assert sum(c for c in model.values() if c > 0) == \
            sum(len(r) for r in owned.values())
        # and a page fully drained by both clients double-frees loudly
        dead = [p for p, c in model.items() if c == 0]
        if dead:
            with pytest.raises(RuntimeError, match="double free"):
                alloc.release([dead[0]])

    run()
