"""Async serving tier (DESIGN.md §16): admission control, fair-share
scheduling, backpressure, cancellation and resource-leak regression.

Property layer (hypothesis when available, fixed examples otherwise) runs
against a pure-Python `FakeEngine` implementing the engine's non-blocking
step contract (step/poll/cancel/free_slots/estimate_pages/pool_free_pages)
so scheduling-policy invariants are checked exactly and fast:

  * weighted fair share: while two tenants stay backlogged, their admitted
    work per unit weight never diverges past the WFQ one-request bound;
  * no starvation within a priority class: every queued ticket resolves;
  * strict priority: a backlogged higher class always dispatches first;
  * all-or-nothing `submit_many` under `max_queue`, and conservation:
    submitted == completed + failed + shed + cancelled + timeouts.

Integration layer drives the real `ServingEngine`: byte-identical outputs
under chunked-prefill pumping vs. serial runs, `PagePoolExhausted` never
escaping the frontend, and the leak regression — cancel/timeout at every
lifecycle stage returns the paged-KV pool to its baseline free count.
Session-level cancellation (`QueryCancelled`/`QueryTimeout`, sampling
reservations rolled back) rides on the oracle extractor.
"""
import time
from collections import deque

import pytest

try:                                   # hypothesis is optional in the image
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.models.cache_ops import PagePoolExhausted
from repro.serving.frontend import (ADMITTED, CANCELLED, DONE, QUEUED, SHED,
                                    SHED_QUEUE_FULL, SHED_TOO_LARGE, TIMEOUT,
                                    ServingFrontend)


# ---------------------------------------------------------- fake substrate --


class FakeEngine:
    """Minimal deterministic engine speaking the non-blocking step API the
    frontend schedules against: slot-bounded admission, a page pool that
    must cover each request's estimated demand, one decode token per step,
    `defer_admission` requeue-at-head semantics on exhaustion."""

    def __init__(self, *, slots=2, max_len=64, num_pages=1000, page_size=8):
        self.slots, self.max_len = slots, max_len
        self.page_size, self.total_pages = page_size, num_pages
        self._free_pages = num_pages
        self._extra = 0
        self.queue: deque = deque()
        self.active: dict = {}          # rid -> (req, pages)
        self._inserting: dict = {}      # unused: admission is atomic here
        self.finished: dict = {}
        self.failed: dict = {}
        self.cancelled: dict = {}
        self.admission_order: list = [] # rids in dispatch order (for props)

    @property
    def free_slots(self):
        return self.slots - len(self.active)

    def estimate_pages(self, prompt_len, max_new):
        return -(-min(prompt_len + max_new, self.max_len) // self.page_size)

    def pool_free_pages(self):
        return self._free_pages

    def submit(self, req):
        self.queue.append(req)

    def poll(self, rid):
        for d in (self.finished, self.failed, self.cancelled):
            if rid in d:
                return d[rid]
        return None

    def cancel(self, rid):
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                self._resolve_cancel(req)
                return True
        if rid in self.active:
            req, pages = self.active.pop(rid)
            self._free_pages += pages
            req.out.clear()
            self._resolve_cancel(req)
            return True
        return False

    def _resolve_cancel(self, req):
        req.error, req.done = "cancelled", False
        self.cancelled[req.rid] = req

    def step(self, *, max_prefill_chunks=None, defer_admission=False):
        while self.queue and self.free_slots > 0:
            req = self.queue.popleft()
            pages = self.estimate_pages(len(req.prompt), req.max_new)
            if pages > self._free_pages:
                self.queue.appendleft(req)      # hardening contract
                if defer_admission and self.active:
                    break
                raise PagePoolExhausted(
                    f"need {pages} pages, {self._free_pages} free")
            self._free_pages -= pages
            self.active[req.rid] = (req, pages)
            self.admission_order.append(req.rid)
        for rid in list(self.active):
            req, pages = self.active[rid]
            req.out.append((rid * 31 + len(req.out)) % 50)
            if len(req.out) >= req.max_new:
                del self.active[rid]
                self._free_pages += pages
                req.done = True
                self.finished[rid] = req
        return bool(self.queue or self.active)


def _fe(engine=None, **kw):
    return ServingFrontend(engine or FakeEngine(), **kw)


def _prompt(n=8):
    return list(range(n))


# ----------------------------------------------------------- fixed intake --


def test_ticket_lifecycle_and_poll():
    fe = _fe()
    t = fe.submit(_prompt(), tenant="a", max_new=3)
    assert t.status == QUEUED and not t.done
    fe.pump()
    assert t.status == ADMITTED and fe.poll(t.rid) is t
    fe.pump_until_idle()
    assert t.status == DONE and t.done
    assert t.out and len(t.out) == 3
    assert t.resolved_tick >= t.admitted_tick >= t.submitted_tick


def test_shed_too_large_prompt_and_pages():
    fe = _fe(FakeEngine(max_len=16, num_pages=1, page_size=8))
    t1 = fe.submit(_prompt(40), tenant="a")          # prompt over max_len
    t2 = fe.submit(_prompt(10), tenant="a", max_new=6)   # 2 pages > pool 1
    assert (t1.status, t1.shed_reason) == (SHED, SHED_TOO_LARGE)
    assert (t2.status, t2.shed_reason) == (SHED, SHED_TOO_LARGE)
    ok = fe.submit(_prompt(4), tenant="a", max_new=4)    # 1 page: fits
    fe.pump_until_idle()
    assert ok.status == DONE


def test_shed_queue_full_bound():
    fe = _fe(max_queue=2)
    kept = [fe.submit(_prompt(), tenant="a") for _ in range(2)]
    over = fe.submit(_prompt(), tenant="a")
    assert (over.status, over.shed_reason) == (SHED, SHED_QUEUE_FULL)
    fe.pump_until_idle()
    assert all(t.status == DONE for t in kept)


def test_submit_many_all_or_nothing():
    fe = _fe(max_queue=4)
    first = fe.submit_many([_prompt() for _ in range(3)], tenant="a")
    assert all(t.status == QUEUED for t in first)
    batch = fe.submit_many([_prompt() for _ in range(3)], tenant="b")
    assert all((t.status, t.shed_reason) == (SHED, SHED_QUEUE_FULL)
               for t in batch), "batch past the bound must shed wholesale"
    assert fe.queued == 3                    # nothing half-enqueued
    fe.pump_until_idle()
    assert all(t.status == DONE for t in first)
    snap = fe.tenants["b"].snapshot()
    assert snap["submitted"] == 3 and snap["shed"] == 3
    assert snap["queue_depth"] == 0


# ----------------------------------------------------- cancellation/expiry --


def test_cancel_queued_and_admitted_releases_pages():
    eng = FakeEngine(slots=1, num_pages=8, page_size=8)
    fe = _fe(eng)
    base = eng.pool_free_pages()
    t1 = fe.submit(_prompt(), tenant="a", max_new=6)
    t2 = fe.submit(_prompt(), tenant="a", max_new=6)
    fe.pump()                                # t1 admitted, t2 queued
    assert t1.status == ADMITTED and t2.status == QUEUED
    assert fe.cancel(t2) and t2.status == CANCELLED
    assert fe.cancel(t1) and t1.status == CANCELLED
    assert not fe.cancel(t1), "second cancel lost the race"
    assert eng.pool_free_pages() == base, "cancel leaked pool pages"
    fe.pump()
    assert not fe.has_work()
    assert fe.stats["cancelled"] == 2
    assert fe.tenants["a"].in_flight == 0
    assert fe.tenants["a"].pool_pages_held == 0


def test_deadline_ticks_times_out_queued_and_inflight():
    eng = FakeEngine(slots=1)
    fe = _fe(eng)
    base = eng.pool_free_pages()
    slow = fe.submit(_prompt(), tenant="a", max_new=50, deadline_ticks=3)
    waiting = fe.submit(_prompt(), tenant="a", max_new=4, deadline_ticks=1)
    fe.pump()                                # slow admitted, waiting queued
    fe.pump()                                # tick 2 > waiting's deadline
    assert waiting.status == TIMEOUT
    fe.pump(); fe.pump()                     # past slow's deadline in flight
    assert slow.status == TIMEOUT
    assert eng.pool_free_pages() == base, "timeout leaked pool pages"
    assert fe.stats["timeouts"] == 2
    fe.pump_until_idle()


def test_wall_clock_deadline():
    fe = _fe(FakeEngine(slots=1), clock="wall")
    blocker = fe.submit(_prompt(), tenant="a", max_new=10_000)
    t = fe.submit(_prompt(), tenant="a", deadline_s=0.0)
    time.sleep(0.005)
    fe.pump()
    assert t.status == TIMEOUT
    fe.cancel(blocker)


# ------------------------------------------------------------ backpressure --


def test_pool_exhaustion_defers_instead_of_raising():
    # pool fits one request at a time; the second must wait, not explode
    eng = FakeEngine(slots=2, num_pages=2, page_size=8, max_len=16)
    fe = _fe(eng)
    ts = [fe.submit(_prompt(8), tenant="a", max_new=8) for _ in range(3)]
    fe.pump_until_idle()
    assert all(t.status == DONE for t in ts)
    assert fe.stats["shed"] == 0
    assert fe.stats["deferred"] > 0, "headroom gate never engaged"
    assert eng.pool_free_pages() == 2


def test_pool_exhausted_absorbed_when_estimate_lies():
    # an engine whose live demand exceeds the frontend's estimate: the
    # raise (no active work -> defer arm unavailable) must still be
    # absorbed, counted, and retried — callers never see the exception
    class Lying(FakeEngine):
        def estimate_pages(self, prompt_len, max_new):
            return 0                    # frontend sees infinite headroom

        def step(self, *, max_prefill_chunks=None, defer_admission=False):
            if self.queue and not self.active and not self._primed:
                self._primed = True
                raise PagePoolExhausted("transient")
            return super().step(max_prefill_chunks=max_prefill_chunks,
                                defer_admission=defer_admission)

    eng = Lying()
    eng._primed = False
    fe = _fe(eng)
    t = fe.submit(_prompt(), tenant="a", max_new=2)
    fe.pump_until_idle()
    assert t.status == DONE
    assert fe.stats["pool_exhausted_absorbed"] == 1


# ------------------------------------------------------ scheduling properties


def _drain_order(weights, counts, *, priorities=None, cost=8, max_new=2):
    """Submit counts[i] requests for tenant i, pump to idle, return the
    admission order as (tenant, rid) pairs."""
    eng = FakeEngine(slots=1, num_pages=1000)
    fe = _fe(eng, tenant_weights=weights)
    tickets = {}
    for ti, (tenant, n) in enumerate(counts.items()):
        for j in range(n):
            t = fe.submit(_prompt(cost), tenant=tenant, max_new=max_new,
                          priority=(priorities or {}).get(tenant, 0))
            tickets[t.rid] = t
    fe.pump_until_idle()
    order = [(tickets[rid].tenant, rid) for rid in eng.admission_order]
    return order, tickets, fe


def _check_fair_share(w_a, w_b, n):
    weights = {"a": float(w_a), "b": float(w_b)}
    order, tickets, fe = _drain_order(weights, {"a": n, "b": n})
    assert all(t.status == DONE for t in tickets.values())   # no starvation
    # WFQ bound while both tenants stay backlogged: admitted-per-weight
    # can differ by at most one request's worth of virtual time
    admitted = {"a": 0, "b": 0}
    remaining = {"a": n, "b": n}
    for tenant, _rid in order:
        if min(remaining.values()) > 0:
            gap = abs(admitted["a"] / weights["a"]
                      - admitted["b"] / weights["b"])
            assert gap <= 1.0 / min(weights.values()) + 1e-9, (
                f"fair-share divergence {gap} with weights {weights}")
        admitted[tenant] += 1
        remaining[tenant] -= 1


def _check_priority_strict(n):
    order, tickets, fe = _drain_order(
        {"hi": 1.0, "lo": 1.0}, {"hi": n, "lo": n},
        priorities={"hi": 5, "lo": 0})
    # every hi-class request dispatches before any lo-class one (both
    # backlogged from tick 0 — strict classes, starvation by design)
    kinds = [tenant for tenant, _ in order]
    assert kinds == ["hi"] * n + ["lo"] * n
    assert all(t.status == DONE for t in tickets.values())


def _check_conservation(n_a, n_b, max_queue):
    fe = _fe(FakeEngine(slots=2), max_queue=max_queue)
    ts = [fe.submit(_prompt(), tenant="a", max_new=2) for _ in range(n_a)]
    ts += fe.submit_many([_prompt() for _ in range(n_b)], tenant="b",
                         max_new=2)
    if ts:
        fe.cancel(ts[0])
    fe.pump_until_idle()
    assert all(t.done for t in ts)
    s = fe.stats
    assert s["submitted"] == (s["completed"] + s["failed"] + s["shed"]
                              + s["cancelled"] + s["timeouts"])
    for snap in fe.tenant_snapshot().values():
        assert snap["queue_depth"] == 0 and snap["in_flight"] == 0
        assert snap["submitted"] == (snap["completed"] + snap["failed"]
                                     + snap["shed"] + snap["cancelled"]
                                     + snap["timeouts"])


if st is not None:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 4), st.integers(2, 12))
    def test_fair_share_within_wfq_bound(w_a, w_b, n):
        _check_fair_share(w_a, w_b, n)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 8))
    def test_priority_class_is_strict(n):
        _check_priority_strict(n)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 8), st.integers(0, 8), st.integers(1, 10))
    def test_accounting_conserved(n_a, n_b, max_queue):
        _check_conservation(n_a, n_b, max_queue)
else:
    @pytest.mark.parametrize("w_a,w_b,n",
                             [(1, 1, 6), (2, 1, 8), (1, 3, 5), (4, 1, 12)])
    def test_fair_share_within_wfq_bound(w_a, w_b, n):
        _check_fair_share(w_a, w_b, n)

    @pytest.mark.parametrize("n", [1, 3, 8])
    def test_priority_class_is_strict(n):
        _check_priority_strict(n)

    @pytest.mark.parametrize("n_a,n_b,max_queue",
                             [(0, 0, 1), (3, 2, 10), (8, 8, 4), (1, 8, 3)])
    def test_accounting_conserved(n_a, n_b, max_queue):
        _check_conservation(n_a, n_b, max_queue)


# ------------------------------------------------------- real-engine layer --


@pytest.fixture(scope="module")
def served():
    jax = pytest.importorskip("jax")
    from repro.configs import get_smoke_config
    from repro.data import lm_data
    from repro.models import init_params
    from repro.serving.engine import ServingEngine
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))

    def make(**kw):
        kw.setdefault("slots", 2)
        kw.setdefault("max_len", 96)
        kw.setdefault("kv_layout", "paged")
        kw.setdefault("page_size", 16)
        kw.setdefault("num_pages", 16)
        return ServingEngine(cfg, params, **kw)
    return make


def _real_reqs(n, max_new=5):
    from repro.data import lm_data
    from repro.serving.engine import Request
    return [Request(rid=i, prompt=lm_data.encode(f"probe {i} value="),
                    max_new=max_new) for i in range(n)]


def test_real_engine_rows_match_serial(served):
    serial = {}
    eng_s = served()
    for req in _real_reqs(4):
        eng_s.submit(req)
        serial[req.rid] = list(eng_s.run()[req.rid].out)
    eng = served()
    fe = ServingFrontend(eng, max_prefill_chunks=1)
    ts = [fe.submit(req=r, tenant=f"t{r.rid % 2}") for r in _real_reqs(4)]
    fe.pump_until_idle()
    assert all(t.status == DONE for t in ts)
    assert {t.rid: list(t.req.out) for t in ts} == serial


def test_real_engine_leak_regression_on_cancel_and_timeout(served):
    eng = served(prefix_cache=True)
    fe = ServingFrontend(eng, max_prefill_chunks=1)
    base = eng.pool_free_pages()
    reqs = _real_reqs(4, max_new=20)
    cancelled_mid = fe.submit(req=reqs[0], tenant="a")
    timed_out = fe.submit(req=reqs[1], tenant="a", deadline_ticks=2)
    cancelled_queued = fe.submit(req=reqs[2], tenant="b")
    survivor = fe.submit(req=reqs[3], tenant="b")
    fe.cancel(cancelled_queued)              # still QUEUED: no engine state
    fe.pump()                                # first two mid-insert/active
    fe.cancel(cancelled_mid)
    fe.pump(); fe.pump()                     # deadline passes in flight
    fe.pump_until_idle()
    assert cancelled_mid.status == CANCELLED
    assert timed_out.status == TIMEOUT
    assert cancelled_queued.status == CANCELLED
    assert survivor.status == DONE
    eng.prefix_cache.clear()                 # cache-held pages are accounted
    assert eng.pool_free_pages() == base, "lifecycle exit leaked KV pages"


def test_real_engine_backpressure_never_raises(served):
    # pool fits ~one request; the rest defer/absorb, never raise
    eng = served(num_pages=4, prefix_cache=False)
    fe = ServingFrontend(eng, max_prefill_chunks=1)
    base = eng.pool_free_pages()             # num_pages minus the sink page
    ts = [fe.submit(req=r, tenant="a") for r in _real_reqs(3, max_new=8)]
    fe.pump_until_idle()
    assert all(t.status == DONE for t in ts)
    assert fe.stats["deferred"] + fe.stats["pool_exhausted_absorbed"] + \
        eng.stats["admission_deferred"] > 0
    assert eng.pool_free_pages() == base


# ------------------------------------------------------------ session layer --


def test_session_cancel_and_timeout_release_sampling():
    from repro.core import (QueryCancelled, QueryTimeout, Session, Filter,
                            Query, conj)
    from repro.data.corpus import make_wiki_corpus
    from repro.extract import OracleExtractor
    from repro.index.retriever import TwoLevelRetriever
    corpus = make_wiki_corpus(seed=0)
    q = Query(tables=["players"], select=[("players", "player_name")],
              where=conj(Filter("age", ">", 30, table="players"),
                         Filter("all_stars", ">=", 5, table="players")))
    sess = Session(TwoLevelRetriever(corpus), OracleExtractor(corpus),
                   batch_size=4)
    h = sess.submit(q, tenant="acme")
    sess._step()                             # mid-sampling: owns reservation
    assert h.cancel() and not h.cancel()
    with pytest.raises(QueryCancelled):
        h.result()
    assert not sess._samples, "cancel left a sampling reservation behind"
    # the session still works: a fresh submit runs to completion, and a
    # zero-deadline one times out with the typed subclass
    ref = sess.execute(q)
    assert ref.rows is not None
    h2 = sess.submit(q, deadline_s=0.0)
    time.sleep(0.005)
    with pytest.raises(QueryTimeout):
        h2.result()
    assert not sess._active
