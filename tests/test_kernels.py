"""Per-kernel allclose vs. the pure-jnp oracles (interpret=True on CPU).

Each Pallas kernel is swept over shapes (incl. non-aligned tails where the
wrapper pads), GQA group factors, causal/non-causal, and dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention_pallas,
                                            paged_decode_attention_pallas,
                                            paged_decode_attention_ref,
                                            paged_verify_attention_pallas,
                                            paged_verify_attention_ref)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gating import moe_gating_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas
from repro.kernels.topk_l2 import topk_l2_pallas

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------- flash attention ---


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 2, 2, 64),
    (2, 256, 4, 2, 64),
    (1, 256, 8, 1, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, Hkv, D, causal, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (B, S, H, D), dtype)
    k = rand(k2, (B, S, Hkv, D), dtype)
    v = rand(k3, (B, S, Hkv, D), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, bq=64, bk=64,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


# ------------------------------------------------------- decode attention --


@pytest.mark.parametrize("B,S,H,Hkv,D,length", [
    (2, 512, 4, 2, 64, 317),
    (1, 1024, 8, 8, 128, 1024),
    (3, 256, 2, 1, 64, 19),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, H, Hkv, D, length, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (B, H, D), dtype)
    kc = rand(k2, (B, S, Hkv, D), dtype)
    vc = rand(k3, (B, S, Hkv, D), dtype)
    out = decode_attention_pallas(q, kc, vc, length, bk=128, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, length)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,Hkv,D,P,ps,nb", [
    (2, 4, 2, 64, 16, 128, 4),
    (3, 2, 1, 128, 9, 256, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, H, Hkv, D, P, ps, nb, dtype):
    """The paged kernel walks K/V through a scalar-prefetched page table —
    scattered physical pages must attend identically to the gathered dense
    cache (both against the jnp gather reference and the dense kernel)."""
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = rand(k1, (B, H, D), dtype)
    kp = rand(k2, (P, ps, Hkv, D), dtype)
    vp = rand(k3, (P, ps, Hkv, D), dtype)
    # distinct random physical pages per row, deliberately out of order
    perm = jax.random.permutation(k4, P)[: B * nb].reshape(B, nb)
    lengths = jnp.asarray([(nb * ps * (i + 1)) // (B + 1) for i in range(B)],
                          jnp.int32)
    out = paged_decode_attention_pallas(q, kp, vp, perm, lengths, interpret=True)
    want = paged_decode_attention_ref(q, kp, vp, perm, lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)
    # cross-check the reference itself against the dense-path reference
    kg = kp[perm].reshape(B, nb * ps, Hkv, D)
    vg = vp[perm].reshape(B, nb * ps, Hkv, D)
    dense = jnp.stack([ref.decode_attention_ref(q[i:i + 1], kg[i:i + 1],
                                                vg[i:i + 1], lengths[i])[0]
                       for i in range(B)])
    np.testing.assert_allclose(np.asarray(want, np.float32),
                               np.asarray(dense, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,Hkv,C,D,P,ps,nb", [
    (2, 4, 2, 5, 64, 16, 128, 4),
    (3, 2, 1, 3, 128, 9, 256, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_verify_attention(B, H, Hkv, C, D, P, ps, nb, dtype):
    """Speculative-verification kernel: C candidate tokens per row attend
    the paged KV causally from per-row start positions — must match the
    gathered-dense causal reference (the batched-verify decode path of
    DESIGN.md §14)."""
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = rand(k1, (B, H, C, D), dtype)
    kp = rand(k2, (P, ps, Hkv, D), dtype)
    vp = rand(k3, (P, ps, Hkv, D), dtype)
    perm = jax.random.permutation(k4, P)[: B * nb].reshape(B, nb)
    # per-row starts, incl. one crossing a page boundary mid-candidates
    starts = jnp.asarray([(nb * ps * (i + 1)) // (B + 1) - C // 2
                          for i in range(B)], jnp.int32)
    out = paged_verify_attention_pallas(q, kp, vp, perm, starts, interpret=True)
    want = paged_verify_attention_ref(q, kp, vp, perm, starts)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


# --------------------------------------------------------------- topk_l2 ---


@pytest.mark.parametrize("N,D,M,k", [
    (512, 64, 4, 5),
    (1000, 128, 7, 10),   # non-aligned N -> wrapper pads
    (256, 32, 1, 1),
])
def test_topk_l2(N, D, M, k):
    k1, k2 = jax.random.split(KEY)
    db = rand(k1, (N, D))
    q = rand(k2, (M, D))
    d, i = topk_l2_pallas(db, q, k, bm=4, bn=128, interpret=True)
    dr, ir = ref.topk_l2_ref(db, q, k)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), atol=1e-4, rtol=1e-4)
    # indices may tie-break differently; distances must agree, and the
    # returned indices must realize those distances
    d2 = ((np.asarray(q)[:, None, :] - np.asarray(db)[None]) ** 2).sum(-1)
    got = np.sqrt(np.take_along_axis(d2, np.asarray(i), axis=1))
    np.testing.assert_allclose(got, np.asarray(dr), atol=1e-4, rtol=1e-4)


# -------------------------------------------------------------- ssm scan ---


@pytest.mark.parametrize("B,S,di,N", [
    (1, 64, 256, 8),
    (2, 128, 512, 16),
    (1, 96, 256, 4),     # chunk 32 divides 96
])
def test_ssm_scan(B, S, di, N):
    ks = jax.random.split(KEY, 5)
    x = rand(ks[0], (B, S, di), scale=0.5)
    dt = jax.nn.softplus(rand(ks[1], (B, S, di)) - 1.0)
    A = -jnp.exp(rand(ks[2], (di, N), scale=0.3))
    B_mat = rand(ks[3], (B, S, N), scale=0.5)
    C_mat = rand(ks[4], (B, S, N), scale=0.5)
    D = jnp.ones((di,))
    y, h = ssm_scan_pallas(x, dt, A, B_mat, C_mat, D, bd=128, chunk=32,
                           interpret=True)
    yr, hr = ref.ssm_scan_ref(x, dt, A, B_mat, C_mat, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------ moe gating ---


@pytest.mark.parametrize("T,E,k", [(100, 8, 2), (256, 64, 6), (17, 4, 2)])
def test_moe_gating(T, E, k):
    logits = rand(KEY, (T, E), scale=2.0)
    w, i = moe_gating_pallas(logits, k, bt=64, interpret=True)
    wr, ir = ref.moe_gating_ref(logits, k)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr), atol=1e-5, rtol=1e-5)
    # same expert sets (order may tie-break differently within equal probs)
    np.testing.assert_array_equal(np.sort(np.asarray(i), 1), np.sort(np.asarray(ir), 1))
