"""System-level behaviour tests for the paper's end-to-end claims.

(The detailed suites live in test_quest_end_to_end.py / test_archs_smoke.py /
test_kernels.py / test_runtime.py / test_distributed.py — this file checks
the public API surface and the cross-cutting invariants.)
"""
import importlib

import pytest


PUBLIC_MODULES = [
    "repro.core", "repro.index.retriever", "repro.extract", "repro.models",
    "repro.kernels.ops", "repro.serving.engine", "repro.training.driver",
    "repro.distributed.sharding", "repro.distributed.decode",
    "repro.launch.mesh", "repro.launch.specs", "repro.configs",
    "repro.data.corpus",
]


@pytest.mark.parametrize("mod", PUBLIC_MODULES)
def test_public_modules_import(mod):
    importlib.import_module(mod)


def test_all_archs_have_full_and_smoke_configs():
    from repro.configs import ARCH_IDS, get_config, get_smoke_config
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        full, smoke = get_config(a), get_smoke_config(a)
        assert full.family == smoke.family
        assert full.param_count() > smoke.param_count()


def test_shape_applicability_covers_40_cells():
    from repro.configs import ARCH_IDS, get_config
    from repro.configs.shapes import SHAPE_ORDER, applicable
    cells = [(a, s) for a in ARCH_IDS for s in SHAPE_ORDER]
    assert len(cells) == 40
    skipped = [(a, s) for a, s in cells if not applicable(get_config(a), s)[0]]
    # long_500k skips exactly the 8 pure full-attention archs
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    assert not any(a in ("zamba2-2.7b", "falcon-mamba-7b") for a, _ in skipped)


def test_ledger_conservation():
    """Engine token accounting equals the sum of extractor charges."""
    from repro.core import Engine, Filter, Query
    from repro.data.corpus import make_swde_corpus
    from repro.extract import OracleExtractor
    from repro.index.retriever import TwoLevelRetriever

    corpus = make_swde_corpus()
    eng = Engine(TwoLevelRetriever(corpus), OracleExtractor(corpus))
    q = Query(tables=["laptops"], select=[("laptops", "model_name")],
              where=Filter("price", "<", 1500, table="laptops"))
    res = eng.execute(q)
    led = res.ledger
    assert led.total_tokens == led.input_tokens + led.output_tokens
    assert led.llm_calls == led.extractions
    assert sum(led.per_phase.values()) == led.total_tokens
    assert led.per_phase.get("sampling", 0) > 0    # sampling phase charged
