"""Speculative decoding (DESIGN.md §14): drafters, verification, rollback.

The invariant everything here leans on: greedy output is *byte-identical*
with speculation on or off, for every drafter (including adversarial ones)
and every model family — a drafter can only change how fast tokens appear,
never which tokens. The rollback tests pin down the state-corruption
failure modes: rejected KV crossing a page boundary, rejected writes into a
CoW'd prefix boundary page, and SSM/conv recurrent state restored from
mid-sequence checkpoints.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.ledger import CostLedger
from repro.data import lm_data
from repro.models import decode_step, init_params, prefill, verify_chunk
from repro.serving.engine import Request, ServingEngine
from repro.serving.prefix_cache import PrefixCache
from repro.serving.spec_decode import (DraftModelDrafter, PromptLookupDrafter,
                                       prompt_lookup)

QWEN = "qwen2.5-3b"


def _cfg(arch=QWEN):
    return get_smoke_config(arch).replace(vocab_size=lm_data.VOCAB)


def _run(cfg, params, prompts, *, spec="off", layout="paged", pc=False,
         max_new=8, spec_k=4, shared=0, draft=None, **kw):
    eng = ServingEngine(cfg, params, slots=2, max_len=64, kv_layout=layout,
                        prefix_cache=pc, prefix_min_len=4, page_size=8,
                        chunk_size=5, spec_decode=spec, spec_k=spec_k,
                        draft_model=draft, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=max_new, eos_id=-1,
                           shared_len=shared))
    done = eng.run()
    return eng, {i: done[i].out for i in range(len(prompts))}


class ScriptedDrafter:
    """Test drafter proposing a fixed transform of the known true greedy
    continuation — exact (full-k acceptance) or off-by-one (zero
    acceptance). Exercises the protocol without a model."""

    def __init__(self, truth: dict, *, corrupt: bool, vocab: int):
        self.truth = truth          # rid -> full greedy out from an off run
        self.corrupt = corrupt
        self.vocab = vocab
        self.stats = {"draft_model_steps": 0}

    def on_insert(self, slot, req):
        pass

    def on_free(self, slot):
        pass

    def draft_round(self, reqs, k_eff):
        out = {}
        for slot, req in reqs.items():
            cont = self.truth[req.rid][len(req.out):]
            d = list(cont[: k_eff.get(slot, 0)])
            if self.corrupt:
                d = [(t + 1) % self.vocab for t in d]
            out[slot] = d
        return out


# ------------------------------------------------------- unit: cache write --


def test_cache_write_chunk_per_row_drops_out_of_bounds():
    """A fixed-width chunk write whose tail crosses the cache end must DROP
    the out-of-bounds positions, never clamp the window backward over valid
    earlier KV (regression: slab verify near max_len silently overwrote the
    prompt's K/V at positions [Smax-C, start))."""
    from repro.models.layers import cache_write_chunk
    cache = jnp.arange(2 * 8, dtype=jnp.float32).reshape(2, 8, 1)
    new = -jnp.ones((2, 5, 1), jnp.float32)
    out = np.asarray(cache_write_chunk(cache, new,
                                       jnp.asarray([2, 6], jnp.int32)))[:, :, 0]
    np.testing.assert_array_equal(out[0], [0, 1, -1, -1, -1, -1, -1, 7])
    # row 1: start 6 + width 5 crosses the end — positions 0..5 untouched,
    # 6..7 written, the 3 overflow positions dropped
    np.testing.assert_array_equal(out[1], [8, 9, 10, 11, 12, 13, -1, -1])


# ------------------------------------------------------------ unit: lookup --


def test_prompt_lookup_prefers_full_continuations():
    ctx = [1, 2, 3, 9, 9, 1, 2, 3, 4, 5, 6, 7, 1, 2, 3]
    # trailing 3-gram (1,2,3) matches at i=0 (cont 9,9,1,2) and i=5
    # (cont 4,5,6,7): the full-k continuation wins over recency order
    assert prompt_lookup(ctx, 4, 3) == [4, 5, 6, 7]


def test_prompt_lookup_shorter_than_ngram_window():
    assert prompt_lookup([], 4, 3) == []
    assert prompt_lookup([5], 4, 3) == []           # no proper earlier match
    assert prompt_lookup([5, 5], 4, 3) == [5]       # 1-gram fallback
    assert prompt_lookup([1, 2], 4, 3) == []


def test_prompt_lookup_never_proposes_past_context():
    ctx = [4, 4, 4]
    assert prompt_lookup(ctx, 8, 3) == [4]          # truncated, not invented


# ----------------------------------------------------- model: verify_chunk --


@pytest.mark.parametrize("arch", [QWEN, "falcon-mamba-7b", "zamba2-2.7b"])
def test_verify_chunk_matches_sequential_decode(arch):
    """Per-position verify logits equal the sequential decode logits, and
    the SSM/conv checkpoints at keep=j equal the state after j decode
    steps (the rollback contract)."""
    cfg = _cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = list(np.random.RandomState(3).randint(1, 200, size=9))
    _, cache = prefill(cfg, params, {"tokens": jnp.asarray(toks, jnp.int32)[None]}, 32)
    cand = [5, 9, 13, 17, 21]
    seq_cache, ref = dict(cache), []
    mid = None
    for j, t in enumerate(cand):
        lg, seq_cache = decode_step(cfg, params, jnp.asarray([[t]], jnp.int32),
                                    seq_cache)
        ref.append(np.asarray(lg)[0, 0])
        if j == 2:
            mid = {k: np.asarray(v) for k, v in seq_cache.items()
                   if k in ("conv", "ssm")}
    vl, _, ck = verify_chunk(cfg, params,
                             {"tokens": jnp.asarray([cand], jnp.int32)},
                             dict(cache))
    got = np.asarray(vl)[0]
    np.testing.assert_allclose(got, np.stack(ref), atol=1e-5, rtol=1e-5)
    assert (got.argmax(-1) == np.stack(ref).argmax(-1)).all()
    if ck:                                          # ssm/hybrid families
        keep = 3
        np.testing.assert_allclose(np.asarray(ck["ssm"][:, :, keep - 1]),
                                   mid["ssm"], atol=1e-6, rtol=1e-6)
        km1 = mid["conv"].shape[2]
        np.testing.assert_allclose(
            np.asarray(ck["conv"][:, :, keep:keep + km1], np.float32),
            np.asarray(mid["conv"], np.float32), atol=1e-6, rtol=1e-6)


# ------------------------------------------------------- engine: parity -----


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "deepseek-v2-lite-16b",
                                  "falcon-mamba-7b", "zamba2-2.7b",
                                  "whisper-medium", "llava-next-mistral-7b"])
def test_spec_decode_byte_identical_all_families(arch):
    """dense / moe+MLA / ssm / hybrid / encdec / vlm: greedy output with
    spec_decode="prompt_lookup" is byte-identical to the plain decode path,
    with and without the prefix cache."""
    cfg = _cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    shared = [7, 3, 9, 4, 2, 8, 1, 6, 5, 7, 3, 2]
    prompts = [shared + [10 + i, 20 + i, 30 + i] for i in range(3)]
    _, off = _run(cfg, params, prompts, spec="off", shared=len(shared))
    e_pl, on = _run(cfg, params, prompts, spec="prompt_lookup",
                    shared=len(shared))
    assert off == on
    assert e_pl.stats["spec_rounds"] == e_pl.stats["decode_steps"] > 0
    _, off_pc = _run(cfg, params, prompts, spec="off", pc=True,
                     shared=len(shared))
    _, on_pc = _run(cfg, params, prompts, spec="prompt_lookup", pc=True,
                    shared=len(shared))
    assert off == off_pc == on_pc


def test_spec_decode_slab_layout_byte_identical():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[7, 3, 9, 4, 2, 8, 1, 6, 5, 10 + i] for i in range(3)]
    _, off = _run(cfg, params, prompts, spec="off", layout="slab")
    _, on = _run(cfg, params, prompts, spec="prompt_lookup", layout="slab")
    assert off == on


def test_spec_decode_slab_near_max_len_does_not_clamp_writes():
    """Regression: a fixed-width verify chunk whose padded tail crosses
    max_len must *drop* the out-of-bounds K/V writes, not clamp the write
    window backward over valid earlier KV (which silently corrupted the
    prompt's cache and broke byte-identity near the bound)."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(range(1, 62))                    # 61 tokens, max_len 64
    for layout in ("slab", "paged"):
        _, off = _run(cfg, params, [prompt], spec="off", layout=layout,
                      max_new=8)
        _, on = _run(cfg, params, [prompt], spec="prompt_lookup",
                     layout=layout, max_new=8)
        assert off == on, f"near-bound divergence in {layout} layout"


@pytest.mark.parametrize("arch", [QWEN, "falcon-mamba-7b"])
def test_spec_decode_draft_model_byte_identical(arch):
    """Draft-model drafting (self-draft: the target doubles as its own
    drafter, the acceptance ceiling) — byte-identical output, near-full
    acceptance, and materially fewer target decode invocations."""
    cfg = _cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    dcfg = _cfg()                                  # dense draft for any target
    dparams = params if arch == QWEN else init_params(dcfg, jax.random.PRNGKey(0))
    prompts = [[7, 3, 9, 4, 2, 8, 1, 6, 5, 10 + i] for i in range(2)]
    e_off, off = _run(cfg, params, prompts, spec="off")
    e_dr, on = _run(cfg, params, prompts, spec="draft", draft=(dcfg, dparams))
    assert off == on
    assert e_dr.stats["draft_tokens"] > 0
    assert e_dr.drafter.stats["draft_model_steps"] > 0
    if arch == QWEN:                               # self-draft: ~all accepted
        assert e_dr.stats["accepted_tokens"] == e_dr.stats["draft_tokens"]
        assert e_dr.stats["decode_steps"] < e_off.stats["decode_steps"]


def test_draft_model_family_and_vocab_validated():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    ssm_cfg = _cfg("falcon-mamba-7b")
    with pytest.raises(ValueError, match="dense/moe"):
        ServingEngine(cfg, params, spec_decode="draft",
                      draft_model=(ssm_cfg, params))
    with pytest.raises(ValueError, match="vocab"):
        ServingEngine(cfg, params, spec_decode="draft",
                      draft_model=(cfg.replace(vocab_size=cfg.vocab_size + 1),
                                   params))
    with pytest.raises(ValueError, match="draft_model"):
        ServingEngine(cfg, params, spec_decode="draft")
    # falsy reads as off (the prefix_cache bool convention); a non-drafter
    # object fails at construction, not deep inside run()
    assert ServingEngine(cfg, params, spec_decode=False).spec is False
    assert ServingEngine(cfg, params, spec_decode=None).spec is False
    with pytest.raises(ValueError, match="drafter protocol"):
        ServingEngine(cfg, params, spec_decode=object())


# -------------------------------------------------- acceptance edge cases ---


def _truth(cfg, params, prompts, **kw):
    _, off = _run(cfg, params, prompts, spec="off", **kw)
    return off


def test_zero_acceptance_rounds_roll_back_exactly():
    """An adversarial drafter whose every proposal is wrong: each round
    rejects the full draft, emits exactly one token, and the rollback must
    leave output byte-identical to plain decode."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[7, 3, 9, 4, 2, 8, 1, 6, 5, 10 + i] for i in range(2)]
    truth = _truth(cfg, params, prompts)
    anti = ScriptedDrafter(truth, corrupt=True, vocab=cfg.vocab_size)
    e, on = _run(cfg, params, prompts, spec=anti)
    assert on == truth
    assert e.stats["draft_tokens"] > 0
    assert e.stats["accepted_tokens"] == 0 and e.stats["decode_steps_saved"] == 0
    # zero acceptance never does worse than one emission per round
    assert e.stats["spec_rounds"] == max(len(o) for o in truth.values()) - 1


def test_full_k_acceptance_saves_decode_steps():
    """An oracle drafter proposing the true continuation: every round
    accepts all k and emits k+1 tokens."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[7, 3, 9, 4, 2, 8, 1, 6, 5, 10 + i] for i in range(2)]
    truth = _truth(cfg, params, prompts, max_new=11)
    oracle = ScriptedDrafter(truth, corrupt=False, vocab=cfg.vocab_size)
    e, on = _run(cfg, params, prompts, spec=oracle, max_new=11, spec_k=4)
    assert on == truth
    assert e.stats["accepted_tokens"] == e.stats["draft_tokens"] > 0
    # 10 post-insert tokens at k=4 -> ceil(10 / 5) = 2 batched rounds for
    # both slots, each request saving 8 single-token steps
    assert e.stats["spec_rounds"] == 2
    assert e.stats["decode_steps_saved"] == 16


def test_rollback_across_page_boundary():
    """Rejected candidates spanning a page boundary: the scrubbed pages and
    released speculative page must leave the engine exactly on the plain
    decode trajectory, and the pool accounting must balance."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    # page_size 8, prompt 14 tokens: pos starts at 14, the k=4 verify round
    # writes positions 14..18 -> crosses the 16-boundary into a fresh page
    prompts = [list(range(1, 15))]
    truth = _truth(cfg, params, prompts)
    anti = ScriptedDrafter(truth, corrupt=True, vocab=cfg.vocab_size)
    e, on = _run(cfg, params, prompts, spec=anti)
    assert on == truth
    # every page returned once the request finished
    assert all(rc == 0 for rc in e.alloc.refcount[1:])
    assert e.alloc.free_pages == e.alloc.num_pages - 1


def test_rollback_of_cow_boundary_page_keeps_prefix_entry_intact():
    """Speculative writes + rollback happen in the slot's CoW copy of a
    prefix entry's boundary page: the entry's page bytes must stay
    untouched so later hits replay the same prefix KV."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    pc = PrefixCache(max_entries=8)
    eng = ServingEngine(cfg, params, slots=1, max_len=64, prefix_cache=pc,
                        prefix_min_len=4, page_size=8, chunk_size=6,
                        spec_decode="prompt_lookup", spec_k=4)
    shared = [7, 3, 9, 4, 2, 8, 1, 6, 5, 7]       # 10 tokens: tail page busy
    eng.submit(Request(rid=0, prompt=shared + [11, 12], max_new=3, eos_id=-1,
                       shared_len=len(shared)))
    eng.run()
    (entry,) = pc._entries.values()
    assert entry.tail_page is not None
    key = next(iter(eng.alloc.pools))
    before = np.asarray(eng.alloc.pools[key][:, entry.tail_page]).copy()
    for rid, tail in ((1, [21, 22]), (2, [31, 32, 33])):
        eng.submit(Request(rid=rid, prompt=shared + tail, max_new=6,
                           eos_id=-1, shared_len=len(shared)))
    done = eng.run()
    after = np.asarray(eng.alloc.pools[key][:, entry.tail_page])
    np.testing.assert_array_equal(before, after)
    assert eng.stats["prefix_hits"] == 2
    # and the decoded outputs equal a cold non-speculative engine's
    eng2 = ServingEngine(cfg, params, slots=1, max_len=64, prefix_cache=False,
                         page_size=8, chunk_size=6)
    for rid, tail in ((1, [21, 22]), (2, [31, 32, 33])):
        eng2.submit(Request(rid=rid, prompt=shared + tail, max_new=6,
                            eos_id=-1, shared_len=len(shared)))
    done2 = eng2.run()
    assert {r: done[r].out for r in (1, 2)} == \
        {r: done2[r].out for r in (1, 2)}


def test_pool_exhaustion_mid_spec_drains_slot_not_strands_it():
    """Speculative engines reserve prompt-only pages at insert and grow
    lazily, so the pool can pin mid-decode. The starved slot must be
    evicted back to the queue (bounded retries, fail-visibly contract) and
    every request must still finish with plain-decode output."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[7, 3, 9, 4, 2, 8][:6], [1, 6, 5, 11, 4, 9][:6]]
    # ample pool: the reference outputs
    _, want = _run(cfg, params, prompts, spec="prompt_lookup", max_new=8)
    # page_size 8, 6-token prompts -> 1 page each at insert, 2 over a
    # lifetime; a pool of 3 usable pages forces the slots to contend for
    # the third page the moment both verify rounds cross the boundary
    eng = ServingEngine(cfg, params, slots=2, max_len=32, page_size=8,
                        chunk_size=5, num_pages=4, prefix_cache=False,
                        spec_decode="prompt_lookup", spec_k=4)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=8, eos_id=-1,
                           max_retries=50))
    done = eng.run()
    assert {i: done[i].out for i in range(2)} == want
    assert eng.stats["evictions"] >= 1 and not eng.failed
    assert all(rc == 0 for rc in eng.alloc.refcount[1:])


def test_prompt_lookup_on_prompt_shorter_than_ngram_window():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[5], [9, 9]]
    _, off = _run(cfg, params, prompts, spec="off")
    _, on = _run(cfg, params, prompts, spec="prompt_lookup")
    assert off == on


def test_eos_inside_accepted_draft_stops_exactly_like_plain_decode():
    """If the true continuation hits EOS inside an accepted draft, the
    request must finish with the same output as plain decode."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[7, 3, 9, 4, 2, 8, 1, 6, 5, 11]]
    base = _truth(cfg, params, prompts, max_new=10)
    eos = base[0][4]                               # 5th generated token
    eng_off = ServingEngine(cfg, params, slots=1, max_len=64)
    eng_off.submit(Request(rid=0, prompt=prompts[0], max_new=10, eos_id=eos))
    off = eng_off.run()[0].out
    oracle = ScriptedDrafter(base, corrupt=False, vocab=cfg.vocab_size)
    eng_on = ServingEngine(cfg, params, slots=1, max_len=64,
                           spec_decode=oracle, spec_k=4)
    eng_on.submit(Request(rid=0, prompt=prompts[0], max_new=10, eos_id=eos))
    on = eng_on.run()[0].out
    assert on == off and on[-1] == eos


# ------------------------------------------------------ drafter internals ---


def test_draft_model_drafter_resyncs_after_rejection():
    """The draft cache realigns to the target's kept history by common
    prefix: after a full rejection its fed history must shrink back, after
    full acceptance it must lag by exactly the last draft token."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    d = DraftModelDrafter(cfg, params, slots=1, max_len=64)
    req = Request(rid=0, prompt=[7, 3, 9, 4, 2], max_new=8, eos_id=-1)
    d.on_insert(0, req)
    assert d._hist[0] == [7, 3, 9, 4, 2]
    req.out = [11]
    props = d.draft_round({0: req}, {0: 3})[0]
    assert len(props) == 3
    assert d._hist[0] == [7, 3, 9, 4, 2, 11] + props[:2]
    # target rejected everything: out grew by the corrected token only
    req.out = [11, 40]
    d.draft_round({0: req}, {0: 3})
    assert d._hist[0][:7] == [7, 3, 9, 4, 2, 11, 40]
    d.on_free(0)
    assert d._hist[0] == []


def test_prompt_lookup_drafter_respects_k_eff():
    pld = PromptLookupDrafter(ngram=3)
    req = Request(rid=0, prompt=[1, 2, 3, 4, 1, 2, 3], max_new=8, eos_id=-1)
    req.out = [4]                                  # context ends ...,1,2,3,4
    out = pld.draft_round({0: req}, {0: 2})
    assert out[0] == [1, 2]                        # capped at k_eff
    assert pld.draft_round({0: req}, {0: 0})[0] == []


# ------------------------------------------------------ stats / plumbing ----


def test_spec_stats_flow_through_served_extractor_and_ledger():
    from repro.core.scheduler import BatchScheduler
    from repro.data.corpus import make_swde_corpus
    from repro.extract.served import ServedExtractor
    from repro.index.retriever import TwoLevelRetriever

    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_swde_corpus()
    docs = sorted(corpus.tables["universities"])[:2]
    items = [(d, a, "universities") for d in docs
             for a in ("tuition", "enrollment")]

    def run(spec):
        engine = ServingEngine(cfg, params, slots=2, max_len=1024,
                               prefix_cache=True, spec_decode=spec, spec_k=4)
        extractor = ServedExtractor(corpus, engine, max_new=16)
        ledger = CostLedger()
        sched = BatchScheduler(TwoLevelRetriever(corpus, mode="rag_topk"),
                               extractor, ledger, {}, batch_size=2)
        rows = sched.extract_many(items)
        return rows, engine, extractor, ledger

    rows_off, e_off, _, led_off = run("off")
    rows_on, e_on, ex_on, led_on = run("prompt_lookup")
    assert rows_on == rows_off
    # token columns are speculation-invariant; savings reported apart
    for col in ("input_tokens", "output_tokens", "total_tokens", "per_phase"):
        assert led_on.snapshot()[col] == led_off.snapshot()[col]
    assert e_on.stats["draft_tokens"] > 0
    assert ex_on.stats.draft_tokens == e_on.stats["draft_tokens"]
    assert ex_on.stats.accepted_tokens == e_on.stats["accepted_tokens"]
    assert led_on.draft_tokens == e_on.stats["draft_tokens"]
    assert led_on.decode_steps_saved == e_on.stats["decode_steps_saved"]
    snap = led_on.snapshot()
    assert {"draft_tokens", "accepted_tokens",
            "decode_steps_saved"} <= set(snap)
