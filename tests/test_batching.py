"""Batched cross-document execution (DESIGN.md §9): semantics tests.

Batching happens only *across* documents — never reordering the lazy
short-circuit plan within one — so the batched engine must return exactly
the serial engine's rows and charge exactly the serial ledger's tokens, at
every batch size. Plus: duplicate (doc, attr) needs inside one batch are
deduplicated to a single charge.
"""
import pytest

from repro.core import Engine, Filter, JoinEdge, Query, conj, disj
from repro.core.expr import And
from repro.data.corpus import make_swde_corpus, make_wiki_corpus
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever


@pytest.fixture(scope="module")
def wiki():
    return make_wiki_corpus(seed=0)


def _run(corpus, query, *, batch_size, seed=0, **kw):
    retr = TwoLevelRetriever(corpus)
    eng = Engine(retr, OracleExtractor(corpus), seed=seed,
                 batch_size=batch_size, **kw)
    return eng.execute(query)


def _row_key(r):
    return tuple(sorted(r["_docs"].items()))


def assert_equivalent(res_a, res_b):
    assert sorted(map(_row_key, res_a.rows)) == sorted(map(_row_key, res_b.rows))
    for r_a, r_b in zip(sorted(res_a.rows, key=_row_key),
                        sorted(res_b.rows, key=_row_key)):
        assert r_a == r_b
    led_a, led_b = res_a.ledger, res_b.ledger
    assert led_a.input_tokens == led_b.input_tokens
    assert led_a.output_tokens == led_b.output_tokens
    assert led_a.extractions == led_b.extractions
    assert led_a.per_phase == led_b.per_phase


@pytest.mark.parametrize("batch_size", [4, 8, 64])
def test_single_table_batched_equals_serial(wiki, batch_size):
    expr = conj(Filter("age", ">", 30, table="players"),
                Filter("all_stars", ">=", 5, table="players"))
    q = Query(tables=["players"], select=[("players", "player_name")], where=expr)
    serial = _run(wiki, q, batch_size=1)
    batched = _run(wiki, q, batch_size=batch_size, queue_depth=16)
    assert_equivalent(serial, batched)
    assert batched.ledger.max_batch > 1          # batching actually engaged


def test_disjunctive_tree_batched_equals_serial(wiki):
    expr = And((disj(Filter("age", ">", 38, table="players"),
                     Filter("all_stars", ">=", 12, table="players")),
                Filter("ppg", ">", 5.0, table="players")))
    q = Query(tables=["players"], select=[("players", "player_name")], where=expr)
    assert_equivalent(_run(wiki, q, batch_size=1),
                      _run(wiki, q, batch_size=8))


def test_join_batched_equals_serial(wiki):
    expr = conj(Filter("age", ">", 32, table="players"),
                Filter("championships", ">", 14, table="teams"))
    q = Query(tables=["players", "teams"],
              select=[("players", "player_name"), ("teams", "team_name")],
              where=expr,
              joins=[JoinEdge("players", "team_name", "teams", "team_name")])
    for strategy in ("transform", "pushdown"):
        assert_equivalent(
            _run(wiki, q, batch_size=1, seed=1, join_strategy=strategy),
            _run(wiki, q, batch_size=8, seed=1, join_strategy=strategy))


def test_repeated_key_in_batch_charged_once():
    corpus = make_swde_corpus()
    retr = TwoLevelRetriever(corpus)
    eng = Engine(retr, OracleExtractor(corpus), batch_size=8)
    doc = sorted(corpus.tables["universities"])[0]
    keys = [(doc, "tuition", "universities")] * 5
    out = eng.scheduler.extract_many(keys)
    assert set(out) == {(doc, "tuition")}
    assert eng.ledger.extractions <= 1           # 0 if retrieval was empty
    assert eng.scheduler.stats.dedup_hits == 4
    # a second sweep over the same key is a pure cache hit, still one charge
    before = eng.ledger.total_tokens
    eng.scheduler.extract_many([(doc, "tuition", "universities")])
    assert eng.ledger.total_tokens == before


def test_served_extract_batch_matches_serial():
    """One continuous-batching round returns the same (value, tokens) pairs
    as draining the engine once per extraction (greedy decode is per-slot
    independent), and really uses a single engine.run()."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data import lm_data
    from repro.extract.served import ServedExtractor
    from repro.models import init_params
    from repro.serving.engine import ServingEngine

    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_swde_corpus()
    retr = TwoLevelRetriever(corpus, mode="rag_topk")
    items = []
    for doc_id in sorted(corpus.tables["universities"])[:4]:
        segs = retr.segments(doc_id, "tuition", "universities")
        if segs:
            items.append((doc_id, "tuition", segs))
    assert len(items) >= 2

    serial_eng = ServingEngine(cfg, params, slots=1, max_len=512)
    serial = ServedExtractor(corpus, serial_eng, max_new=6)
    want = [serial.extract(d, a, s) for d, a, s in items]
    assert serial_eng.stats["runs"] == len(items)

    batch_eng = ServingEngine(cfg, params, slots=4, max_len=512)
    batched = ServedExtractor(corpus, batch_eng, max_new=6)
    got = batched.extract_batch(items)
    assert batch_eng.stats["runs"] == 1
    assert got == want
    assert batched.stats.max_batch == len(items)


def test_scheduler_stats_and_ledger_batches(wiki):
    expr = conj(Filter("age", ">", 30, table="players"),
                Filter("all_stars", ">=", 5, table="players"))
    q = Query(tables=["players"], select=[("players", "player_name")], where=expr)
    retr = TwoLevelRetriever(wiki)
    eng = Engine(retr, OracleExtractor(wiki), batch_size=8)
    eng.execute(q)
    # ledger batches = scheduler extraction rounds + sampling-phase chunks
    assert eng.ledger.batches >= eng.scheduler.stats.rounds >= 1
    assert eng.ledger.batched_extractions >= eng.scheduler.stats.submitted
    assert 1 < eng.ledger.max_batch <= 8
    assert eng.scheduler.stats.max_batch <= 8
