"""End-to-end QUEST behaviour on the synthetic corpora (paper's system claims).

Validates: (1) query answers match ground truth with high F1; (2) QUEST's
token cost is below the full-document (Lotus-like) baseline; (3) joins via
transformation return the same rows as pushdown but cheaper (Lemma 2's
consequence); (4) the two-level index beats segment-only on cost.
"""
import pytest

from repro.core import Engine, Filter, JoinEdge, Query, conj, disj
from repro.core.expr import evaluate_expr
from repro.data.corpus import make_swde_corpus, make_wiki_corpus
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever


@pytest.fixture(scope="module")
def wiki():
    corpus = make_wiki_corpus(seed=0)
    retr = TwoLevelRetriever(corpus)
    return corpus, retr


def truth_rows(corpus, table, expr):
    out = []
    for doc_id, truth in corpus.truth_rows(table).items():
        if expr is None or evaluate_expr(expr, truth):
            out.append(doc_id)
    return out


def prf(pred_ids, true_ids):
    pred, true = set(pred_ids), set(true_ids)
    tp = len(pred & true)
    p = tp / max(len(pred), 1)
    r = tp / max(len(true), 1)
    f1 = 2 * p * r / max(p + r, 1e-9)
    return p, r, f1


def run(corpus, retr_mode, query, **engine_kw):
    retr = TwoLevelRetriever(corpus, mode=retr_mode)
    eng = Engine(retr, OracleExtractor(corpus), **engine_kw)
    return eng.execute(query)


def test_single_table_accuracy_and_cost(wiki):
    corpus, retr = wiki
    expr = conj(Filter("age", ">", 30, table="players"),
                Filter("all_stars", ">=", 5, table="players"))
    q = Query(tables=["players"], select=[("players", "player_name")], where=expr)

    eng = Engine(retr, OracleExtractor(corpus))
    res = eng.execute(q)
    pred = [r["_docs"]["players"] for r in res.rows]
    true = truth_rows(corpus, "players", expr)
    p, r, f1 = prf(pred, true)
    assert f1 >= 0.8, (p, r, f1)

    # Lotus-like full-doc scan must cost much more
    res_full = run(corpus, "fulldoc", q)
    assert res.ledger.total_tokens < 0.5 * res_full.ledger.total_tokens, (
        res.ledger.total_tokens, res_full.ledger.total_tokens)


def test_two_level_beats_segment_only_on_cost(wiki):
    # players.age overlaps lexically with owners' bios (shared template), so
    # segment-only pays extraction cost on out-of-domain documents that the
    # document-level index would have pruned (paper Fig. 8-a mechanism).
    corpus, _ = wiki
    expr = conj(Filter("age", ">", 33, table="players"),
                Filter("ppg", ">", 10.0, table="players"))
    q = Query(tables=["players"], select=[("players", "player_name")], where=expr)
    res_quest = run(corpus, "quest", q)
    res_seg = run(corpus, "segment_only", q)
    true = truth_rows(corpus, "players", expr)
    _, _, f1_q = prf([r["_docs"]["players"] for r in res_quest.rows], true)
    _, _, f1_s = prf([r["_docs"]["players"] for r in res_seg.rows], true)
    assert res_quest.ledger.total_tokens < res_seg.ledger.total_tokens, (
        res_quest.ledger.total_tokens, res_seg.ledger.total_tokens)
    assert f1_q >= f1_s - 0.05, (f1_q, f1_s)


def test_disjunction_query(wiki):
    corpus, retr = wiki
    expr = disj(Filter("age", ">", 38, table="players"),
                Filter("all_stars", ">=", 12, table="players"))
    q = Query(tables=["players"], select=[("players", "player_name")], where=expr)
    res = Engine(retr, OracleExtractor(corpus), seed=3).execute(q)
    pred = [r["_docs"]["players"] for r in res.rows]
    true = truth_rows(corpus, "players", expr)
    _, _, f1 = prf(pred, true)
    assert f1 >= 0.75, f1


def _join_truth(corpus, p_age, t_champ):
    truth = []
    for pid, pt in corpus.truth_rows("players").items():
        for tid, tt in corpus.truth_rows("teams").items():
            if pt["team_name"] == tt["team_name"] and pt["age"] > p_age \
                    and tt["championships"] > t_champ:
                truth.append((pt["player_name"], tt["team_name"]))
    return truth


def test_join_transform_matches_pushdown_rows_cheaper(wiki):
    corpus, _ = wiki
    # selective team-side filter => the transformed IN filter has low
    # selectivity, the regime where the paper's Lemma 2 gain is clear-cut
    expr = conj(Filter("age", ">", 32, table="players"),
                Filter("championships", ">", 14, table="teams"))
    q = Query(tables=["players", "teams"],
              select=[("players", "player_name"), ("teams", "team_name")],
              where=expr,
              joins=[JoinEdge("players", "team_name", "teams", "team_name")])
    res_t = run(corpus, "quest", q, join_strategy="transform", seed=1)
    res_p = run(corpus, "quest", q, join_strategy="pushdown", seed=1)

    rows_t = {(r["players.player_name"], r["teams.team_name"]) for r in res_t.rows}
    rows_p = {(r["players.player_name"], r["teams.team_name"]) for r in res_p.rows}
    truth = _join_truth(corpus, 32, 14)
    _, _, f1_t = prf(rows_t, truth)
    _, _, f1_p = prf(rows_p, truth)
    assert f1_t >= 0.7, (f1_t, len(rows_t), len(truth))
    assert f1_t >= f1_p - 0.15, (f1_t, f1_p)
    # cost: transform must beat classical pushdown in this selective regime
    assert res_t.ledger.total_tokens < res_p.ledger.total_tokens, (
        res_t.ledger.total_tokens, res_p.ledger.total_tokens)


def test_swde_short_docs():
    corpus = make_swde_corpus()
    expr = conj(Filter("tuition", "<", 30000, table="universities"),
                Filter("enrollment", ">", 20000, table="universities"))
    q = Query(tables=["universities"], select=[("universities", "university_name")],
              where=expr)
    res = run(corpus, "quest", q)
    pred = [r["_docs"]["universities"] for r in res.rows]
    true = truth_rows(corpus, "universities", expr)
    _, _, f1 = prf(pred, true)
    assert f1 >= 0.8, f1
